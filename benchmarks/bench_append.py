"""Grow-phase append throughput — the host-sync-free protocol headline.

Two comparisons per array size (the largest decides the acceptance claim):

``append.donated.n*`` vs ``append.undonated.n*``
    The amortized protocol (CapacityPlanner + donated structure-cached
    ``gg.append``) against the legacy path (per-wave ``ensure_capacity``
    device read + undonated ``push_back``, which copies every bucket level).
    ``derived`` reports appends/s and **host transfers per append wave**,
    counted by a ``jax.device_get`` spy in a separate (untimed) pass: the
    donated path amortizes to ~0, the legacy path pays exactly 1 per wave.

``append.fused.n*`` vs ``append.scan.n*``
    The fused Pallas push-back kernel (offsets + all-level scatter in one
    tiled pass) against the jnp scan+scatter, both under the donated
    protocol.

Smoke mode (``REPRO_BENCH_SMOKE=1`` or ``--smoke``) shrinks sizes for the CI
artifact run; the measured code paths are identical.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.core import ggarray as gg

from benchmarks.common import emit, smoke_mode, timeit, write_json

NBLOCKS = 8
WAVES = 16


def _sizes() -> tuple[int, ...]:
    if smoke_mode():
        return (1 << 8,)
    return (1 << 10, 1 << 12, 1 << 14)


def _grow_donated(n: int, method: str = "scan"):
    m = n // WAVES // NBLOCKS
    wave = jnp.ones((NBLOCKS, m), jnp.float32)
    arr = gg.init(NBLOCKS, b0=max(m, 1))
    planner = gg.CapacityPlanner()
    for _ in range(WAVES):
        arr = planner.reserve(arr, m)
        arr, _, hd = gg.append(arr, wave, method=method)
        planner.note_append(arr, hd)
    return arr.buckets


def _grow_undonated(n: int, method: str = "scan"):
    m = n // WAVES // NBLOCKS
    wave = jnp.ones((NBLOCKS, m), jnp.float32)
    arr = gg.init(NBLOCKS, b0=max(m, 1))
    for _ in range(WAVES):
        arr = gg.ensure_capacity(arr, m)  # one device read per wave
        arr, _ = gg.push_back(arr, wave, method=method)
    return arr.buckets


def _count_transfers(fn) -> int:
    """Run ``fn`` once under a jax.device_get spy (untimed pass)."""
    calls = 0
    real_get = jax.device_get

    def spy(x):
        nonlocal calls
        calls += 1
        return real_get(x)

    jax.device_get = spy
    try:
        jax.block_until_ready(fn())
    finally:
        jax.device_get = real_get
    return calls


def bench_protocol() -> None:
    for n in _sizes():
        t_don = timeit(lambda: _grow_donated(n), repeats=5, warmup=1)
        t_und = timeit(lambda: _grow_undonated(n), repeats=5, warmup=1)
        x_don = _count_transfers(lambda: _grow_donated(n))
        x_und = _count_transfers(lambda: _grow_undonated(n))
        apps = n / t_don * 1e6
        emit(
            f"append.donated.n{n}", t_don,
            f"appends_per_s={apps:.0f} transfers_per_wave={x_don / WAVES:.2f} "
            f"speedup_vs_undonated={t_und / t_don:.2f}",
        )
        emit(
            f"append.undonated.n{n}", t_und,
            f"appends_per_s={n / t_und * 1e6:.0f} transfers_per_wave={x_und / WAVES:.2f}",
        )


def bench_insert_method() -> None:
    from repro.kernels.tuning import FUSED_PUSH_BACK_MIN_WAVE, resolve_push_back_method

    for n in _sizes():
        m = n // WAVES // NBLOCKS
        t_fused = timeit(lambda: _grow_donated(n, "fused"), repeats=3, warmup=1)
        t_scan = timeit(lambda: _grow_donated(n, "scan"), repeats=3, warmup=1)
        t_auto = timeit(lambda: _grow_donated(n, "auto"), repeats=3, warmup=1)
        emit(f"append.fused.n{n}", t_fused, f"speedup_vs_scan={t_scan / t_fused:.2f}")
        emit(f"append.scan.n{n}", t_scan, "")
        # "auto" must track the better side of the tuned crossover — the
        # resolved method and the threshold it came from go in the artifact
        # so a re-tune of kernels/tuning.py shows up in the bench history.
        emit(
            f"append.auto.n{n}",
            t_auto,
            f"resolved={resolve_push_back_method('auto', m)} m={m} "
            f"threshold={FUSED_PUSH_BACK_MIN_WAVE} "
            f"vs_best={min(t_fused, t_scan) / t_auto:.2f}",
        )


def bench_arena_growth() -> None:
    """Slab-arena append waves under each pool growth schedule.

    The flat schedules realloc+memcpy the whole pool on growth; the extent
    schedules (``"doubling"``/``"tz"``, DESIGN.md §8) append fresh extents
    and copy nothing — ``derived`` records grow events, bytes memcpy'd, and
    the final extent count so the tradeoff shows up in the bench history.
    """
    from repro.pool import SlabArena

    labels = {1: "flat", "geometric": "geometric", "doubling": "doubling", "tz": "tz"}
    for n in _sizes():
        m = max(n // WAVES // NBLOCKS, 1)
        wave = jnp.ones((NBLOCKS, m), jnp.float32)

        def run(sched):
            arena = SlabArena(NBLOCKS, m, dtype=jnp.float32, grow_chunk=sched)
            for _ in range(WAVES):
                arena.append(wave)
            return arena

        for sched, label in labels.items():
            t = timeit(lambda: run(sched).pool.extents[-1], repeats=3, warmup=1)
            a = run(sched)
            emit(
                f"append.arena.{label}.n{n}",
                t,
                f"grow_events={a.pool_grow_events} "
                f"copied={a.pool_copied_bytes}B extents={a.pool.n_extents}",
            )


def main() -> None:
    bench_protocol()
    bench_insert_method()
    bench_arena_growth()


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        import os

        os.environ["REPRO_BENCH_SMOKE"] = "1"
    from benchmarks.common import Row

    main()
    write_json("append", Row.rows)  # standalone run: emit the CI artifact
