"""Paper Fig. 3 — theoretical memory usage under log-normal insertion loads.

Exact reproduction (no CPU scaling needed — it's an analytic/Monte-Carlo
model): memory, relative to the realized optimum, for static (sized for 1%
failure), semistatic doubling, and GGArray, for sigma ∈ [0, 2].
"""
from __future__ import annotations

import numpy as np

from repro.core.theory import MemoryModel, memory_curves

from benchmarks.common import emit


def main() -> None:
    curves = memory_curves(np.linspace(0.0, 2.0, 9), MemoryModel())
    for i, sigma in enumerate(curves["sigma"]):
        emit(
            f"fig3.memory.sigma{sigma:.2f}",
            0.0,
            (
                f"gg/opt={curves['ggarray_over_optimal'][i]:.3f} "
                f"static/opt={curves['static_over_optimal'][i]:.3f}"
            ),
        )
    worst = float(curves["ggarray_over_optimal"].max())
    emit("fig3.ggarray.worst_ratio", 0.0, f"{worst:.3f} (paper bound: <= 2x)")


if __name__ == "__main__":
    main()
