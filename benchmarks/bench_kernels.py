"""Memory-space kernel matrix — vmem vs hbm tilings, one-hot vs MXU dispatch.

Every indirection kernel family runs under two ``GridPlan`` tilings
(``kernels/common``, DESIGN.md §4.7): the all-VMEM-resident layout and the
HBM-resident layout whose scalar-prefetched tables drive per-tile DMA.  The
rows time both on identical inputs:

``kernels.<family>.{vmem,hbm}.n*``
    paged gather, slab-append, fused push-back, and segmented flatten.
    Off-TPU these wall-clocks are interpreter-relative (the hbm tilings run
    more, smaller grid steps, so they are *slower* under interpretation —
    the claim under test is bit-identical results and the DMA-sized
    footprint, not CPU ms; on a real TPU the vmem tiling simply cannot hold
    serving-scale pools resident).

``kernels.dispatch.{onehot,mxu}.m*``
    the insert permutation below and above the measured
    ``kernels/tuning.MXU_DISPATCH_WAVE`` crossover — the exact int32
    one-hot reduction vs the dispatch matmul
    (``kernels/dispatch_mxu.permute_rows``), bit-exact by construction.

Usage: ``python benchmarks/bench_kernels.py [--smoke]`` → rows on stdout +
``BENCH_kernels.json`` (benchmarks/run.py schema).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    Row, emit, smoke_mode, timeit, write_json, write_metrics_json,
)
from repro.obs import device
from repro.core import ggarray as gg
from repro.core import indexing
from repro.kernels.flatten import ops as flatten_ops
from repro.kernels.paged import ops as paged_ops
from repro.kernels import tuning
from repro.kernels.push_back import ops as pb_ops

SPACES = ("vmem", "hbm")


def _paged_setup(rng, S, T, N, P, D):
    pages = np.full((N, P), -1, np.int32)
    perm = rng.permutation(S)
    k = 0
    for i in range(N):
        for p in range(rng.integers(1, P + 1)):
            pages[i, p] = perm[k]
            k += 1
    owners = np.full((S,), -1, np.int32)
    bases = np.zeros((S,), np.int32)
    for i in range(N):
        for p in range(P):
            if pages[i, p] >= 0:
                owners[pages[i, p]] = i
                bases[pages[i, p]] = p * T
    pool = jnp.asarray(rng.standard_normal((S, T, D)), jnp.float32)
    return pool, jnp.asarray(pages), jnp.asarray(owners), jnp.asarray(bases)


def main() -> None:
    smoke = smoke_mode() or "--smoke" in sys.argv
    rng = np.random.default_rng(0)
    reps = dict(repeats=3, warmup=1) if smoke else dict(repeats=5, warmup=2)

    # --- paged gather + slab append --------------------------------------
    S, T, N, P, D = (16, 8, 8, 2, 4) if smoke else (96, 16, 24, 4, 16)
    m = 8 if smoke else 32
    pool, pages, owners, bases = _paged_setup(rng, S, T, N, P, D)
    sizes = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    elems = jnp.asarray(rng.standard_normal((N, m, D)), jnp.float32)
    n = S * T * D
    for space in SPACES:
        us = timeit(
            lambda: paged_ops.paged_gather(pool, pages, memory_space=space), **reps
        )
        emit(f"kernels.gather.{space}.n{n}", us, f"S={S} T={T} N={N} P={P}")
    wave_mask = jnp.ones((N, m), bool)
    for space in SPACES:
        us = timeit(
            lambda: paged_ops.slab_append(
                pool, owners, bases, sizes, elems, wave_mask, memory_space=space
            ),
            **reps,
        )
        emit(f"kernels.slab_append.{space}.n{n}", us, f"wave={N}x{m}")

    # --- fused push-back ---------------------------------------------------
    nblocks, b0, nlev = (8, 8, 3) if smoke else (16, 64, 5)
    mm = 8 if smoke else 32
    arr = gg.init(nblocks, b0, dtype=jnp.float32, nbuckets=nlev)
    wave = jnp.asarray(rng.standard_normal((nblocks, mm)), jnp.float32)
    wmask = jnp.asarray(rng.random((nblocks, mm)) > 0.3)
    wsizes = jnp.asarray(rng.integers(0, b0, nblocks), jnp.int32)
    cap = nblocks * indexing.capacity(b0, nlev)
    for space in SPACES:
        us = timeit(
            lambda: pb_ops.push_back_fused(
                arr.buckets, wsizes, b0, wave, wmask, memory_space=space
            ),
            **reps,
        )
        emit(f"kernels.push_back.{space}.n{cap}", us, f"levels={nlev} m={mm}")

    # --- segmented flatten -------------------------------------------------
    per = rng.integers(0, indexing.capacity(b0, nlev) + 1, nblocks)
    fm = max(int(per.max()), 1)
    fel = jnp.asarray(rng.standard_normal((nblocks, fm)), jnp.float32)
    fmask = jnp.asarray(np.arange(fm)[None, :] < per[:, None])
    farr, _ = gg.push_back(
        gg.init(nblocks, b0, dtype=jnp.float32, nbuckets=nlev), fel, fmask
    )
    for space in SPACES:
        us = timeit(
            lambda: flatten_ops.flatten_segmented(
                farr.buckets, farr.sizes, farr.b0, memory_space=space
            ),
            **reps,
        )
        emit(f"kernels.flatten.{space}.n{cap}", us, f"levels={nlev}")

    # --- dispatch: one-hot vs MXU across the wave threshold ----------------
    # Bracket the *measured* crossover (kernels/tuning.py) so a re-tune moves
    # the sweep with it — the threshold cannot drift from what the kernels use.
    thr = tuning.MXU_DISPATCH_WAVE
    waves = (8, thr) if smoke else (thr // 4, thr // 2, thr, 2 * thr)
    for wm in waves:
        delems = jnp.asarray(rng.standard_normal((nblocks, wm)), jnp.float32)
        dmask = jnp.asarray(rng.random((nblocks, wm)) > 0.3)
        outs = {}
        for disp in ("onehot", "mxu"):
            us = timeit(
                lambda: pb_ops.push_back_fused(
                    arr.buckets, wsizes, b0, delems, dmask, dispatch=disp
                ),
                **reps,
            )
            outs[disp] = pb_ops.push_back_fused(
                arr.buckets, wsizes, b0, delems, dmask, dispatch=disp
            )
            emit(f"kernels.dispatch.{disp}.m{wm}", us, f"threshold={thr}")
        for a, b in zip(jax.tree.leaves(outs["onehot"]), jax.tree.leaves(outs["mxu"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # --- device counter plane: per-family kernel geometry (DESIGN.md §9.x) -
    # One instrumented call per family on the inputs just timed; the derived
    # waste/occupancy ratios land in METRICS_kernels.json for trajectory
    # tracking next to the wall-clock rows.
    families = {}
    for space in SPACES:
        _, gv = paged_ops.paged_gather(
            pool, pages, memory_space=space, instrument=True
        )
        families[f"gather.{space}"] = device.as_dict(gv)
        _, pv = pb_ops.push_back_fused(
            arr.buckets, wsizes, b0, wave, wmask,
            memory_space=space, instrument=True,
        )[-2:]
        families[f"push_back.{space}"] = device.as_dict(pv)
        _, fv = flatten_ops.flatten_segmented(
            farr.buckets, farr.sizes, farr.b0,
            memory_space=space, instrument=True,
        )
        families[f"flatten.{space}"] = device.as_dict(fv)
    sv = paged_ops.slab_append(
        pool, owners, bases, sizes, elems, wave_mask, instrument=True
    )[3]
    families["slab_append"] = device.as_dict(sv)

    def _ratio(d, num, den):
        return d[num] / max(d[den], 1.0)

    gd = families["gather.vmem"]
    pd = families["push_back.vmem"]
    sd = families["slab_append"]
    derived = {
        "gather_masked_tile_frac": gd["paged_gather.masked_tiles"]
        / max(gd["paged_gather.tiles"] + gd["paged_gather.masked_tiles"], 1.0),
        "push_back_occupancy": _ratio(
            pd, "push_back.active_lanes", "push_back.lanes"
        ),
        "push_back_padded_lane_frac": _ratio(
            pd, "push_back.padded_lanes", "push_back.lanes"
        ),
        "append_occupancy": _ratio(
            sd, "slab_append.active_lanes", "slab_append.lanes"
        ),
    }
    emit(
        "kernels.device.push_back_occupancy_pct",
        derived["push_back_occupancy"] * 100.0,
        f"active/total wave lanes ({pd['push_back.active_lanes']:.0f}"
        f"/{pd['push_back.lanes']:.0f})",
    )
    emit(
        "kernels.device.gather_masked_tile_pct",
        derived["gather_masked_tile_frac"] * 100.0,
        "page-table entries walked without a live slab",
    )
    write_metrics_json("kernels", {"device": {**families, "derived": derived}})


if __name__ == "__main__":
    start = len(Row.rows)
    print("name,us_per_call,derived")
    main()
    write_json("kernels", Row.rows[start:])
