"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract) and writes one
``BENCH_<name>.json`` per module (``BENCH_append.json``,
``BENCH_two_phase.json``, …) for trajectory tracking — schema in
``benchmarks/common.py::write_json``; output dir via ``REPRO_BENCH_DIR``.
Roofline numbers come from the dry-run artifacts
(benchmarks/roofline_table.py), not from CPU wall-clock.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_append,
        bench_insertion,
        bench_kernels,
        bench_kvcache,
        bench_memory,
        bench_nblocks,
        bench_operations,
        bench_pool,
        bench_two_phase,
    )
    from benchmarks.common import Row, write_json

    print("name,us_per_call,derived")
    failures = 0
    for mod in (
        bench_memory,       # Fig. 3 (fast, analytic)
        bench_insertion,    # Fig. 4 col 1
        bench_nblocks,      # Fig. 4 cols 2-3
        bench_operations,   # Table II / Fig. 5
        bench_append,       # host-sync-free grow protocol (PR 2 headline)
        bench_two_phase,    # Fig. 6
        bench_kernels,      # memory-space tilings + MXU dispatch (PR 4)
        bench_kvcache,      # beyond-paper serving payoff
        bench_pool,         # slab arena: fleet capacity + sequences/s
    ):
        start = len(Row.rows)
        try:
            mod.main()
            write_json(mod.__name__.removeprefix("benchmarks.bench_"), Row.rows[start:])
        except Exception:
            failures += 1
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
