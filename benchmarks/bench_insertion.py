"""Paper Fig. 4 column 1 — choosing the fastest insertion algorithm.

Protocol (scaled for CPU): start with a static array of N elements and
duplicate its size per wave by inserting N more with each algorithm:
``atomic`` (serialized counter), ``scan`` (cumsum / warp-shuffle analog),
``matmul`` (the tensor-core scan algorithm in XLA ops).  The paper's claims
under test: shuffle-scan fastest, atomic slowest, tensor-core competitive
but workload-starved at 1 element/thread.

The serialized ``atomic`` path is capped at 2^15-element waves (it is the
paper's pathological baseline; CPU wall-clock past that adds minutes, not
information) — capping is logged per the no-silent-caps rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core.insertion import insertion_offsets
from repro.kernels.scan_mxu import ref as mxu_ref

from benchmarks.common import emit, timeit

START = 1 << 12
DUPS = 7
ATOMIC_CAP = 1 << 15


def _insert_with(method: str, mask: jax.Array) -> jax.Array:
    if method == "matmul":
        m = mask.astype(jnp.int32)
        inc = mxu_ref.row_scan_matmul(m)
        return inc - m, inc[:, -1]
    return insertion_offsets(mask, method=method)


def main() -> None:
    for method in ("atomic", "scan", "matmul"):
        size = START
        for wave in range(DUPS):
            if method == "atomic" and size > ATOMIC_CAP:
                emit(f"fig4.insertion.{method}.n{size}", float("nan"),
                     "capped: serialized baseline beyond 2^15 (logged, not silent)")
                size *= 2
                continue
            mask = jnp.ones((1, size), bool)
            fn = jax.jit(lambda m=mask, meth=method: _insert_with(meth, m))
            us = timeit(fn, repeats=3, warmup=1)
            emit(f"fig4.insertion.{method}.n{size}", us, f"elements={size}")
            size *= 2


if __name__ == "__main__":
    main()
