"""Beyond-paper: GGArray as a serving KV cache (DESIGN.md §3).

Reduced model, batched generation past the initial cache capacity: decode
throughput, growth events, bytes copied and allocated per policy.  The
paper's structure translated to its serving payoff: semistatic copies the
whole live cache on growth; GGArray never copies and stays ≤ 2× memory.
"""
from __future__ import annotations

import time

import jax

from repro.configs import reduced
from repro.models import transformer
from repro.serving.engine import Engine

from benchmarks.common import emit

NEW_TOKENS = 48


def main() -> None:
    cfg = reduced("qwen2.5-3b", cache_b0=8)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3, 4, 5], [6, 7, 8], [9, 10, 11, 12], [13, 14]]
    for policy in ("static", "semistatic", "ggarray"):
        eng = Engine(params, cfg, policy=policy, max_len=128)
        t0 = time.perf_counter()
        eng.generate(prompts, max_new_tokens=NEW_TOKENS)
        dt = time.perf_counter() - t0
        s = eng.stats
        emit(
            f"kvcache.{policy}.decode",
            dt / max(s.decode_steps, 1) * 1e6,
            (
                f"grows={s.grow_events} copied_MB={s.copied_bytes / 1e6:.2f} "
                f"alloc_MB={s.allocated_bytes / 1e6:.2f} compiles={s.compiles}"
            ),
        )


if __name__ == "__main__":
    main()
