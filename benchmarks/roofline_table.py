"""Render EXPERIMENTS.md §Roofline / §Dry-run tables from results/dryrun/*.json.

    PYTHONPATH=src:. python -m benchmarks.roofline_table [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh_tag: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def render(mesh_tag: str) -> str:
    rows = load(mesh_tag)
    out = [
        f"### Mesh {rows[0]['mesh'] if rows else mesh_tag} ({rows[0]['chips'] if rows else '?'} chips)",
        "",
        "| arch × shape | HBM/dev | compute | memory | collective | bound | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        name = f"{r['arch']} × {r['shape']}"
        if r["status"] == "skipped":
            out.append(f"| {name} | — | — | — | — | skip | — | {r['skip_reason'][:60]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {name} | ERROR | | | | | | {r['error'][:60]} |")
            continue
        t = r["roofline"]
        mf = r["model_flops"]
        # roofline fraction: useful model flops at peak vs the step lower bound
        ideal = mf["model_flops_per_device"] / 197e12
        frac = ideal / t["step_s_lower_bound"] if t["step_s_lower_bound"] else 0.0
        out.append(
            f"| {name} | {r['memory']['hbm_used_bytes'] / 1e9:.1f}GB"
            f"{'' if r['memory']['fits_16gb'] else ' ⚠'} "
            f"| {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | {t['bound']} "
            f"| {r['useful_flop_ratio']:.2f} | {frac:.1%} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=["pod16x16", "pod2x16x16"])
    args = ap.parse_args()
    tags = [args.mesh] if args.mesh else ["pod16x16", "pod2x16x16"]
    for tag in tags:
        if glob.glob(os.path.join(RESULTS, f"*__{tag}.json")):
            print(render(tag))
            print()


if __name__ == "__main__":
    main()
