"""Paper Fig. 4 columns 2–3 — choosing the optimal number of LFVectors.

Sweeps nblocks ∈ {8, 32, 128, 512}: time one duplication (grow + insert) and
read/write passes in both access modes (rw_g global binary search, rw_b
per-block).  Paper claims under test: few blocks → slow growth (no insert
parallelism); ≥32 blocks → rw_b faster and improving with block count;
rw_g pays the search overhead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ggarray as gg

from benchmarks.common import emit, timeit

TOTAL = 1 << 17  # elements in the array before the timed duplication


def main() -> None:
    for nblocks in (8, 32, 128, 512):
        per_block = TOTAL // nblocks
        arr = gg.init(nblocks, b0=max(per_block // 8, 1))
        arr = gg.ensure_capacity(arr, per_block)
        elems = jnp.ones((nblocks, per_block), jnp.float32)
        arr, _ = gg.push_back(arr, elems)

        # grow + insert one duplication (returns buckets: keep writes live)
        def dup(a=arr, e=elems):
            a2 = gg.ensure_capacity(a, e.shape[1])
            a2, _ = gg.push_back(a2, e)
            return a2.buckets

        emit(f"fig4.grow_insert.blocks{nblocks}", timeit(dup, repeats=3), f"n={TOTAL}->{2*TOTAL}")

        # rw_b: one fused pass per bucket, no search
        rw_b = jax.jit(lambda a: gg.map_elements(a, lambda x: x + 1.0).buckets)
        emit(f"fig4.rw_b.blocks{nblocks}", timeit(lambda: rw_b(arr), repeats=3), f"n={TOTAL}")

        # rw_g: global index + binary search per element
        idx = jnp.arange(TOTAL, dtype=jnp.int32)
        rw_g = jax.jit(lambda a, i: gg.write_global(a, i, gg.read_global(a, i) + 1.0).buckets)
        emit(f"fig4.rw_g.blocks{nblocks}", timeit(lambda: rw_g(arr, idx), repeats=3), f"n={TOTAL}")


if __name__ == "__main__":
    main()
