"""Benchmark harness utilities: timing + the run.py CSV contract.

CSV contract (assignment): every benchmark emits ``name,us_per_call,derived``
rows.  All wall-clock numbers here are CPU-relative — the claims under test
are *orderings and asymptotics* from the paper (atomic ≪ scan, GGArray r/w
slower than static, memory ≤ 2×), not absolute ms (EXPERIMENTS.md §Method).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

import jax

__all__ = ["timeit", "emit", "Row", "write_json", "write_metrics_json", "smoke_mode"]


def timeit(fn: Callable[[], Any], *, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time in µs (blocks on all returned jax arrays)."""
    def once() -> float:
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) * 1e6

    for _ in range(warmup):
        once()
    times = sorted(once() for _ in range(repeats))
    return times[len(times) // 2]


class Row:
    rows: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    Row.rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def smoke_mode() -> bool:
    """CI smoke runs: tiny sizes, same code paths (REPRO_BENCH_SMOKE=1)."""
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def write_json(short_name: str, rows: list[tuple[str, float, str]]) -> str:
    """Dump rows as ``BENCH_<short_name>.json`` (trajectory-tracking artifact).

    Output directory: ``REPRO_BENCH_DIR`` if set, else the current directory.
    Returns the path written.
    """
    path = os.path.join(
        os.environ.get("REPRO_BENCH_DIR", "."), f"BENCH_{short_name}.json"
    )
    payload = {
        "benchmark": short_name,
        "smoke": smoke_mode(),
        "rows": [
            {"name": n, "us_per_call": us, "derived": d} for n, us, d in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def write_metrics_json(short_name: str, snapshots: dict) -> str:
    """Dump ``obs`` registry snapshots as ``METRICS_<short_name>.json``.

    The telemetry companion to :func:`write_json`: while the rows carry the
    headline numbers, the metrics artifact preserves the full counter/gauge/
    histogram state of each engine the benchmark ran (keyed by a caller
    label), so regressions can be diagnosed — and gated
    (``check_regression.py --metrics``) — without rerunning the bench.
    Written next to ``BENCH_<short_name>.json`` (``REPRO_BENCH_DIR``).
    """
    path = os.path.join(
        os.environ.get("REPRO_BENCH_DIR", "."), f"METRICS_{short_name}.json"
    )
    payload = {
        "benchmark": short_name,
        "smoke": smoke_mode(),
        "engines": snapshots,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path
