"""Slab-arena serving benchmark — sequences/s and pool utilization.

Compares the paged-policy ``BatchEngine`` (one shared slab pool, continuous
batching, slab reclamation) against the per-array ``ggarray`` policy
(``Engine.generate``: every sequence owns a geometric bucket chain) on the
same ragged request fleet:

* ``seqs_per_s`` — completed sequences per wall second, end to end
  (admission prefill + decode + reclamation).  CPU-relative like every
  wall-clock number here: the claim under test is the *ordering*, not ms.
* ``pool_utilization`` — peak live tokens / peak pool capacity.  The arena's
  capacity bound (live + one slab per sequence, DESIGN.md §4) keeps this
  high under ragged loads, where the per-array policy pays each sequence's
  bucket-chain rounding (capacity ≈ next bucket boundary per sequence).
* ``capacity_ratio`` — allocated token slots / peak live tokens for each
  policy (the §V memory metric at fleet scale).

Usage: ``python benchmarks/bench_pool.py [--smoke]`` → rows on stdout +
``BENCH_pool.json`` (via benchmarks/run.py schema).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import Row, emit, smoke_mode, write_json
from repro.configs import reduced
from repro.models import transformer
from repro.serving import kvcache
from repro.serving.engine import BatchEngine, Engine


def _fleet(rng, nseqs, max_prompt):
    return [
        rng.integers(1, 200, rng.integers(1, max_prompt + 1)).tolist()
        for _ in range(nseqs)
    ]


def main() -> None:
    smoke = smoke_mode() or "--smoke" in sys.argv
    nseqs = 6 if smoke else 12
    max_prompt = 8 if smoke else 24
    new_tokens = 5 if smoke else 16
    max_batch = 4 if smoke else 8

    cfg = reduced("qwen2.5-3b", cache_b0=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = _fleet(rng, nseqs, max_prompt)

    # --- paged: shared pool, continuous batching --------------------------
    warm = BatchEngine(params, cfg, max_batch=max_batch)
    warm.run_all(prompts[:2], 2)  # compile cache warm-up
    be = BatchEngine(params, cfg, max_batch=max_batch)
    t0 = time.perf_counter()
    be.run_all(prompts, new_tokens)
    dt_paged = time.perf_counter() - t0
    peak_live = be.stats.peak_live_tokens
    util = peak_live / max(be.stats.peak_pool_tokens, 1)
    emit("pool_paged_seqs_per_s", dt_paged / nseqs * 1e6, f"{nseqs / dt_paged:.2f}/s")
    emit(
        "pool_paged_utilization",
        util * 100.0,
        f"peak_live={peak_live} pool={be.stats.peak_pool_tokens} "
        f"reused={be.stats.reused_slabs}",
    )
    emit(
        "pool_paged_capacity_ratio",
        be.stats.peak_pool_tokens / max(peak_live, 1),
        f"bound<2x+slab/seq grow_events={be.stats.pool_grow_events}",
    )

    # --- ggarray oracle: one bucket chain per sequence --------------------
    eng = Engine(params, cfg, policy="ggarray", max_len=256)
    eng.generate(prompts[:2], 2)  # warm-up
    eng = Engine(params, cfg, policy="ggarray", max_len=256)
    t0 = time.perf_counter()
    eng.generate(prompts, new_tokens)
    dt_gg = time.perf_counter() - t0
    # per-sequence bucket-chain capacity at end of generation
    lens = [len(p) + new_tokens for p in prompts]
    caps = [kvcache.cache_capacity(cfg, "ggarray", n) for n in lens]
    live = sum(lens)
    emit("pool_ggarray_seqs_per_s", dt_gg / nseqs * 1e6, f"{nseqs / dt_gg:.2f}/s")
    emit(
        "pool_ggarray_capacity_ratio",
        sum(caps) / live,
        f"live={live} allocated={sum(caps)} (per-array bucket rounding)",
    )
    emit(
        "pool_capacity_advantage",
        (sum(caps) / live) / max(be.stats.peak_pool_tokens / max(peak_live, 1), 1e-9),
        "arena slots per ggarray slot at equal live data",
    )


if __name__ == "__main__":
    start = len(Row.rows)
    print("name,us_per_call,derived")
    main()
    write_json("pool", Row.rows[start:])
