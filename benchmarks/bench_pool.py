"""Slab-arena serving benchmark — sequences/s, TTFT, and pool utilization.

Compares the paged-policy ``BatchEngine`` (one shared slab pool, bucketed
chunked-prefill admission, continuous batching, slab reclamation) against
(a) the same engine under monolithic admission and (b) the per-array
``ggarray`` policy (``Engine.generate``: every sequence owns a geometric
bucket chain) on the same ragged request fleet:

* ``seqs_per_s`` — completed sequences per wall second, end to end
  (admission prefill + decode + reclamation).  CPU-relative like every
  wall-clock number here: the claim under test is the *ordering*, not ms.
  Timed engines are fresh instances after a warm-up engine over the same
  fleet: the step jits are shared per-``ModelConfig`` (module-level
  factories), so the timed run measures steady-state serving, not tracing.
* ``ttft_ms`` — mean time-to-first-token over the fleet (chunked admission
  interleaves prefill chunks with decode, so long prompts no longer block
  the queue for their whole prefill).
* ``prefill_traces`` — distinct prefill compilations; bounded by the
  bucket table (O(log chunk)), not by distinct prompt lengths.
* ``pool_utilization`` / ``capacity_ratio`` — peak live tokens vs peak pool
  capacity (the §V memory metric at fleet scale); the arena's bound is
  live + one slab per sequence, the per-array policy pays bucket rounding.
* ``prefix_hit_rate`` / ``prefix_ttft_{hit,cold}_ms`` — the shared-prefix
  fleet (one system prompt, many tenants, ``prefix_cache=True``): hit rate
  must be 1.0 and the full-hit TTFT skips the entire chunked prefill
  (``check_regression.py`` gates both via ``METRICS_pool.json``).

Usage: ``python benchmarks/bench_pool.py [--smoke] [--profile]`` → rows on
stdout + ``BENCH_pool.json`` (benchmarks/run.py schema).  ``--profile``
additionally writes a ``jax.profiler`` trace of the timed paged run under
``REPRO_BENCH_DIR`` (default ``.``)/``profile_pool`` for the CI artifact.
"""
from __future__ import annotations

import contextlib
import os
import shutil
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, emit, smoke_mode, write_json, write_metrics_json
from repro.configs import reduced
from repro.models import transformer
from repro.pool.extents import grow_extents, grow_flat, init_extent_pool, plan_extents
from repro.serving import kvcache
from repro.serving.engine import BatchEngine, Engine


def _fleet(rng, nseqs, max_prompt):
    return [
        rng.integers(1, 200, rng.integers(1, max_prompt + 1)).tolist()
        for _ in range(nseqs)
    ]


def _serve(params, cfg, prompts, new_tokens, max_batch, admission, grow_chunk=1):
    """One fresh engine over the fleet → (engine, wall seconds, ttfts)."""
    be = BatchEngine(
        params, cfg, max_batch=max_batch, admission=admission, grow_chunk=grow_chunk
    )
    rids = [be.submit(p, new_tokens) for p in prompts]
    t0 = time.perf_counter()
    be.run()
    dt = time.perf_counter() - t0
    ttfts = [be._requests[r].ttft for r in rids]
    return be, dt, ttfts


def _grow_sweep(schedule: str, waves: int, slab_size: int):
    """Per-grow latency of doubling demand ``waves`` times from one slab.

    ``"flat"`` is the realloc pool (alloc + memcpy of the live prefix);
    the extent schedules allocate one fresh extent and copy nothing.
    Returns (p95 µs per grow step, total live bytes memcpy'd).
    """
    pool = init_extent_pool(1, slab_size, (), jnp.float32)
    times, copied = [], 0
    for _ in range(waves):
        short = pool.n_slabs  # double the fleet's demand each wave
        t0 = time.perf_counter()
        if schedule == "flat":
            copied += pool.extents[0].size * pool.dtype.itemsize
            pool = grow_flat(pool, short)
        else:
            pool = grow_extents(
                pool, plan_extents(pool.extent_sizes, short, schedule)
            )
        jax.block_until_ready(pool.extents[-1])
        times.append(time.perf_counter() - t0)
    return float(np.quantile(times, 0.95)) * 1e6, copied


def main() -> None:
    smoke = smoke_mode() or "--smoke" in sys.argv
    profile = "--profile" in sys.argv
    nseqs = 6 if smoke else 12
    # past attention_chunk=32 so the chunked path really chunks
    max_prompt = 40 if smoke else 70
    new_tokens = 5 if smoke else 16
    max_batch = 4 if smoke else 8

    cfg = reduced("qwen2.5-3b", cache_b0=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = _fleet(rng, nseqs, max_prompt)

    # --- paged: shared pool, chunked admission ----------------------------
    # The warm-up engine compiles every (bucket, first) prefill trace and
    # the decode trace into the shared per-config jit cache; the timed
    # engine reuses them all (tests/serving/test_trace_count.py pins this).
    _serve(params, cfg, prompts, new_tokens, max_batch, "chunked")
    prof_dir = os.path.join(os.environ.get("REPRO_BENCH_DIR", "."), "profile_pool")
    prof = jax.profiler.trace(prof_dir) if profile else contextlib.nullcontext()
    try:
        with prof:
            be, dt_paged, ttfts = _serve(
                params, cfg, prompts, new_tokens, max_batch, "chunked"
            )
    except BaseException:
        # a run that dies mid-trace must not leave a half-written trace dir
        # behind — CI would upload it as if it were a real profile artifact
        if profile:
            shutil.rmtree(prof_dir, ignore_errors=True)
        raise
    peak_live = be.stats.peak_live_tokens
    util = peak_live / max(be.stats.peak_pool_tokens, 1)
    emit(
        "pool_paged_seqs_per_s",
        dt_paged / nseqs * 1e6,
        f"{nseqs / dt_paged:.2f}/s chunks={be.stats.prefill_chunks}",
    )
    emit(
        "pool_paged_ttft_ms",
        float(np.mean(ttfts)) * 1e6,
        f"mean={np.mean(ttfts) * 1e3:.1f}ms p95={np.quantile(ttfts, 0.95) * 1e3:.1f}ms",
    )
    emit(
        "pool_paged_prefill_traces",
        float(be.stats.prefill_traces),
        f"buckets={be.sched.buckets} distinct_lengths={len({len(p) for p in prompts})}",
    )
    emit(
        "pool_paged_utilization",
        util * 100.0,
        f"peak_live={peak_live} pool={be.stats.peak_pool_tokens} "
        f"reused={be.stats.reused_slabs}",
    )
    emit(
        "pool_paged_capacity_ratio",
        be.stats.peak_pool_tokens / max(peak_live, 1),
        f"bound<2x+slab/seq grow_events={be.stats.pool_grow_events}",
    )

    # --- extent growth schedules: zero-copy pool growth (DESIGN.md §8) ----
    # Grow-step microbench: p95 latency of one growth under doubling demand,
    # realloc pool ("flat": alloc + full-pool memcpy) vs extent appends.
    grow_waves = 8 if smoke else 12
    grow_slab = 1024 if smoke else 4096
    grow_p95 = {}
    for sched in ("flat", "doubling", "tz"):
        p95_us, copied = _grow_sweep(sched, grow_waves, grow_slab)
        grow_p95[sched] = p95_us
        emit(
            f"pool_grow_p95_us_{sched}",
            p95_us,
            f"{grow_waves} doublings slab={grow_slab}f32 copied={copied}B",
        )
        emit(
            f"pool_grow_copied_bytes_{sched}",
            float(copied),
            "live bytes memcpy'd by growth (extent schedules must be 0)",
        )
    # Steady-state serving under each extent schedule: same fleet, growth
    # retraces bounded by the extent count instead of realloc copies.
    for sched in ("doubling", "tz"):
        _serve(params, cfg, prompts, new_tokens, max_batch, "chunked", sched)
        bs, dt_s, _ = _serve(
            params, cfg, prompts, new_tokens, max_batch, "chunked", sched
        )
        nx = sum(1 for s in bs._extent_sizes if s > 0)
        emit(
            f"pool_paged_seqs_per_s_{sched}",
            dt_s / nseqs * 1e6,
            f"{nseqs / dt_s:.2f}/s vs_flat={dt_paged / dt_s:.2f} extents={nx} "
            f"grow_events={bs.stats.pool_grow_events} "
            f"copied={bs.stats.pool_copied_bytes}B",
        )
        emit(
            f"pool_serve_copied_bytes_{sched}",
            float(bs.stats.pool_copied_bytes),
            f"engine pool bytes memcpy'd end-to-end (flat engine: "
            f"{be.stats.pool_copied_bytes}B)",
        )

    # --- paged, monolithic admission: the pre-chunking scheduler ----------
    _serve(params, cfg, prompts, new_tokens, max_batch, "monolithic")
    bm, dt_mono, ttfts_m = _serve(
        params, cfg, prompts, new_tokens, max_batch, "monolithic"
    )
    emit(
        "pool_monolithic_seqs_per_s",
        dt_mono / nseqs * 1e6,
        f"{nseqs / dt_mono:.2f}/s chunked_speedup={dt_mono / dt_paged:.2f}",
    )
    emit(
        "pool_monolithic_ttft_ms",
        float(np.mean(ttfts_m)) * 1e6,
        f"chunked_ttft_ratio={np.mean(ttfts) / max(np.mean(ttfts_m), 1e-12):.2f}",
    )

    # --- shared-prefix fleet: copy-on-write prefix caching (§10) ----------
    # One system prompt, many tenants: the first request pays the chunked
    # prefill and publishes its slabs; every later identical prompt admits
    # with zero prefill chunks (full hit) and aliases the cached slabs.
    fleet_n = 8 if smoke else 32
    sys_prompt = rng.integers(1, 200, 36).tolist()  # 36 % slab_tokens == 0
    bp = BatchEngine(params, cfg, max_batch=max_batch, prefix_cache=True)
    r_cold = bp.submit(list(sys_prompt), new_tokens)
    bp.run()
    ttft_cold = bp._requests[r_cold].ttft
    chunks_cold = bp.stats.prefill_chunks
    hits0 = bp.stats.prefix_hits
    for _ in range(fleet_n):
        bp.submit(list(sys_prompt), new_tokens)
    bp.run()
    hit_rate = (bp.stats.prefix_hits - hits0) / fleet_n
    fleet_chunks = bp.stats.prefill_chunks - chunks_cold
    # apples-to-apples TTFT: one more hit request alone (no queue wait),
    # against the cold request that ran alone through the same jit cache
    r_hit = bp.submit(list(sys_prompt), new_tokens)
    bp.run()
    ttft_hit = bp._requests[r_hit].ttft
    ttft_hit_ratio = ttft_hit / max(ttft_cold, 1e-12)
    emit(
        "pool_prefix_hit_rate",
        hit_rate * 100.0,
        f"{fleet_n} shared-prefix requests, {fleet_chunks} prefill chunks, "
        f"cow={bp.stats.cow_copies} live_slabs={bp.alloc.n_slabs}",
    )
    emit(
        "pool_prefix_ttft_cold_ms",
        ttft_cold * 1e6,
        "first request: full chunked prefill, publishes the prompt slabs",
    )
    emit(
        "pool_prefix_ttft_hit_ms",
        ttft_hit * 1e6,
        f"fully cached: first token from the first decode step, "
        f"hit/cold={ttft_hit_ratio:.2f}",
    )

    # --- ggarray oracle: one bucket chain per sequence --------------------
    eng = Engine(params, cfg, policy="ggarray", max_len=256)
    eng.generate(prompts, new_tokens)  # warm-up
    eng = Engine(params, cfg, policy="ggarray", max_len=256)
    t0 = time.perf_counter()
    eng.generate(prompts, new_tokens)
    dt_gg = time.perf_counter() - t0
    # per-sequence bucket-chain capacity at end of generation
    lens = [len(p) + new_tokens for p in prompts]
    caps = [kvcache.cache_capacity(cfg, "ggarray", n) for n in lens]
    live = sum(lens)
    emit(
        "pool_ggarray_seqs_per_s",
        dt_gg / nseqs * 1e6,
        f"{nseqs / dt_gg:.2f}/s paged_vs_ggarray={dt_gg / dt_paged:.2f}",
    )
    emit(
        "pool_ggarray_capacity_ratio",
        sum(caps) / live,
        f"live={live} allocated={sum(caps)} (per-array bucket rounding)",
    )
    emit(
        "pool_capacity_advantage",
        (sum(caps) / live) / max(be.stats.peak_pool_tokens / max(peak_live, 1), 1e-9),
        "arena slots per ggarray slot at equal live data",
    )

    # --- device counter plane: see inside the pool (DESIGN.md §9.x) -------
    # A separate instrumented engine over the same fleet (the timed engines
    # stay uninstrumented so the wall-clocks are untouched); its in-kernel
    # counters yield the geometry metrics check_regression.py ratchets.
    bi = BatchEngine(params, cfg, max_batch=max_batch, instrument=True)
    for p in prompts:
        bi.submit(p, new_tokens)
    bi.run()
    dev = bi.drain_device_counters()
    decode_tokens = nseqs * new_tokens
    attend_lanes = max(dev["paged_attend.lanes"], 1.0)
    append_lanes = max(dev["slab_append.lanes"], 1.0)
    masked_waste = dev["paged_attend.masked_lanes"] / attend_lanes
    tiles_per_token = dev["paged_attend.tiles"] / max(decode_tokens, 1)
    occupancy = dev["slab_append.active_lanes"] / append_lanes
    emit(
        "pool_device_masked_lane_waste_pct",
        masked_waste * 100.0,
        f"attend lanes past kv_len / lanes walked "
        f"({dev['paged_attend.masked_lanes']:.0f}/{attend_lanes:.0f})",
    )
    emit(
        "pool_device_tiles_per_token",
        tiles_per_token,
        f"attend KV tiles per decoded token over {decode_tokens} tokens",
    )
    emit(
        "pool_device_append_occupancy_pct",
        occupancy * 100.0,
        f"slab-append active/total lanes "
        f"({dev['slab_append.active_lanes']:.0f}/{append_lanes:.0f})",
    )

    # --- telemetry artifact: full registry snapshots of the timed engines -
    # check_regression.py --metrics gates TTFT p95 (chunked/monolithic),
    # pool utilization, and the device-counter waste ratchet from this file;
    # the rest is for diagnosis.
    write_metrics_json(
        "pool",
        {
            "chunked": be.obs.snapshot(),
            "monolithic": bm.obs.snapshot(),
            "device": {
                "counters": dev,
                "masked_lane_waste": masked_waste,
                "tiles_per_token": tiles_per_token,
                "append_occupancy": occupancy,
                "decode_tokens": decode_tokens,
            },
            "prefix": {
                "hit_rate": hit_rate,
                "ttft_cold_ms": ttft_cold * 1e3,
                "ttft_hit_ms": ttft_hit * 1e3,
                "ttft_hit_ratio": ttft_hit_ratio,
                "fleet": fleet_n,
                "suffix_chunks": fleet_chunks,
                "cow_copies": bp.stats.cow_copies,
                "live_slabs": bp.alloc.n_slabs,
                "metrics": bp.obs.snapshot(),
            },
        },
    )


if __name__ == "__main__":
    start = len(Row.rows)
    print("name,us_per_call,derived")
    main()
    write_json("pool", Row.rows[start:])
