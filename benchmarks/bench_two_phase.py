"""Paper Fig. 6 — two-phase application: GGArray speedup over memMap.

Grow phase: waves of insertions (size doubles per wave).  Work phase: the
paper's kernel (+1, 30×) applied W ∈ {1, 10, 100, 1000} times.  GGArray path
inserts into buckets then **flattens once** and works on the flat array; the
memMap path works directly on its contiguous buffer but pays host-resize on
every growth.  Claim under test: the dynamic structure's overhead is
amortized as W grows (speedup → ~1 and the crossover is visible).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import ggarray as gg

from benchmarks.common import emit, timeit

START = 1 << 12
WAVES = 4
NBLOCKS = 32


def _work_once(x):
    for _ in range(30):
        x = x + 1.0
    return x


def _ggarray_run(W: int) -> None:
    per0 = START // NBLOCKS
    arr = gg.init(NBLOCKS, b0=max(per0 // 2, 1))
    size = START
    for wave in range(WAVES):
        per = size // NBLOCKS
        arr = gg.ensure_capacity(arr, per)
        arr, _ = gg.push_back(arr, jnp.ones((NBLOCKS, per), jnp.float32))
        size *= 2
    flat, n = gg.flatten(arr)
    work = jax.jit(lambda x: jax.lax.fori_loop(0, W, lambda _, y: _work_once(y), x))
    jax.block_until_ready(work(flat))


def _memmap_run(W: int) -> None:
    semi = bl.SemiStaticArray.create(START)
    size = START
    for wave in range(WAVES):
        semi.push_back(jnp.ones((size,), jnp.float32))  # doubles + copies
        size *= 2
    work = jax.jit(lambda x: jax.lax.fori_loop(0, W, lambda _, y: _work_once(y), x))
    jax.block_until_ready(work(semi.arr.data))


def main() -> None:
    for W in (1, 10, 100, 1000):
        t_gg = timeit(lambda: _ggarray_run(W), repeats=3, warmup=1)
        t_mm = timeit(lambda: _memmap_run(W), repeats=3, warmup=1)
        emit(f"fig6.two_phase.W{W}", t_gg, f"speedup_vs_memMap={t_mm / t_gg:.3f}")


if __name__ == "__main__":
    main()
