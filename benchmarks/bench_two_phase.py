"""Paper Fig. 6 + the two-phase runtime: grow, freeze, and frozen-read costs.

Four measurement groups:

``fig6.two_phase.W*``      the paper's original claim — GGArray grow+flatten
                           then W static work kernels, vs the memMap baseline.
``grow.*``                 growth-phase push_back throughput (elems/s) for the
                           pipeline vs the pre-allocated static and doubling
                           semi-static baselines in ``core/baselines.py``.
``freeze.*``               freeze (flatten) latency of the linear-time
                           segmented-gather kernel vs the legacy O(n²)
                           dispatch-matmul kernel vs the pure-jnp core
                           scatter, per array size.  The acceptance claim:
                           segmented < dispatch at the largest benched size.
``frozen_read.*``          static-phase read bandwidth: contiguous frozen
                           reads vs the GGArray bucket-walk ``read_global``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import ggarray as gg
from repro.kernels.flatten import ops as flatten_ops
from repro.runtime import TwoPhasePipeline

from benchmarks.common import emit, timeit

START = 1 << 12
WAVES = 4
NBLOCKS = 32
FREEZE_SIZES = (1 << 10, 1 << 12, 1 << 14)  # elements, largest decides the claim


def _work_once(x):
    for _ in range(30):
        x = x + 1.0
    return x


# --------------------------------------------------------------------------
# Fig. 6 — original two-phase application comparison.
# --------------------------------------------------------------------------

def _ggarray_run(W: int) -> None:
    per0 = START // NBLOCKS
    pipe = TwoPhasePipeline(NBLOCKS, b0=max(per0 // 2, 1))
    size = START
    for wave in range(WAVES):
        per = size // NBLOCKS
        pipe.append(jnp.ones((NBLOCKS, per), jnp.float32))
        size *= 2
    frozen = pipe.freeze()
    work = jax.jit(lambda x: jax.lax.fori_loop(0, W, lambda _, y: _work_once(y), x))
    jax.block_until_ready(work(frozen.data))


def _memmap_run(W: int) -> None:
    semi = bl.SemiStaticArray.create(START)
    size = START
    for wave in range(WAVES):
        semi.push_back(jnp.ones((size,), jnp.float32))  # doubles + copies
        size *= 2
    work = jax.jit(lambda x: jax.lax.fori_loop(0, W, lambda _, y: _work_once(y), x))
    jax.block_until_ready(work(semi.arr.data))


def bench_fig6() -> None:
    for W in (1, 10, 100, 1000):
        t_gg = timeit(lambda: _ggarray_run(W), repeats=3, warmup=1)
        t_mm = timeit(lambda: _memmap_run(W), repeats=3, warmup=1)
        emit(f"fig6.two_phase.W{W}", t_gg, f"speedup_vs_memMap={t_mm / t_gg:.3f}")


# --------------------------------------------------------------------------
# Growth-phase throughput.
# --------------------------------------------------------------------------

def bench_grow() -> None:
    n = 1 << 14
    per = n // NBLOCKS
    wave = jnp.ones((NBLOCKS, per), jnp.float32)
    flat_wave = jnp.ones((n,), jnp.float32)

    def grow_pipeline():
        pipe = TwoPhasePipeline(NBLOCKS, b0=max(per // 2, 1))
        for _ in range(4):
            pipe.append(wave)
        return pipe.array.buckets

    def grow_static():
        arr = bl.static_init(8 * n)  # worst-case pre-allocation
        for _ in range(4):
            arr, _ = bl.static_push_back(arr, flat_wave)
        return arr.data

    def grow_semistatic():
        semi = bl.SemiStaticArray.create(n)
        for _ in range(4):
            semi.push_back(flat_wave)  # doubles + copies past capacity
        return semi.arr.data

    total = 4 * n
    for name, fn in (
        ("pipeline", grow_pipeline),
        ("static", grow_static),
        ("semistatic", grow_semistatic),
    ):
        us = timeit(fn, repeats=3, warmup=1)
        emit(f"grow.{name}", us, f"melems_per_s={total / us:.2f}")


# --------------------------------------------------------------------------
# Freeze latency: segmented gather vs dispatch matmul vs core scatter.
# --------------------------------------------------------------------------

def _filled(n: int) -> gg.GGArray:
    per = n // NBLOCKS
    arr = gg.init(NBLOCKS, b0=max(per // 2, 1))
    arr = gg.ensure_capacity(arr, per)
    arr, _ = gg.push_back(arr, jnp.ones((NBLOCKS, per), jnp.float32))
    return arr

def bench_freeze() -> None:
    for n in FREEZE_SIZES:
        arr = _filled(n)
        t_seg = timeit(
            lambda: flatten_ops.flatten_segmented(arr.buckets, arr.sizes, arr.b0),
            repeats=3, warmup=1,
        )
        t_disp = timeit(
            lambda: flatten_ops.flatten_dispatch(arr.buckets, arr.sizes, arr.b0),
            repeats=3, warmup=1,
        )
        t_core = timeit(lambda: gg.flatten(arr), repeats=3, warmup=1)
        emit(f"freeze.segmented.n{n}", t_seg,
             f"speedup_vs_dispatch={t_disp / t_seg:.2f}")
        emit(f"freeze.dispatch.n{n}", t_disp, "")
        emit(f"freeze.core.n{n}", t_core, "")


# --------------------------------------------------------------------------
# Frozen-read bandwidth: contiguous gather vs the bucket walk.
# --------------------------------------------------------------------------

def bench_frozen_read() -> None:
    n = 1 << 14
    pipe = TwoPhasePipeline.from_ggarray(_filled(n))
    frozen = pipe.freeze()
    arr = pipe.array
    idx = jnp.arange(n, dtype=jnp.int32)
    read_flat = jax.jit(lambda fz, i: fz.data[i])
    read_walk = jax.jit(gg.read_global)
    t_flat = timeit(lambda: read_flat(frozen, idx), repeats=5, warmup=2)
    t_walk = timeit(lambda: read_walk(arr, idx), repeats=5, warmup=2)
    bytes_moved = n * 4
    emit("frozen_read.flat", t_flat,
         f"gb_per_s={bytes_moved / (t_flat * 1e-6) / 1e9:.3f}")
    emit("frozen_read.bucket_walk", t_walk,
         f"slowdown_vs_flat={t_walk / t_flat:.2f}")


def main() -> None:
    bench_fig6()
    bench_grow()
    bench_freeze()
    bench_frozen_read()


if __name__ == "__main__":
    main()
