"""Paper Table II / Fig. 5 — grow / insert / read-write across structures.

Structures: static (pre-allocated), semistatic-realloc (doubling + copy),
semistatic-memMap (doubling, allocation timed, copy excluded — the CUDA VMM
remap has no XLA analog, see core/baselines.py), GGArray32, GGArray512.
The read/write op is the paper's kernel: add +1, 30 times, to every element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import ggarray as gg
from repro.configs.ggarray_demo import CONFIG as DEMO

from benchmarks.common import emit, timeit

N = 1 << 17  # scaled stand-in for the paper's 5.12e8 final size
REPEATS = DEMO.rw_op_repeats


def _work(x):
    for _ in range(REPEATS):
        x = x + 1.0
    return x


def main() -> None:
    elems_flat = jnp.ones((N,), jnp.float32)

    # ---- static ----
    st = bl.static_init(2 * N)
    st, _ = bl.static_push_back(st, elems_flat)
    emit("table2.static.grow", 0.0, "no grow operation exists")
    ins = jax.jit(lambda a, e: bl.static_push_back(a, e)[0].data)
    emit("table2.static.insert", timeit(lambda: ins(st, elems_flat), repeats=3), f"n={N}")
    rw = jax.jit(lambda a: _work(a.data))
    emit("table2.static.rw", timeit(lambda: rw(st), repeats=3), f"n={N} x{REPEATS}")

    # ---- semistatic: realloc (timed copy) vs memMap (alloc only) ----
    semi = bl.SemiStaticArray.create(N)
    semi.push_back(elems_flat)
    emit("table2.semistatic_realloc.grow", timeit(lambda: semi.grow_alloc_only().at[:N].set(semi.arr.data), repeats=3), "alloc+copy")
    emit("table2.memMap.grow", timeit(lambda: semi.grow_alloc_only() + 0.0, repeats=3), "alloc only (VMM remap analog)")
    semi.ensure_capacity(N)
    emit("table2.memMap.insert", timeit(lambda: ins(semi.arr, elems_flat), repeats=3), f"n={N}")
    emit("table2.memMap.rw", timeit(lambda: rw(semi.arr), repeats=3), f"n={N} x{REPEATS}")

    # ---- GGArray 32 / 512 blocks ----
    for nblocks in (32, 512):
        per_block = N // nblocks
        arr = gg.init(nblocks, b0=max(per_block // 8, 1))
        arr = gg.ensure_capacity(arr, per_block)
        arr, _ = gg.push_back(arr, jnp.ones((nblocks, per_block), jnp.float32))
        emit(
            f"table2.ggarray{nblocks}.grow",
            timeit(lambda a=arr: gg.grow(a).buckets[-1], repeats=3),
            "bucket alloc, copy-free",
        )
        arr2 = gg.grow(arr)
        ins_g = jax.jit(lambda a, e: gg.push_back(a, e)[0].buckets)
        e2 = jnp.ones((nblocks, per_block), jnp.float32)
        emit(f"table2.ggarray{nblocks}.insert", timeit(lambda: ins_g(arr2, e2), repeats=3), f"n={N}")
        rw_b = jax.jit(lambda a: gg.map_elements(a, _work).buckets)
        emit(f"table2.ggarray{nblocks}.rw", timeit(lambda: rw_b(arr2), repeats=3), f"n={N} x{REPEATS} (rw_b)")


if __name__ == "__main__":
    main()
