"""CI gate: paged-engine throughput must not regress vs the committed baseline.

Reads ``BENCH_pool.json`` (the smoke artifact the CI job just produced),
computes the paged/ggarray sequences-per-second ratio — both engines run on
the same machine in the same process, so the ratio self-normalizes away the
runner's absolute speed — and fails (exit 1) if it has dropped more than
``--tolerance`` (default 20%) below the committed baseline ratio in
``benchmarks/baselines/pool_smoke.json``.  Two floors are enforced:

* relative: ``ratio ≥ (1 − tolerance) · baseline_ratio`` — catches a
  scheduler/jit-cache regression even while the ratio is comfortably > 1;
* absolute: ``ratio ≥ 0.8`` — the ISSUE 6 acceptance bound (the paged
  engine must serve at least 0.8× ggarray's seqs/s, up from 0.21×).

``--update`` rewrites the baseline from the current artifact (a deliberate,
reviewed re-tune — commit the diff).

Usage::

    python benchmarks/check_regression.py [--bench BENCH_pool.json]
        [--baseline benchmarks/baselines/pool_smoke.json]
        [--tolerance 0.2] [--update]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ABSOLUTE_FLOOR = 0.8  # ISSUE 6 acceptance: paged ≥ 0.8× ggarray seqs/s


def _rows(path: str) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r["us_per_call"] for r in payload["rows"]}


def main(argv: list[str] | None = None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="BENCH_pool.json")
    ap.add_argument(
        "--baseline", default=os.path.join(here, "baselines", "pool_smoke.json")
    )
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--update", action="store_true")
    args = ap.parse_args(argv)

    rows = _rows(args.bench)
    try:
        us_paged = rows["pool_paged_seqs_per_s"]
        us_gg = rows["pool_ggarray_seqs_per_s"]
    except KeyError as e:
        print(f"check_regression: {args.bench} is missing row {e}", file=sys.stderr)
        return 1
    # rows record µs per sequence, so throughput ratio inverts them
    ratio = us_gg / us_paged

    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(
                {
                    "metric": "paged_vs_ggarray_seqs_per_s_ratio",
                    "value": round(ratio, 3),
                    "source": "benchmarks/bench_pool.py --smoke",
                },
                f,
                indent=2,
            )
            f.write("\n")
        print(f"check_regression: baseline updated to {ratio:.3f}")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)["value"]
    floor = (1.0 - args.tolerance) * base
    verdict = (
        f"paged/ggarray seqs/s ratio {ratio:.3f} "
        f"(baseline {base:.3f}, relative floor {floor:.3f}, "
        f"absolute floor {ABSOLUTE_FLOOR})"
    )
    if ratio < ABSOLUTE_FLOOR:
        print(f"check_regression: FAIL — below acceptance bound: {verdict}")
        return 1
    if ratio < floor:
        print(f"check_regression: FAIL — >{args.tolerance:.0%} regression: {verdict}")
        return 1
    print(f"check_regression: OK — {verdict}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
