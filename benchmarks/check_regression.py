"""CI gate: paged-engine throughput must not regress vs the committed baseline.

Reads ``BENCH_pool.json`` (the smoke artifact the CI job just produced),
computes the paged/ggarray sequences-per-second ratio — both engines run on
the same machine in the same process, so the ratio self-normalizes away the
runner's absolute speed — and fails (exit 1) if it has dropped more than
``--tolerance`` (default 20%) below the committed baseline ratio in
``benchmarks/baselines/pool_smoke.json``.  Two floors are enforced:

* relative: ``ratio ≥ (1 − tolerance) · baseline_ratio`` — catches a
  scheduler/jit-cache regression even while the ratio is comfortably > 1;
* absolute: ``ratio ≥ 0.8`` — the ISSUE 6 acceptance bound (the paged
  engine must serve at least 0.8× ggarray's seqs/s, up from 0.21×).

The extent pool's zero-copy growth contract (ISSUE 7, DESIGN.md §8) is
gated too:

* hard: ``pool_grow_copied_bytes_{doubling,tz}`` and
  ``pool_serve_copied_bytes_{doubling,tz}`` must be **exactly 0** — a
  reintroduced full-pool copy fails CI deterministically (a missing row
  fails as well, so the gate cannot be dodged by dropping the bench);
* relative: the grow-step p95 advantage ``flat / max(extent)`` is a
  same-process self-normalizing ratio gated against the committed
  ``grow_step`` baseline with the same ``--tolerance``.

The telemetry artifact (``METRICS_pool.json``, registry snapshots of the
timed engines — ISSUE 8) supplies two more self-normalizing gates:

* ``ttft_p95_ratio`` — chunked ``serve.ttft_ms`` p95 over monolithic p95,
  both from the same process; chunked admission exists to cut tail TTFT,
  so this ratio drifting *up* past
  ``max((1 + tolerance) · baseline, 0.5)`` fails (the absolute ceiling
  absorbs timer jitter on a tiny baseline — the chunked tail p95 is tens
  of ms in smoke mode — while still catching the real failure mode of
  chunking ceasing to help, which drives the ratio toward 1);
* ``utilization`` — chunked peak live tokens over peak pool capacity
  (gauge high-water marks); dropping below
  ``(1 − tolerance) · baseline`` means the pool got sparser.

The shared-prefix fleet (copy-on-write prefix caching, DESIGN.md §10)
contributes two more gates from the metrics artifact's ``prefix`` section:

* hard: ``hit_rate`` must be **exactly 1.0** — every identical prompt after
  the first must alias the cached slabs (a missing section fails too, so
  the gate cannot be dodged by dropping the scenario);
* relative: ``ttft_hit_ratio`` (full-hit TTFT over cold TTFT, same process,
  same jit cache) is a ceiling gate like ``ttft_p95_ratio`` — a fully
  cached prompt's first token comes from one decode step instead of the
  whole chunked prefill, so this ratio drifting up toward 1 means the
  cache stopped skipping prefill.

A missing metrics file or metric key fails, same as a missing bench row.

``--update`` rewrites the baseline from the current artifacts (a
deliberate, reviewed re-tune — commit the diff).

Usage::

    python benchmarks/check_regression.py [--bench BENCH_pool.json]
        [--metrics METRICS_pool.json]
        [--baseline benchmarks/baselines/pool_smoke.json]
        [--tolerance 0.2] [--update]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ABSOLUTE_FLOOR = 0.8  # ISSUE 6 acceptance: paged ≥ 0.8× ggarray seqs/s
TTFT_ABS_CEILING = 0.5  # chunked TTFT p95 must stay < 0.5× monolithic's
HIT_TTFT_ABS_CEILING = 0.5  # full-hit TTFT must stay < 0.5× cold TTFT


def _rows(path: str) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r["us_per_call"] for r in payload["rows"]}


def _telemetry(path: str) -> tuple[float, float, float, float, float] | str:
    """(ttft_p95_ratio, utilization, prefix_hit_rate, ttft_hit_ratio,
    masked_lane_waste) from METRICS_pool.json, or an error string."""
    try:
        with open(path) as f:
            engines = json.load(f)["engines"]
        chunked, mono = engines["chunked"], engines["monolithic"]
        ttft_ratio = chunked["histograms"]["serve.ttft_ms"]["p95"] / max(
            mono["histograms"]["serve.ttft_ms"]["p95"], 1e-12
        )
        util = chunked["gauges"]["pool.live_tokens"]["hwm"] / max(
            chunked["gauges"]["pool.capacity_tokens"]["hwm"], 1
        )
        prefix = engines["prefix"]
        hit_rate = float(prefix["hit_rate"])
        hit_ttft_ratio = float(prefix["ttft_hit_ratio"])
        # device counter plane (DESIGN.md §9.x): attend masked-lane waste —
        # a missing section fails, so the gate cannot be dodged
        masked_waste = float(engines["device"]["masked_lane_waste"])
    except (OSError, KeyError, TypeError) as e:
        return f"{path}: {type(e).__name__}: {e}"
    return ttft_ratio, util, hit_rate, hit_ttft_ratio, masked_waste


def main(argv: list[str] | None = None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="BENCH_pool.json")
    ap.add_argument("--metrics", default="METRICS_pool.json")
    ap.add_argument(
        "--baseline", default=os.path.join(here, "baselines", "pool_smoke.json")
    )
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--update", action="store_true")
    args = ap.parse_args(argv)

    rows = _rows(args.bench)
    try:
        us_paged = rows["pool_paged_seqs_per_s"]
        us_gg = rows["pool_ggarray_seqs_per_s"]
    except KeyError as e:
        print(f"check_regression: {args.bench} is missing row {e}", file=sys.stderr)
        return 1
    # rows record µs per sequence, so throughput ratio inverts them
    ratio = us_gg / us_paged

    # zero-copy growth contract: every copied-bytes row must exist and be 0
    copy_rows = [
        f"pool_{kind}_copied_bytes_{sched}"
        for kind in ("grow", "serve")
        for sched in ("doubling", "tz")
    ]
    missing = [r for r in copy_rows if r not in rows]
    if missing:
        print(
            f"check_regression: {args.bench} is missing zero-copy gate "
            f"row(s) {missing}",
            file=sys.stderr,
        )
        return 1
    grow_ratio = None
    try:
        grow_ratio = rows["pool_grow_p95_us_flat"] / max(
            rows["pool_grow_p95_us_doubling"], rows["pool_grow_p95_us_tz"], 1e-12
        )
    except KeyError as e:
        print(f"check_regression: {args.bench} is missing row {e}", file=sys.stderr)
        return 1

    telemetry = _telemetry(args.metrics)
    if isinstance(telemetry, str):
        print(f"check_regression: telemetry gate unreadable — {telemetry}",
              file=sys.stderr)
        return 1
    ttft_ratio, util, hit_rate, hit_ttft_ratio, masked_waste = telemetry

    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(
                {
                    "metric": "paged_vs_ggarray_seqs_per_s_ratio",
                    "value": round(ratio, 3),
                    "grow_step": {
                        "metric": "flat_over_extent_grow_p95_ratio",
                        "value": round(grow_ratio, 3),
                    },
                    "telemetry": {
                        "ttft_p95_ratio": round(ttft_ratio, 3),
                        "utilization": round(util, 3),
                        "source": "METRICS_pool.json",
                    },
                    "prefix": {
                        "hit_rate": round(hit_rate, 3),
                        "ttft_hit_ratio": round(hit_ttft_ratio, 3),
                        "source": "METRICS_pool.json",
                    },
                    "device": {
                        "masked_lane_waste": round(masked_waste, 4),
                        "source": "METRICS_pool.json",
                    },
                    "source": "benchmarks/bench_pool.py --smoke",
                },
                f,
                indent=2,
            )
            f.write("\n")
        print(
            f"check_regression: baseline updated to {ratio:.3f} "
            f"(grow-step ratio {grow_ratio:.3f}, ttft p95 ratio "
            f"{ttft_ratio:.3f}, utilization {util:.3f}, prefix hit rate "
            f"{hit_rate:.3f}, hit/cold ttft {hit_ttft_ratio:.3f}, "
            f"masked-lane waste {masked_waste:.4f})"
        )
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    base = baseline["value"]
    floor = (1.0 - args.tolerance) * base
    verdict = (
        f"paged/ggarray seqs/s ratio {ratio:.3f} "
        f"(baseline {base:.3f}, relative floor {floor:.3f}, "
        f"absolute floor {ABSOLUTE_FLOOR})"
    )
    if ratio < ABSOLUTE_FLOOR:
        print(f"check_regression: FAIL — below acceptance bound: {verdict}")
        return 1
    if ratio < floor:
        print(f"check_regression: FAIL — >{args.tolerance:.0%} regression: {verdict}")
        return 1

    copied = {r: rows[r] for r in copy_rows if rows[r] != 0.0}
    if copied:
        print(
            "check_regression: FAIL — extent growth copied pool bytes "
            f"(must be 0): {copied}"
        )
        return 1
    grow_verdict = f"grow-step p95 flat/extent ratio {grow_ratio:.3f}"
    grow_base = baseline.get("grow_step")
    if grow_base is not None:
        grow_floor = (1.0 - args.tolerance) * grow_base["value"]
        grow_verdict += f" (baseline {grow_base['value']:.3f}, floor {grow_floor:.3f})"
        if grow_ratio < grow_floor:
            print(
                f"check_regression: FAIL — grow-step regression: {grow_verdict}"
            )
            return 1
    tel_verdict = f"ttft p95 ratio {ttft_ratio:.3f}, utilization {util:.3f}"
    tel_base = baseline.get("telemetry")
    if tel_base is not None:
        ttft_ceil = max(
            (1.0 + args.tolerance) * tel_base["ttft_p95_ratio"], TTFT_ABS_CEILING
        )
        util_floor = (1.0 - args.tolerance) * tel_base["utilization"]
        tel_verdict += (
            f" (ttft ceiling {ttft_ceil:.3f}, utilization floor {util_floor:.3f})"
        )
        if ttft_ratio > ttft_ceil:
            print(
                "check_regression: FAIL — chunked TTFT tail regressed vs "
                f"monolithic: {tel_verdict}"
            )
            return 1
        if util < util_floor:
            print(
                f"check_regression: FAIL — pool utilization dropped: {tel_verdict}"
            )
            return 1
    # prefix caching (DESIGN.md §10): full-hit rate is a hard 1.0 gate, the
    # hit/cold TTFT ratio a ceiling gate like ttft_p95_ratio
    px_verdict = (
        f"prefix hit rate {hit_rate:.3f}, hit/cold ttft {hit_ttft_ratio:.3f}"
    )
    if hit_rate != 1.0:
        print(
            "check_regression: FAIL — shared-prefix fleet missed the cache "
            f"(hit rate must be exactly 1.0): {px_verdict}"
        )
        return 1
    px_base = baseline.get("prefix")
    if px_base is not None:
        px_ceil = max(
            (1.0 + args.tolerance) * px_base["ttft_hit_ratio"],
            HIT_TTFT_ABS_CEILING,
        )
        px_verdict += f" (ceiling {px_ceil:.3f})"
        if hit_ttft_ratio > px_ceil:
            print(
                "check_regression: FAIL — full-hit TTFT no longer beats cold "
                f"prefill: {px_verdict}"
            )
            return 1
    # device counter plane (DESIGN.md §9.x): masked-lane waste is a ratchet —
    # the attend walk reading lanes past kv_len may only get leaner; a jump
    # past baseline + tolerance means the page-walk gating regressed
    dev_verdict = f"masked-lane waste {masked_waste:.4f}"
    dev_base = baseline.get("device")
    if dev_base is not None:
        waste_ceil = (1.0 + args.tolerance) * dev_base["masked_lane_waste"]
        dev_verdict += (
            f" (baseline {dev_base['masked_lane_waste']:.4f}, "
            f"ceiling {waste_ceil:.4f})"
        )
        if masked_waste > waste_ceil:
            print(
                "check_regression: FAIL — attend masked-lane waste grew: "
                f"{dev_verdict}"
            )
            return 1
    print(
        f"check_regression: OK — {verdict}; {grow_verdict}; {tel_verdict}; "
        f"{px_verdict}; {dev_verdict}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
