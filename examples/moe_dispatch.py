"""MoE dispatch IS parallel insertion (DESIGN.md §3).

Routes a batch of tokens to experts and computes each token's buffer slot
with the paper's three insertion algorithms — experts play the role of
LFVector blocks.  Shows the GGArray-geometry capacity (no token drops at
≤2× memory) vs a fixed capacity factor (drops).

    PYTHONPATH=src python examples/moe_dispatch.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.insertion import insertion_offsets
from repro.models import moe as moe_mod
from repro.models import transformer


def main() -> None:
    cfg = configs.reduced("dbrx-132b")  # 4 experts top-2 reduced
    moe = cfg.moe
    T = 64
    key = jax.random.PRNGKey(0)
    xt = jax.random.normal(key, (T, cfg.d_model))

    params = moe_mod.init_moe(key, cfg, jnp.float32)
    logits = xt @ params["router"]
    gate, expert = jax.lax.top_k(jax.nn.softmax(logits, -1), moe.top_k)
    flat_expert = expert.reshape(-1)
    assign = jax.nn.one_hot(flat_expert, moe.n_experts, dtype=jnp.int32).T

    print(f"{T} tokens → {moe.n_experts} experts (top-{moe.top_k})")
    print("per-expert load:", jnp.sum(assign, axis=1))
    for method in ("atomic", "scan", "mxu"):
        offsets, counts = insertion_offsets(assign.astype(bool), method=method)
        rank = jnp.take_along_axis(offsets.T, flat_expert[:, None], 1)[:, 0]
        print(f"  insertion[{method}]: max rank per expert = {counts} (unique slots ✓)")

    # capacity: fixed factor (drops) vs GGArray geometry (≤2x, no drops)
    import dataclasses

    fixed = moe_mod.expert_capacity(moe, T)
    gg = moe_mod.expert_capacity(dataclasses.replace(moe, ggarray_capacity=True), T)
    load = jnp.max(jnp.sum(assign, axis=1))
    print(f"capacity: fixed-factor={fixed} (drops if load>{fixed}), "
          f"ggarray-bucket={gg} (max load {load})")

    out, aux = moe_mod.moe_block(params, xt[None], cfg)
    print(f"moe_block out shape={out.shape}, aux loss={float(aux):.4f}")


if __name__ == "__main__":
    main()
