"""Quickstart: the GGArray public API in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import core


def main() -> None:
    # --- single LFVector: the paper's Algorithms 1-2 ----------------------
    v = core.LFVector.create(b0=4)
    v.push_back(jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0]))  # grows automatically
    v[2] = 30.0
    print("LFVector:", v.to_array(), f"(len={len(v)}, capacity={v.capacity}, "
          f"buckets={v.nbuckets})")

    # --- GGArray: one LFVector per block, block-local parallel insertion --
    nblocks = 4
    arr = core.init(nblocks, b0=4)
    arr = core.ensure_capacity(arr, 6)

    elems = jnp.arange(24, dtype=jnp.float32).reshape(nblocks, 6)
    mask = elems % 3 != 0  # only some lanes insert — scan assigns dense slots
    arr, positions = core.push_back(arr, elems, mask, method="scan")
    print("per-block sizes:", arr.sizes, " capacity/block:", arr.capacity_per_block)
    print("assigned in-block positions:\n", positions)

    # --- the three insertion algorithms agree (paper §III.B) --------------
    for method in ("atomic", "scan", "mxu"):
        off, cnt = core.insertion_offsets(mask, method=method)
        print(f"insertion[{method}]: counts={cnt}")

    # --- global indexing: prefix-sum table + binary search (rw_g) ---------
    flat, total = core.flatten(arr)
    idx = jnp.arange(int(total))
    print("rw_g read:", core.read_global(arr, idx)[:8], "...")
    print("flatten :", flat[: int(total)][:8], "...")

    # --- memory bound: capacity < 2x size + B0 (paper §V) -----------------
    n = int(total)
    print(f"memory: size={n} allocated={core.memory_elems(arr)} "
          f"(bound 2n+B0·blocks={2 * n + 4 * nblocks})")


if __name__ == "__main__":
    main()
