"""Quickstart: the GGArray public API in five minutes.

Covers the paper's core objects bottom-up — LFVector (Algs. 1–2), GGArray
(block-parallel push_back, rw_g indexing), the three insertion algorithms —
then the intended way to consume them: ``runtime.TwoPhasePipeline``, which
owns the grow → freeze (linear-time segmented flatten) → static-read
lifecycle.  See README.md for the paper-section → module map and DESIGN.md
for the allocation model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import core
from repro.runtime import TwoPhasePipeline


def main() -> None:
    # --- single LFVector: the paper's Algorithms 1-2 ----------------------
    v = core.LFVector.create(b0=4)
    v.push_back(jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0]))  # grows automatically
    v[2] = 30.0
    print("LFVector:", v.to_array(), f"(len={len(v)}, capacity={v.capacity}, "
          f"buckets={v.nbuckets})")

    # --- GGArray: one LFVector per block, block-local parallel insertion --
    nblocks = 4
    arr = core.init(nblocks, b0=4)
    arr = core.ensure_capacity(arr, 6)

    elems = jnp.arange(24, dtype=jnp.float32).reshape(nblocks, 6)
    mask = elems % 3 != 0  # only some lanes insert — scan assigns dense slots
    arr, positions = core.push_back(arr, elems, mask, method="scan")
    print("per-block sizes:", arr.sizes, " capacity/block:", arr.capacity_per_block)
    print("assigned in-block positions:\n", positions)

    # --- the three insertion algorithms agree (paper §III.B) --------------
    for method in ("atomic", "scan", "mxu"):
        off, cnt = core.insertion_offsets(mask, method=method)
        print(f"insertion[{method}]: counts={cnt}")

    # --- the hot path: donated append + host-side planner (DESIGN.md §2) --
    # Steady-state waves issue ZERO device->host transfers: the planner
    # proves capacity from its host-side bound and gg.append donates the
    # buffers (old references die). The headroom flag is read only when a
    # growth might be needed — O(log n) host contacts total.
    planner = core.CapacityPlanner.for_array(arr)
    wave = jnp.ones((nblocks, 2), jnp.float32)
    for _ in range(3):
        arr = planner.reserve(arr, 2)
        arr, _, headroom = core.append(arr, wave)
        planner.note_append(arr, headroom)
    print(f"amortized appends: sizes={arr.sizes}, host syncs={planner.host_syncs}")

    # --- global indexing: prefix-sum table + binary search (rw_g) ---------
    flat, total = core.flatten(arr)
    idx = jnp.arange(int(total))
    print("rw_g read:", core.read_global(arr, idx)[:8], "...")
    print("flatten :", flat[: int(total)][:8], "...")

    # --- memory bound: capacity < 2x size + B0 (paper §V) -----------------
    n = int(total)
    print(f"memory: size={n} allocated={core.memory_elems(arr)} "
          f"(bound 2n+B0·blocks={2 * n + 4 * nblocks})")

    # --- the two-phase runtime: grow → freeze → static reads (§VI.D) ------
    pipe = TwoPhasePipeline(nblocks=4, b0=4)
    pipe.append(jnp.arange(12, dtype=jnp.float32).reshape(4, 3))
    frozen = pipe.freeze()  # linear-time segmented flatten kernel
    print(f"two-phase: froze {int(frozen.size)} elements, "
          f"contiguous read: {frozen.read(jnp.arange(4))}")
    pipe.thaw()  # copy-free return to the grow phase


if __name__ == "__main__":
    main()
