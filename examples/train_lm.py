"""End-to-end training driver example (deliverable b).

Trains a small qwen-family model for a few hundred steps with the full
substrate: deterministic data, AdamW + warmup-cosine, checkpointing, resume.
Default is a fast CPU preset; ``--model-size 100m --steps 300`` reproduces
the assignment's ~100M-parameter run on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --model-size 100m --steps 300
"""
import argparse

from repro import configs
from repro.train import loop as loop_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-size", default="10m", choices=["2m", "10m", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    dims = {
        "2m": dict(d_model=128, n_layers=4, n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=4096),
        "10m": dict(d_model=256, n_layers=6, n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=8192),
        "100m": dict(d_model=768, n_layers=12, n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32768),
    }[args.model_size]
    cfg = configs.reduced("qwen2.5-3b", **dims)
    print(f"model: {cfg.param_counts()['total'] / 1e6:.1f}M params")

    losses = []
    out = loop_mod.run(
        cfg,
        loop_mod.LoopConfig(
            steps=args.steps,
            batch=args.batch,
            seq=args.seq,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=50,
            async_ckpt=True,
            warmup=20,
            lr=3e-4,
            log_every=20,
        ),
        on_metrics=lambda it, m: losses.append(float(m["loss"])),
    )
    first, last = out["losses"][0], out["losses"][-1]
    print(f"loss: {first:.3f} → {last:.3f} over {len(out['losses'])} steps "
          f"(resumed from {out['start_step']})")
    assert last < first, "training should reduce loss"


if __name__ == "__main__":
    main()
