"""The paper's case study (§VI.D) on the phase-aware runtime.

``TwoPhasePipeline`` makes the two-phase pattern an explicit state machine:

Phase 1 (GROW)   — waves of insertions with unknown final size; the pipeline's
                   GGArray grows copy-free (a doubling baseline reallocates +
                   copies every element on each growth).
freeze()         — the one-shot handoff: the linear-time segmented-gather
                   Pallas kernel flattens the bucket chain into a contiguous,
                   globally-ordered FrozenArray (the legacy one-hot dispatch
                   matmul did the same in O(n²) work).
Phase 2 (FROZEN) — the static pipeline: W work kernels run on the contiguous
                   buffer at flat-array speed via ``map_frozen``.
thaw()           — optional return to GROW for the next ingest cycle.

    PYTHONPATH=src python examples/two_phase.py
"""
import time

import jax
import jax.numpy as jnp

from repro.runtime import TwoPhasePipeline


def work_kernel(x, repeats=30):
    for _ in range(repeats):
        x = x + 1.0
    return x


def main() -> None:
    nblocks, waves, start = 8, 5, 1 << 10
    W = 100  # work-phase iterations

    # ---- phase 1: grow ---------------------------------------------------
    t0 = time.perf_counter()
    pipe = TwoPhasePipeline(nblocks, b0=start // nblocks)
    size = start
    for wave in range(waves):
        per_block = size // nblocks
        pipe.append(jnp.ones((nblocks, per_block), jnp.float32))
        size *= 2
    t_grow = time.perf_counter() - t0

    # ---- the handoff: freeze via the segmented flatten kernel ------------
    frozen = pipe.freeze()
    total = int(frozen.size)
    print(f"grow phase: {total} elements in {pipe.stats.appends} waves, "
          f"{pipe.stats.grow_events} growth events (copy-free), "
          f"capacity {pipe.memory_elems()} "
          f"(≤2x: {pipe.memory_elems() <= 2 * total + pipe.array.b0 * nblocks}), "
          f"{t_grow * 1e3:.1f} ms")
    print(f"freeze: {pipe.stats.last_freeze_s * 1e3:.1f} ms "
          f"(segmented gather, O(n); first freeze includes one-time compile — "
          f"see bench_two_phase.py for warm latency)")

    # ---- phase 2: static work on the frozen array ------------------------
    t0 = time.perf_counter()
    fn = jax.jit(lambda x: jax.lax.fori_loop(0, W, lambda _, y: work_kernel(y), x))
    pipe.map_frozen(fn)
    jax.block_until_ready(pipe.frozen.data)
    t_work = time.perf_counter() - t0
    print(f"work phase: {W} kernels on frozen array, {t_work * 1e3:.1f} ms")
    print(f"grow+freeze overhead amortized: "
          f"{(t_grow + pipe.stats.last_freeze_s) / (t_grow + pipe.stats.last_freeze_s + t_work) * 100:.1f}% of total")

    # ---- thaw: the cycle can repeat --------------------------------------
    pipe.thaw()
    pipe.append(jnp.ones((nblocks, 16), jnp.float32))
    print(f"thawed and regrew: {pipe.total_size()} elements, "
          f"phase={pipe.phase.value}")


if __name__ == "__main__":
    main()
