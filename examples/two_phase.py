"""The paper's case study (§VI.D): a two-phase application.

Phase 1 (grow): waves of insertions with unknown final size — GGArray grows
copy-free; the semistatic baseline reallocates + copies on every doubling.
Phase 2 (work): flatten once, then run the static work kernel (+1, 30×) W
times on the contiguous array.

    PYTHONPATH=src python examples/two_phase.py
"""
import time

import jax
import jax.numpy as jnp

from repro import core


def work_kernel(x, repeats=30):
    for _ in range(repeats):
        x = x + 1.0
    return x


def main() -> None:
    nblocks, waves, start = 8, 5, 1 << 10
    W = 100  # work-phase iterations

    # ---- phase 1: grow with GGArray ----
    t0 = time.perf_counter()
    arr = core.init(nblocks, b0=start // nblocks)
    size = start
    for wave in range(waves):
        per_block = size // nblocks
        arr = core.ensure_capacity(arr, per_block)
        elems = jnp.ones((nblocks, per_block), jnp.float32)
        arr, _ = core.push_back(arr, elems)
        size *= 2
    flat, total = core.flatten(arr)
    jax.block_until_ready(flat)
    t_grow = time.perf_counter() - t0
    print(f"grow phase: {int(total)} elements, capacity {core.memory_elems(arr)} "
          f"(≤2x: {core.memory_elems(arr) <= 2 * int(total) + arr.b0 * nblocks}), "
          f"{t_grow * 1e3:.1f} ms")

    # ---- phase 2: static work on the flattened array ----
    t0 = time.perf_counter()
    fn = jax.jit(lambda x: jax.lax.fori_loop(0, W, lambda _, y: work_kernel(y), x))
    out = jax.block_until_ready(fn(flat))
    t_work = time.perf_counter() - t0
    print(f"work phase: {W} kernels on flat array, {t_work * 1e3:.1f} ms")
    print(f"grow overhead amortized: {t_grow / (t_grow + t_work) * 100:.1f}% of total")


if __name__ == "__main__":
    main()
