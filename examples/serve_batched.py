"""Batched serving example (deliverable b): GGArray KV cache end to end.

Serves a small model with batched requests of different lengths, comparing
the three cache policies on the same prompts: identical outputs, different
growth behavior (copy-free vs copying vs worst-case pre-allocation).
Then the same fleet goes through the slab-arena ``BatchEngine``
(policy="paged", DESIGN.md §4): continuous batching over one shared pool,
identical tokens again, capacity bounded by live data + one slab/sequence.

    PYTHONPATH=src python examples/serve_batched.py --new-tokens 24
"""
import argparse
import time

import jax

from repro import configs
from repro.models import transformer
from repro.serving.engine import BatchEngine, Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch, cache_b0=8)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [11, 12, 13], [21, 22, 23, 24], [31, 32]]

    outputs = {}
    for policy in ("ggarray", "semistatic", "static"):
        eng = Engine(params, cfg, policy=policy, max_len=256)
        t0 = time.perf_counter()
        outputs[policy] = eng.generate(prompts, max_new_tokens=args.new_tokens)
        dt = time.perf_counter() - t0
        s = eng.stats
        print(
            f"{policy:10s}: {len(prompts) * args.new_tokens / dt:7.1f} tok/s  "
            f"grows={s.grow_events}  copied={s.copied_bytes / 1e3:.1f}KB  "
            f"allocated={s.allocated_bytes / 1e3:.1f}KB  recompiles={s.compiles}"
        )

    assert outputs["ggarray"] == outputs["semistatic"] == outputs["static"], (
        "all cache policies must produce identical tokens"
    )
    print("✓ identical generations across policies")

    # the slab arena: 2 decode slots serve all 4 requests through one pool
    be = BatchEngine(params, cfg, max_batch=2)
    t0 = time.perf_counter()
    paged = be.run_all(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    s = be.stats
    print(
        f"{'paged':10s}: {len(prompts) * args.new_tokens / dt:7.1f} tok/s  "
        f"pool={s.peak_pool_tokens} tok  peak_live={s.peak_live_tokens} tok  "
        f"reused_slabs={s.reused_slabs}  host_syncs={s.host_syncs}"
    )
    assert paged == outputs["ggarray"], "paged must match the ggarray oracle"
    assert s.peak_pool_tokens < 2 * s.peak_live_tokens + cfg.slab_tokens * be.B
    print("✓ paged BatchEngine matches bit-for-bit within the capacity bound")
    print("sample:", outputs["ggarray"][0][:12], "...")


if __name__ == "__main__":
    main()
