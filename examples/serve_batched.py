"""Batched serving example (deliverable b): GGArray KV cache end to end.

Serves a small model with batched requests of different lengths, comparing
the three cache policies on the same prompts: identical outputs, different
growth behavior (copy-free vs copying vs worst-case pre-allocation).

    PYTHONPATH=src python examples/serve_batched.py --new-tokens 24
"""
import argparse
import time

import jax

from repro import configs
from repro.models import transformer
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch, cache_b0=8)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [11, 12, 13], [21, 22, 23, 24], [31, 32]]

    outputs = {}
    for policy in ("ggarray", "semistatic", "static"):
        eng = Engine(params, cfg, policy=policy, max_len=256)
        t0 = time.perf_counter()
        outputs[policy] = eng.generate(prompts, max_new_tokens=args.new_tokens)
        dt = time.perf_counter() - t0
        s = eng.stats
        print(
            f"{policy:10s}: {len(prompts) * args.new_tokens / dt:7.1f} tok/s  "
            f"grows={s.grow_events}  copied={s.copied_bytes / 1e3:.1f}KB  "
            f"allocated={s.allocated_bytes / 1e3:.1f}KB  recompiles={s.compiles}"
        )

    assert outputs["ggarray"] == outputs["semistatic"] == outputs["static"], (
        "all cache policies must produce identical tokens"
    )
    print("✓ identical generations across policies")
    print("sample:", outputs["ggarray"][0][:12], "...")


if __name__ == "__main__":
    main()
