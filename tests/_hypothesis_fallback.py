"""Shim for containers without ``hypothesis`` (no network to install it).

Importing ``given``/``settings``/``st`` from here keeps modules that mix
property tests with ordinary example tests collectable: every ``@given`` test
becomes an individually-skipped test instead of killing the whole module at
import, and the example tests keep running.  CI (which installs the real
``hypothesis`` from pyproject.toml) exercises the property tests in full.
"""
import pytest


class _AnyStrategy:
    """Stands in for ``hypothesis.strategies``: every call returns None."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


st = _AnyStrategy()


def settings(*_args, **_kwargs):
    return lambda fn: fn


def given(*_args, **_kwargs):
    def deco(fn):
        def skipper():
            pytest.skip("property test needs hypothesis")

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper

    return deco
