"""Test session config.

Smoke tests and kernel tests run on the single real CPU device — the 512-way
placeholder device farm belongs exclusively to launch/dryrun.py (which sets
XLA_FLAGS before any jax import). Distributed tests that need >1 device spawn
subprocesses with their own XLA_FLAGS.
"""
import os

# Fail fast if something leaked the dry-run device farm into the test session.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), (
    "tests must see the real device count; dryrun.py owns XLA_FLAGS"
)

import jax

jax.config.update("jax_enable_x64", False)
