"""Test session config.

Smoke tests and kernel tests run on the single real CPU device — the 512-way
placeholder device farm belongs exclusively to launch/dryrun.py (which sets
XLA_FLAGS before any jax import). Distributed tests that need >1 device spawn
subprocesses with their own XLA_FLAGS.
"""
import os

# Fail fast if something leaked the dry-run device farm into the test session.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), (
    "tests must see the real device count; dryrun.py owns XLA_FLAGS"
)

# Flight-recorder bundles (DESIGN.md §9.y): route postmortem dumps from
# engine-test failures to a known directory so CI can upload them as an
# artifact (ci.yml overrides this with a workspace-relative path).  The
# directory is only created when a failure actually dumps a bundle.
os.environ.setdefault(
    "REPRO_FLIGHTREC_DIR",
    os.path.join(os.path.dirname(__file__), "..", "test-artifacts", "flightrec"),
)

import gc

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables_per_module():
    """Release each module's jit executables once the module finishes.

    Every XLA:CPU compile mmaps JIT code pages and the suite never unloads
    test modules, so a full run accumulates memory maps until it crosses the
    kernel's vm.max_map_count (65530 by default) and LLVM's allocator
    segfaults mid-compile.  Clearing per module keeps the peak bounded by the
    largest single module while leaving intra-module warm-cache assertions
    (compile spies, shared engine fixtures) untouched.
    """
    yield
    gc.collect()
    jax.clear_caches()
    gc.collect()
