"""Slab arena: GGArray parity, free-list invariants, reclamation, quotas."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.core import ggarray as gg
from repro.pool import QuotaExceeded, SlabArena
from repro.runtime import TwoPhasePipeline


def test_arena_append_matches_ggarray_bitwise():
    """Same waves → identical positions, sizes, and flattened contents."""
    rng = np.random.default_rng(0)
    arena = SlabArena(4, 8, dtype=jnp.float32)
    ref = gg.init(4, b0=8, dtype=jnp.float32, nbuckets=1)
    planner = gg.CapacityPlanner()
    for _ in range(10):
        m = int(rng.integers(1, 9))
        elems = jnp.asarray(rng.standard_normal((4, m)), jnp.float32)
        mask = rng.random((4, m)) > 0.3
        pos_a = arena.append(elems, mask)
        ref = planner.reserve(ref, m, mask=mask)
        ref, pos_g, hr = gg.append(ref, elems, jnp.asarray(mask))
        planner.note_append(ref, hr)
        np.testing.assert_array_equal(np.asarray(pos_a), np.asarray(pos_g))
    flat_a, tot_a, _ = arena.flatten()
    flat_g, tot_g = gg.flatten(ref)
    n = int(jax.device_get(tot_a))
    assert n == int(jax.device_get(tot_g))
    np.testing.assert_array_equal(np.asarray(flat_a)[:n], np.asarray(flat_g)[:n])
    assert arena.host_syncs == 0, "host-known masks must plan without syncs"
    arena.check_invariants()


def test_arena_capacity_bound():
    """Fleet capacity ≤ live tokens + one slab per array (demand growth)."""
    rng = np.random.default_rng(1)
    arena = SlabArena(6, 16, dtype=jnp.float32)
    for _ in range(8):
        m = int(rng.integers(1, 20))
        arena.append(jnp.ones((6, m), jnp.float32))
    stats = arena.check_invariants()
    assert stats["capacity_tokens"] <= stats["live_tokens"] + 16 * 6
    assert stats["capacity_tokens"] < 2 * stats["live_tokens"] + 16 * 6


def test_arena_nonscalar_items_flatten():
    arena = SlabArena(2, 4, item_shape=(3,), dtype=jnp.float32)
    elems = jnp.arange(2 * 5 * 3, dtype=jnp.float32).reshape(2, 5, 3)
    arena.append(elems)
    flat, total, starts = arena.flatten()
    assert int(jax.device_get(total)) == 10
    np.testing.assert_array_equal(
        np.asarray(flat)[:5], np.asarray(elems[0])
    )
    np.testing.assert_array_equal(np.asarray(flat)[5:10], np.asarray(elems[1]))


def test_release_then_reuse_before_growth():
    arena = SlabArena(3, 8, dtype=jnp.float32)
    arena.append(jnp.ones((3, 20), jnp.float32))
    grown_before = arena.alloc.grown_slabs
    arena.release(1)
    freed = arena.alloc.free_count
    assert freed == 3  # ceil(20/8)
    # next growth on another tenant must consume the freed slabs first
    arena.append(
        jnp.ones((3, 16), jnp.float32),
        np.asarray([[True] * 16, [False] * 16, [True] * 16]),
    )
    assert arena.alloc.reuse_claims >= 3, "freed slabs must be reused"
    assert arena.alloc.grown_slabs == grown_before + 1, (
        "pool may grow only for the shortfall beyond the free list"
    )
    arena.check_invariants()


def test_quota_rejects_runaway_tenant():
    arena = SlabArena(2, 4, quota_slabs=2, dtype=jnp.float32)
    arena.append(jnp.ones((2, 8), jnp.float32))  # 2 slabs each: at quota
    with pytest.raises(QuotaExceeded):
        arena.append(jnp.ones((2, 4), jnp.float32))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_interleaved_admit_grow_evict_never_double_assigns(seed):
    """Property: any interleaving of appends and releases keeps every slab
    either free or owned by exactly one array, with freed slabs reused
    before the pool grows."""
    rng = np.random.default_rng(seed)
    n = 4
    arena = SlabArena(n, 4, dtype=jnp.float32)
    for _ in range(12):
        if rng.random() < 0.3:
            arena.release(int(rng.integers(0, n)))
            continue
        m = int(rng.integers(1, 10))
        mask = rng.random((n, m)) < 0.7
        free_before = arena.alloc.free_count
        grown_before = arena.alloc.grown_slabs
        arena.append(
            jnp.asarray(rng.standard_normal((n, m)), jnp.float32), mask
        )
        claimed = (
            arena.alloc.grown_slabs - grown_before
            + free_before - arena.alloc.free_count
        )
        if arena.alloc.grown_slabs > grown_before:
            # growth only for the shortfall: the free list was consumed
            assert arena.alloc.free_count == 0 or claimed >= free_before
    stats = arena.check_invariants()
    assert stats["capacity_tokens"] <= stats["live_tokens"] + 4 * n + 4 * n


def test_geometric_growth_pays_o_log_copies():
    """grow_chunk="geometric": pool realloc copies are O(log final slabs),
    while demand growth (the tight-capacity default) pays ~one per wave."""
    geo = SlabArena(2, 4, dtype=jnp.float32, grow_chunk="geometric")
    demand = SlabArena(2, 4, dtype=jnp.float32)
    waves = 40
    for _ in range(waves):
        elems = jnp.ones((2, 6), jnp.float32)
        geo.append(elems)
        demand.append(elems)
    n = geo.pool.n_slabs
    assert geo.pool_grow_events <= int(np.ceil(np.log2(max(n, 2)))) + 1, (
        f"{geo.pool_grow_events} realloc copies for {n} slabs is not O(log)"
    )
    assert demand.pool_grow_events > 2 * geo.pool_grow_events
    # the data is identical either way — over-provisioning is capacity-only
    fg, tg, _ = geo.flatten()
    fd, td, _ = demand.flatten()
    ng = int(jax.device_get(tg))
    assert ng == int(jax.device_get(td))
    np.testing.assert_array_equal(np.asarray(fg)[:ng], np.asarray(fd)[:ng])
    geo.check_invariants()


def test_high_water_pre_carve_never_grows():
    """initial_slabs at the expected high-water mark: zero realloc copies."""
    arena = SlabArena(2, 4, dtype=jnp.float32, initial_slabs=32)
    for _ in range(10):
        arena.append(jnp.ones((2, 6), jnp.float32))  # 60 tokens < 64 carved
    assert arena.pool_grow_events == 0
    arena.check_invariants()


def test_arena_memory_space_paths_agree():
    """vmem- and hbm-pinned arenas produce identical appends and flattens."""
    rng = np.random.default_rng(9)
    arenas = {
        sp: SlabArena(3, 4, dtype=jnp.float32, memory_space=sp)
        for sp in ("vmem", "hbm")
    }
    for _ in range(6):
        m = int(rng.integers(1, 9))
        elems = jnp.asarray(rng.standard_normal((3, m)), jnp.float32)
        mask = rng.random((3, m)) > 0.3
        pos = {sp: a.append(elems, mask) for sp, a in arenas.items()}
        np.testing.assert_array_equal(
            np.asarray(pos["vmem"]), np.asarray(pos["hbm"])
        )
    flats = {sp: a.flatten() for sp, a in arenas.items()}
    np.testing.assert_array_equal(
        np.asarray(flats["vmem"][0]), np.asarray(flats["hbm"][0])
    )
    for a in arenas.values():
        a.check_invariants()


def test_pipeline_from_arena_freeze_thaw():
    """TwoPhasePipeline lifecycle over arena-backed storage."""
    pipe = TwoPhasePipeline.from_arena(SlabArena(4, 8, dtype=jnp.float32))
    ref = TwoPhasePipeline(4, 8, dtype=jnp.float32)
    rng = np.random.default_rng(3)
    for _ in range(5):
        m = int(rng.integers(1, 12))
        elems = jnp.asarray(rng.standard_normal((4, m)), jnp.float32)
        mask = rng.random((4, m)) > 0.4
        p1 = pipe.append(elems, mask)
        p2 = ref.append(elems, np.asarray(mask))
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    fa, fg = pipe.freeze(), ref.freeze()
    n = int(jax.device_get(fa.size))
    assert n == int(jax.device_get(fg.size))
    np.testing.assert_array_equal(
        np.asarray(fa.data)[:n], np.asarray(fg.data)[:n]
    )
    np.testing.assert_array_equal(
        np.asarray(fa.block_starts), np.asarray(fg.block_starts)
    )
    pipe.thaw()
    pipe.append(jnp.ones((4, 3), jnp.float32))  # grow resumes after thaw
    assert pipe.total_size() == n + 12
