"""Segmented extent pool: zero-copy growth and two-level table invariants.

Deterministic seeded sweeps run everywhere; the ``@given`` variants fuzz the
same properties when hypothesis is installed (CI).  The buffer-identity tests
are the teeth behind the "zero-copy growth" claim: growing an extent pool must
keep every existing extent's device buffer (checked via object identity and
``unsafe_buffer_pointer``), while the flat realloc pool demonstrably does not.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.pool import SlabArena
from repro.pool.extents import (
    EXTENT_SCHEDULES,
    _tz_size,
    flat_data,
    grow_extents,
    grow_flat,
    init_extent_pool,
    plan_extents,
    resolve_pages,
    slab_tables,
)
from repro.pool.planner import SlabAllocator


def _buf_ptrs(pool):
    return [e.unsafe_buffer_pointer() for e in pool.extents]


# ---------------------------------------------------------------------------
# growth schedules
# ---------------------------------------------------------------------------


def test_plan_doubling_covers_total_plus_reserved():
    assert plan_extents((4,), 1, "doubling") == [4]
    assert plan_extents((4, 4), 3, "doubling") == [8]
    # reserved-but-unclaimed slabs size the base, not just live ones
    assert plan_extents((4,), 1, "doubling", reserved=9) == [13]
    assert plan_extents((), 1, "doubling") == [1]


def test_plan_tz_block_sequence():
    """Tarjan–Zwick: superblock k holds 2^floor(k/2) blocks of 2^ceil(k/2)."""
    assert [_tz_size(j) for j in range(11)] == [1, 2, 2, 2, 4, 4, 4, 4, 4, 4, 8]
    assert plan_extents((), 5, "tz") == [1, 2, 2]
    # sequence resumes at the first unused block index
    assert plan_extents((1, 2, 2), 4, "tz") == [2, 4]
    assert plan_extents((1, 2, 2), 5, "tz") == [2, 4]
    # shortfall() already counts reservations, so tz ignores ``reserved``
    assert plan_extents((1,), 2, "tz", reserved=3) == [2]


def test_tz_waste_is_o_sqrt_n():
    """Capacity overshoot after any tz growth is at most O(sqrt(total))."""
    sizes: list[int] = []
    for short in [1, 3, 7, 20, 50, 200]:
        sizes += plan_extents(tuple(sizes), short, "tz")
        total = sum(sizes)
        assert sizes[-1] <= 2 * int(np.sqrt(total)) + 1


# ---------------------------------------------------------------------------
# buffer identity: the zero-copy claim
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", EXTENT_SCHEDULES)
def test_grow_extents_keeps_device_buffers(schedule):
    """N growths never touch existing extents: same objects, same pointers."""
    pool = init_extent_pool(2, 4, (3,), jnp.float32)
    pool = dataclass_fill(pool)
    for wave in range(5):
        before, ptrs = pool.extents, _buf_ptrs(pool)
        pool = grow_extents(pool, plan_extents(pool.extent_sizes, wave + 1, schedule))
        for i, old in enumerate(before):
            assert pool.extents[i] is old, "existing extent was rebuilt"
            assert pool.extents[i].unsafe_buffer_pointer() == ptrs[i]
    # contents of the original extent survive every growth bit-for-bit
    np.testing.assert_array_equal(
        np.asarray(pool.extents[0]), np.arange(2 * 4 * 3).reshape(2, 4, 3)
    )


def dataclass_fill(pool):
    filled = jnp.arange(pool.extents[0].size, dtype=pool.dtype).reshape(
        pool.extents[0].shape
    )
    return type(pool)(extents=(filled,) + pool.extents[1:], free=pool.free)


def test_grow_flat_reallocates_buffers():
    """Oracle for the spy: the flat fallback *does* move the live bytes."""
    pool = init_extent_pool(2, 4, (), jnp.float32)
    ptr = pool.extents[0].unsafe_buffer_pointer()
    grown = grow_flat(pool, 4)
    assert grown.n_extents == 1
    assert grown.extents[0].unsafe_buffer_pointer() != ptr


@pytest.mark.parametrize("schedule", EXTENT_SCHEDULES)
def test_arena_extent_growth_is_zero_copy(schedule):
    """SlabArena under an extent schedule: grows happen, bytes copied = 0."""
    arena = SlabArena(3, 4, dtype=jnp.float32, grow_chunk=schedule)
    rng = np.random.default_rng(0)
    first_ptr = None
    for _ in range(8):
        m = int(rng.integers(1, 10))
        arena.append(jnp.asarray(rng.standard_normal((3, m)), jnp.float32))
        if first_ptr is None and arena.pool.n_slabs:
            first_ptr = arena.pool.extents[0].unsafe_buffer_pointer()
    assert arena.pool_grow_events >= 2
    assert arena.pool_copied_bytes == 0
    assert arena.pool.n_extents > 1
    assert arena.pool.extents[0].unsafe_buffer_pointer() == first_ptr
    arena.check_invariants()


def test_arena_flat_growth_copies_bytes():
    arena = SlabArena(3, 4, dtype=jnp.float32, grow_chunk=1)
    for _ in range(4):
        arena.append(jnp.ones((3, 6), jnp.float32))
    assert arena.pool_copied_bytes > 0


@pytest.mark.parametrize("schedule", EXTENT_SCHEDULES)
def test_arena_extent_parity_vs_flat(schedule):
    """Extent layouts are invisible: positions and flatten match the flat pool."""
    rng = np.random.default_rng(2)
    flat = SlabArena(4, 8, dtype=jnp.float32, grow_chunk=1)
    seg = SlabArena(4, 8, dtype=jnp.float32, grow_chunk=schedule)
    for _ in range(6):
        m = int(rng.integers(1, 12))
        elems = jnp.asarray(rng.standard_normal((4, m)), jnp.float32)
        mask = jnp.asarray(rng.random((4, m)) > 0.3)
        pos_f = flat.append(elems, mask)
        pos_s = seg.append(elems, mask)
        np.testing.assert_array_equal(np.asarray(pos_f), np.asarray(pos_s))
    ff, tf, _ = flat.flatten()
    fs, ts, _ = seg.flatten()
    n = int(jax.device_get(tf))
    assert n == int(jax.device_get(ts))
    np.testing.assert_array_equal(np.asarray(ff)[:n], np.asarray(fs)[:n])
    seg.check_invariants()


# ---------------------------------------------------------------------------
# two-level table round-trip
# ---------------------------------------------------------------------------


def _check_round_trip(sizes):
    ext_of, off_of = slab_tables(tuple(sizes))
    bases = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    n = int(sum(sizes))
    assert ext_of.shape == off_of.shape == (n,)
    np.testing.assert_array_equal(bases[ext_of] + off_of, np.arange(n))
    assert (off_of < np.asarray(sizes)[ext_of]).all()


def test_slab_tables_round_trip_examples():
    for sizes in [(1,), (1, 2, 2), (4, 4, 8), (3, 1, 5, 2)]:
        _check_round_trip(sizes)


def test_resolve_pages_marks_invalid():
    ext, off = resolve_pages(jnp.asarray([[0, 2, -1], [3, -1, -1]]), (1, 2, 2))
    np.testing.assert_array_equal(np.asarray(ext), [[0, 1, -1], [2, -1, -1]])
    np.testing.assert_array_equal(np.asarray(off), [[0, 1, -1], [0, -1, -1]])


@pytest.mark.parametrize("schedule", EXTENT_SCHEDULES)
@pytest.mark.parametrize("seed", range(3))
def test_table_round_trips_under_claim_release_grow(schedule, seed):
    """Interleaved claim/release/grow waves: every live slab id resolves to a
    unique (extent, offset) cell and back, after every wave."""
    rng = np.random.default_rng(seed)
    alloc = SlabAllocator(0)
    sizes: list[int] = []
    live: dict[int, np.ndarray] = {}
    for tenant in range(20):
        k = int(rng.integers(1, 6))
        short = alloc.shortfall(k)
        if short:
            new = plan_extents(tuple(sizes), short, schedule)
            sizes += new
            alloc.grow(sum(new))
        live[tenant] = alloc.claim(tenant, k)
        if live and rng.random() < 0.4:
            victim = int(rng.choice(list(live)))
            alloc.release(live.pop(victim))
        _check_round_trip(sizes)
        assert sum(sizes) == alloc.n_slabs
        held = np.concatenate(list(live.values())) if live else np.empty(0, int)
        assert len(set(held.tolist())) == len(held)
        ext, off = resolve_pages(jnp.asarray(held, jnp.int32)[None], tuple(sizes))
        assert (np.asarray(ext) >= 0).all() and (np.asarray(off) >= 0).all()


@given(st.lists(st.integers(min_value=1, max_value=16), min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_slab_tables_round_trip_property(sizes):
    _check_round_trip(tuple(sizes))


@given(
    st.sampled_from(EXTENT_SCHEDULES),
    st.lists(st.integers(min_value=1, max_value=7), min_size=1, max_size=12),
)
@settings(max_examples=25, deadline=None)
def test_schedule_always_covers_shortfall(schedule, shorts):
    sizes: list[int] = []
    need = 0
    for short in shorts:
        sizes += plan_extents(tuple(sizes), short, schedule)
        need += short
        assert sum(sizes) >= need
        assert all(s > 0 for s in sizes)
        _check_round_trip(tuple(sizes))
