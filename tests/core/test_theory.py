"""Theoretical memory model (paper §V, Fig. 3)."""
import numpy as np
import pytest

from repro.core.theory import MemoryModel, memory_curves


def test_ggarray_capacity_bound_uniform_load():
    m = MemoryModel(n0=10_000, nblocks=64, b0=8)
    for s in [1_000, 10_000, 123_456, 1_000_000]:
        cap = m.ggarray_capacity(s)
        assert cap >= s
        # uniform load: < 2x + per-block slack (B0 per block)
        assert cap < 2 * s + 2 * m.b0 * m.nblocks


def test_static_needs_exponentially_more_with_sigma():
    m = MemoryModel()
    caps = [m.static_capacity(s) for s in (0.0, 1.0, 2.0)]
    assert caps[0] == pytest.approx(m.n0, rel=1e-6)
    assert caps[1] > 5 * m.n0  # e^{2.33} ≈ 10.2
    assert caps[2] > 50 * m.n0  # e^{4.65} ≈ 105


def test_fig3_curves_shape_and_ordering():
    curves = memory_curves(np.linspace(0, 2, 5))
    # GGArray stays within 2x of optimal; static blows up with sigma (Fig. 3)
    assert np.all(curves["ggarray_over_optimal"] <= 2.05)
    assert curves["static_over_optimal"][-1] > curves["static_over_optimal"][0]
    assert curves["static"][-1] > curves["ggarray"][-1]


def test_norm_ppf_sane():
    from repro.core.theory import _norm_ppf

    assert _norm_ppf(0.5) == pytest.approx(0.0, abs=1e-8)
    assert _norm_ppf(0.99) == pytest.approx(2.326, abs=1e-3)
    assert _norm_ppf(0.01) == pytest.approx(-2.326, abs=1e-3)
