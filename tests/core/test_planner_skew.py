"""CapacityPlanner with host-known masks: skew-exact per-block bounds."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ggarray as gg


def _run(nwaves, m, make_mask, use_host_mask):
    arr = gg.init(4, b0=8, nbuckets=2)
    planner = gg.CapacityPlanner()
    for w in range(nwaves):
        mask = make_mask(w)
        planner_mask = mask if use_host_mask else jnp.asarray(mask)
        arr = planner.reserve(arr, m, mask=planner_mask)
        arr, _, hr = gg.append(arr, jnp.ones((4, m)), jnp.asarray(mask))
        planner.note_append(arr, hr)
    return arr, planner


def test_host_mask_skew_fewer_syncs_than_device_mask():
    """One dense lane in a wide wave: the scalar bound advances by m per
    wave and syncs every ~capacity/m waves; the host-mask vector bound
    advances by 1 and stays silent until the target block really fills."""
    m = 8

    def one_lane(_w):
        mask = np.zeros((4, m), bool)
        mask[2, 0] = True
        return mask

    _, host_planner = _run(12, m, one_lane, use_host_mask=True)
    _, dev_planner = _run(12, m, one_lane, use_host_mask=False)
    assert host_planner.host_syncs == 0
    assert dev_planner.host_syncs > 0
    assert host_planner.size_ub == 12  # exact: 12 waves × 1 lane


def test_host_mask_growth_is_skew_exact():
    """Growth under host masks sizes capacity for the true max, not max+m."""
    m = 16

    def dense_one_block(_w):
        mask = np.zeros((4, m), bool)
        mask[0] = True
        return mask

    arr, planner = _run(4, m, dense_one_block, use_host_mask=True)
    sizes = np.asarray(jax.device_get(arr.sizes))
    np.testing.assert_array_equal(sizes, [64, 0, 0, 0])
    assert planner.size_ub == 64
    # never grows further than the skewed block needs
    assert arr.capacity_per_block >= 64
    assert gg.init(4, b0=8, nbuckets=arr.nbuckets - 1).capacity_per_block < 64


def test_device_mask_still_correct_if_pessimistic():
    m = 4

    def random_mask(w):
        return (np.arange(4 * m).reshape(4, m) + w) % 3 == 0

    arr_h, _ = _run(6, m, random_mask, use_host_mask=True)
    arr_d, _ = _run(6, m, random_mask, use_host_mask=False)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(arr_h.sizes)),
        np.asarray(jax.device_get(arr_d.sizes)),
    )
    fh, th = gg.flatten(arr_h)
    fd, td = gg.flatten(arr_d)
    n = int(jax.device_get(th))
    assert n == int(jax.device_get(td))
    np.testing.assert_array_equal(np.asarray(fh)[:n], np.asarray(fd)[:n])
