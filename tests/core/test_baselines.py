"""Static / semi-static comparison structures (paper §III.A)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl


def test_static_push_back_dense_and_masked():
    arr = bl.static_init(16)
    arr, pos = bl.static_push_back(arr, jnp.asarray([1.0, 2.0, 3.0]))
    np.testing.assert_array_equal(np.asarray(pos), [0, 1, 2])
    mask = jnp.asarray([True, False, True])
    arr, pos = bl.static_push_back(arr, jnp.asarray([4.0, 5.0, 6.0]), mask)
    np.testing.assert_array_equal(np.asarray(pos), [3, -1, 4])
    np.testing.assert_allclose(np.asarray(arr.data)[:5], [1, 2, 3, 4, 6])
    assert int(arr.size) == 5


def test_static_has_no_resize_overflow_drops():
    arr = bl.static_init(2)
    arr, _ = bl.static_push_back(arr, jnp.asarray([1.0, 2.0, 3.0]))
    # overflow is dropped (segfault analog is a hard failure on GPU; XLA drops)
    np.testing.assert_allclose(np.asarray(arr.data), [1, 2])


def test_semistatic_doubles_with_copy():
    arr = bl.SemiStaticArray.create(4)
    arr.push_back(jnp.arange(4, dtype=jnp.float32))
    assert arr.capacity == 4
    grows = arr.ensure_capacity(5)
    assert grows >= 1 and arr.capacity >= 9 - 1
    arr.push_back(jnp.asarray([9.0]))
    np.testing.assert_allclose(np.asarray(arr.arr.data)[:5], [0, 1, 2, 3, 9])


def test_semistatic_alloc_only_matches_shape():
    arr = bl.SemiStaticArray.create(8, copy_on_grow=False)
    buf = arr.grow_alloc_only()
    assert buf.shape == (16,)
