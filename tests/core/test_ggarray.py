"""GGArray semantics vs a per-block python-list oracle (paper §IV invariants)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # property tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core import ggarray as gg
from repro.core import indexing


def _oracle_push(oracle, elems, mask):
    for b in range(len(oracle)):
        for j in range(elems.shape[1]):
            if mask[b, j]:
                oracle[b].append(float(elems[b, j]))


def test_push_back_flatten_matches_list_semantics():
    nblocks, b0 = 4, 4
    arr = gg.init(nblocks, b0, nbuckets=3)
    oracle = [[] for _ in range(nblocks)]
    rng = np.random.default_rng(0)
    for wave in range(5):
        m = rng.integers(1, 6)
        elems = rng.standard_normal((nblocks, m)).astype(np.float32)
        mask = rng.random((nblocks, m)) < 0.7
        arr = gg.ensure_capacity(arr, m)
        arr, pos = gg.push_back(arr, jnp.asarray(elems), jnp.asarray(mask))
        _oracle_push(oracle, elems, mask)
    flat, total = gg.flatten(arr)
    want = [x for blk in oracle for x in blk]
    assert int(total) == len(want)
    np.testing.assert_allclose(np.asarray(flat)[: len(want)], want, rtol=0)
    np.testing.assert_array_equal(np.asarray(arr.sizes), [len(b) for b in oracle])


def test_positions_returned_are_the_read_back_indices():
    arr = gg.init(2, 2, nbuckets=4)
    elems = jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]])
    arr, pos = gg.push_back(arr, elems)
    blocks = jnp.asarray([[0, 0, 0], [1, 1, 1]])
    got = gg.gather_block(arr, blocks, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(elems))


def test_grow_is_copy_free_and_preserves_content():
    arr = gg.init(2, 2, nbuckets=1)
    arr, _ = gg.push_back(arr, jnp.asarray([[1.0, 2.0], [3.0, 4.0]]))
    old_buckets = arr.buckets
    grown = gg.grow(arr, 2)
    # same bucket objects, not copies — the paper's no-move property
    for a, b in zip(old_buckets, grown.buckets):
        assert a is b
    assert grown.nbuckets == 3
    flat, total = gg.flatten(grown)
    np.testing.assert_allclose(np.asarray(flat)[:4], [1, 2, 3, 4])


def test_rw_global_binary_search():
    nblocks = 3
    arr = gg.init(nblocks, 2, nbuckets=4)
    sizes = [5, 1, 7]
    for b, n in enumerate(sizes):
        elems = jnp.arange(n, dtype=jnp.float32)[None] + 100 * b
        mask = jnp.ones((1, n), bool)
        pad_elems = jnp.zeros((nblocks, n))
        pad_mask = jnp.zeros((nblocks, n), bool)
        pad_elems = pad_elems.at[b].set(elems[0])
        pad_mask = pad_mask.at[b].set(mask[0])
        arr, _ = gg.push_back(arr, pad_elems, pad_mask)
    want = np.concatenate([100 * b + np.arange(n) for b, n in enumerate(sizes)])
    idx = jnp.arange(sum(sizes))
    got = gg.read_global(arr, idx)
    np.testing.assert_allclose(np.asarray(got), want)
    # write_global roundtrip
    arr2 = gg.write_global(arr, idx, jnp.asarray(want * 2.0))
    np.testing.assert_allclose(np.asarray(gg.read_global(arr2, idx)), want * 2.0)


def test_map_elements_touches_only_live_slots():
    arr = gg.init(2, 2, nbuckets=3)
    arr, _ = gg.push_back(arr, jnp.asarray([[1.0], [2.0]]))
    out = gg.map_elements(arr, lambda x: x + 10.0)
    flat, total = gg.flatten(out)
    np.testing.assert_allclose(np.asarray(flat)[:2], [11.0, 12.0])
    # dead capacity slots stay zero
    assert float(jnp.sum(jnp.abs(flat))) == pytest.approx(23.0)


def test_from_flat_roundtrip():
    flat_in = jnp.arange(37, dtype=jnp.float32)
    arr = gg.from_flat(flat_in, 37, nblocks=4, b0=2)
    flat, total = gg.flatten(arr)
    assert int(total) == 37
    np.testing.assert_allclose(np.sort(np.asarray(flat)[:37]), np.asarray(flat_in))


@given(st.integers(1, 64), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_property_memory_bound(n_per_block, b0):
    """Paper §V: allocated capacity stays < 2×size + B0 per block."""
    nbuckets = indexing.min_buckets_for(b0, n_per_block)
    cap = indexing.capacity(b0, max(nbuckets, 1))
    assert cap >= n_per_block
    assert cap < 2 * n_per_block + b0


@given(st.lists(st.integers(1, 9), min_size=1, max_size=6), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_property_push_waves_preserve_order(waves, seed):
    rng = np.random.default_rng(seed)
    nblocks = 2
    arr = gg.init(nblocks, 2)
    oracle = [[] for _ in range(nblocks)]
    for m in waves:
        elems = rng.standard_normal((nblocks, m)).astype(np.float32)
        mask = rng.random((nblocks, m)) < 0.6
        arr = gg.ensure_capacity(arr, m)
        arr, _ = gg.push_back(arr, jnp.asarray(elems), jnp.asarray(mask))
        _oracle_push(oracle, elems, mask)
    flat, total = gg.flatten(arr)
    want = [x for blk in oracle for x in blk]
    np.testing.assert_allclose(np.asarray(flat)[: len(want)], want, rtol=0, atol=0)


def test_item_shape_payloads():
    """Vector payloads (the KV-cache use case: items are (heads, dim) slabs)."""
    arr = gg.init(2, 2, item_shape=(3, 4), dtype=jnp.bfloat16, nbuckets=2)
    elems = jnp.ones((2, 2, 3, 4), jnp.bfloat16)
    arr, pos = gg.push_back(arr, elems)
    flat, total = gg.flatten(arr)
    assert flat.shape == (2 * arr.capacity_per_block, 3, 4)
    assert int(total) == 4
    np.testing.assert_allclose(np.asarray(flat[:2], np.float32), 1.0)


# --------------------------------------------------------------------------
# The host-sync-free append contract (DESIGN.md §2 growth protocol).
# --------------------------------------------------------------------------


def test_append_donates_input_buffers():
    """A donated append consumes its input: the old buffers are deleted."""
    arr = gg.init(2, 4, nbuckets=2)
    old_bucket, old_sizes = arr.buckets[0], arr.sizes
    new, pos, headroom = gg.append(arr, jnp.ones((2, 3)))
    assert old_bucket.is_deleted(), "bucket level must be donated to the append"
    assert old_sizes.is_deleted(), "sizes vector must be donated to the append"
    # the returned array is live and correct
    np.testing.assert_array_equal(np.asarray(new.sizes), [3, 3])
    assert int(headroom) == new.capacity_per_block - 3


def test_append_headroom_flag_tracks_capacity():
    arr = gg.init(2, 4, nbuckets=1)  # capacity 4/block
    arr, _, hd = gg.append(arr, jnp.ones((2, 3)))
    assert int(hd) == 1
    arr, _, hd = gg.append(arr, jnp.ones((2, 2)))
    assert int(hd) == -1, "negative headroom must signal dropped writes"


def test_steady_state_append_performs_zero_host_transfers(monkeypatch):
    """Planner + donated append: the steady-state loop never contacts the host.

    ``transfer_guard('disallow')`` enforces the no-implicit-transfer contract
    at the JAX runtime level; because a CPU-only backend never performs a
    physical copy (the guard cannot fire), a ``jax.device_get`` spy
    additionally proves the protocol issues zero explicit scalar reads.
    """
    calls = {"n": 0}
    real_get = jax.device_get

    def spy(x):
        calls["n"] += 1
        return real_get(x)

    arr = gg.init(4, 8, nbuckets=4)  # capacity 120/block
    planner = gg.CapacityPlanner()
    elems = jnp.ones((4, 5))
    # warm the executable outside the guarded region (compile-time constants
    # may legitimately transfer)
    arr = planner.reserve(arr, 5)
    arr, _, hd = gg.append(arr, elems)
    planner.note_append(arr, hd)

    monkeypatch.setattr(jax, "device_get", spy)
    with jax.transfer_guard("disallow"):
        for _ in range(10):
            arr = planner.reserve(arr, 5)
            arr, pos, hd = gg.append(arr, elems)
            planner.note_append(arr, hd)
    assert calls["n"] == 0, "steady-state appends must not read device memory"
    assert planner.host_syncs == 0
    np.testing.assert_array_equal(np.asarray(arr.sizes), [55, 55, 55, 55])


def test_planner_host_contacts_stay_logarithmic():
    """Growing 0 → n by waves of m costs O(log n) scalar reads, not O(n/m)."""
    arr = gg.init(2, 4, nbuckets=1)
    planner = gg.CapacityPlanner()
    waves = 64
    for _ in range(waves):
        arr = planner.reserve(arr, 4)
        arr, _, hd = gg.append(arr, jnp.ones((2, 4)))
        planner.note_append(arr, hd)
    assert int(jnp.max(arr.sizes)) == waves * 4
    # every host contact coincides with a (geometric) growth decision
    assert planner.host_syncs <= arr.nbuckets + 1
    assert planner.host_syncs < waves // 4


def test_planner_recovers_true_size_after_masked_waves():
    """Masked-out lanes only make the bound pessimistic, never wrong."""
    arr = gg.init(2, 2, nbuckets=1)
    planner = gg.CapacityPlanner()
    none = jnp.zeros((2, 2), bool)
    for _ in range(8):  # all-masked waves: ub inflates, true size stays 0
        arr = planner.reserve(arr, 2)
        arr, _, hd = gg.append(arr, jnp.ones((2, 2)), none)
        planner.note_append(arr, hd)
    np.testing.assert_array_equal(np.asarray(arr.sizes), [0, 0])
    # the bound was reset from the headroom flag at least once
    assert planner.size_ub <= 2 + 2 * arr.capacity_per_block
    arr = planner.reserve(arr, 2)
    arr, pos, _ = gg.append(arr, jnp.asarray([[1.0, 2.0], [3.0, 4.0]]))
    np.testing.assert_array_equal(np.asarray(pos), [[0, 1], [0, 1]])


def test_reserve_with_host_bound_matches_ensure_capacity():
    arr = gg.init(2, 2, nbuckets=1)
    arr, _ = gg.push_back(arr, jnp.ones((2, 2)))
    a = gg.ensure_capacity(arr, 5)  # device read
    b = gg.reserve(arr, 5, max_size=2)  # host-known bound, no read
    assert a.nbuckets == b.nbuckets
    assert a.capacity_per_block >= 2 + 5


def test_push_back_rejects_float_mask():
    arr = gg.init(2, 2, nbuckets=2)
    with pytest.raises(TypeError):
        gg.push_back(arr, jnp.ones((2, 2)), jnp.ones((2, 2), jnp.float32))
