"""Single LFVector (paper Algs. 1–2) semantics."""
import jax.numpy as jnp
import numpy as np

from repro.core import LFVector


def test_push_back_grow_and_read():
    v = LFVector.create(b0=2)
    idx = v.push_back(jnp.asarray([1.0, 2.0, 3.0]))
    np.testing.assert_array_equal(np.asarray(idx), [0, 1, 2])
    assert len(v) == 3
    assert v.nbuckets >= 2  # grew past the first bucket (B0=2)
    np.testing.assert_allclose(np.asarray(v.to_array()), [1, 2, 3])


def test_setitem_getitem():
    v = LFVector.create(b0=2)
    v.push_back(jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0]))
    v[2] = 30.0
    assert float(v[2]) == 30.0
    np.testing.assert_allclose(np.asarray(v.to_array()), [1, 2, 30, 4, 5])


def test_capacity_bound_matches_paper():
    v = LFVector.create(b0=4)
    for wave in range(6):
        v.push_back(jnp.ones((7,), jnp.float32))
    n = len(v)
    assert v.capacity < 2 * n + 4  # §V bound
