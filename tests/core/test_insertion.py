"""Insertion-index algorithms (paper §III.B): all three must agree exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # property tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core.insertion import INSERTION_METHODS, insertion_offsets

METHODS = sorted(INSERTION_METHODS)


def _ref_offsets(mask: np.ndarray):
    inc = np.cumsum(mask.astype(np.int32), axis=-1)
    return inc - mask.astype(np.int32), inc[:, -1]


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("shape", [(1, 1), (1, 7), (3, 64), (5, 130), (8, 256), (2, 1000)])
def test_matches_reference(method, shape):
    rng = np.random.default_rng(hash((method, shape)) % 2**32)
    mask = rng.random(shape) < 0.5
    off, cnt = insertion_offsets(jnp.asarray(mask), method=method)
    ref_off, ref_cnt = _ref_offsets(mask)
    np.testing.assert_array_equal(np.where(mask, np.asarray(off), 0), np.where(mask, ref_off, 0))
    np.testing.assert_array_equal(np.asarray(cnt), ref_cnt)


@pytest.mark.parametrize("method", METHODS)
def test_offsets_unique_and_dense(method):
    """Each inserter gets a unique index in [0, count) — the paper's invariant."""
    rng = np.random.default_rng(0)
    mask = rng.random((4, 97)) < 0.3
    off, cnt = insertion_offsets(jnp.asarray(mask), method=method)
    off, cnt = np.asarray(off), np.asarray(cnt)
    for b in range(mask.shape[0]):
        got = np.sort(off[b][mask[b]])
        np.testing.assert_array_equal(got, np.arange(cnt[b]))


@given(
    st.integers(1, 6),
    st.integers(1, 300),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_property_methods_agree(nblocks, m, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random((nblocks, m)) < rng.random())
    outs = {meth: insertion_offsets(mask, method=meth) for meth in METHODS}
    base_off, base_cnt = outs[METHODS[0]]
    for meth in METHODS[1:]:
        off, cnt = outs[meth]
        valid = np.asarray(mask)
        np.testing.assert_array_equal(
            np.where(valid, np.asarray(off), 0), np.where(valid, np.asarray(base_off), 0),
            err_msg=f"{meth} offsets diverge",
        )
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(base_cnt))


def test_rejects_bad_rank_and_method():
    with pytest.raises(ValueError):
        insertion_offsets(jnp.ones((3,), bool))
    with pytest.raises(ValueError):
        insertion_offsets(jnp.ones((1, 3), bool), method="nope")


@pytest.mark.parametrize("method", METHODS)
def test_integer_mask_counts_lanes_not_values(method):
    """An int mask of 3s is two truthy *lanes*, not six inserts."""
    mask = jnp.asarray([[3, 0, 7], [0, 0, 1]], jnp.int32)
    off, cnt = insertion_offsets(mask, method=method)
    np.testing.assert_array_equal(np.asarray(cnt), [2, 1])
    ref_off, _ = _ref_offsets(np.asarray(mask) != 0)
    valid = np.asarray(mask) != 0
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(off), 0), np.where(valid, ref_off, 0)
    )


@pytest.mark.parametrize("method", METHODS)
def test_empty_wave_m0(method):
    """m=0 waves are legal: empty offsets, zero counts — for every backend."""
    off, cnt = insertion_offsets(jnp.zeros((3, 0), bool), method=method)
    assert off.shape == (3, 0)
    np.testing.assert_array_equal(np.asarray(cnt), [0, 0, 0])


def test_float_mask_rejected():
    with pytest.raises(TypeError):
        insertion_offsets(jnp.ones((1, 3), jnp.float32))
