"""Flash attention + flash-decode kernels vs exact softmax oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as dec_ops
from repro.kernels.decode_attention import ref as dec_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("BH,Sq,Skv,D,group", [(2, 128, 128, 64, 1), (4, 256, 256, 32, 2), (2, 64, 128, 128, 1)])
def test_flash_matches_ref(causal, BH, Sq, Skv, D, group):
    if causal and Sq != Skv:
        pytest.skip("causal requires square for this oracle")
    rng = np.random.default_rng(hash((causal, BH, Sq, Skv, D, group)) % 2**32)
    q = jnp.asarray(rng.standard_normal((BH, Sq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH // group, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH // group, Skv, D)), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, group=group, causal=causal, bq=64, bk=64)
    want = fa_ref.attention(q, k, v, group=group, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 128, 64)), dtype)
    k = jnp.asarray(rng.standard_normal((2, 128, 64)), dtype)
    v = jnp.asarray(rng.standard_normal((2, 128, 64)), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = fa_ref.attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("B,H,KH,S,D", [(2, 8, 2, 256, 64), (1, 4, 4, 512, 32), (3, 16, 2, 128, 128)])
def test_decode_matches_ref_partial_lengths(B, H, KH, S, D):
    rng = np.random.default_rng(hash((B, H, KH, S, D)) % 2**32)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KH, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, KH, S, D)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, (B,)), jnp.int32)
    got = dec_ops.decode_attention(q, k, v, lengths, bk=64)
    want = dec_ref.decode_attention(
        q.reshape(B, KH, H // KH, D), k, v, lengths
    ).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_decode_ignores_dead_cache_tail():
    """Garbage past the live length must not leak into the output."""
    B, H, KH, S, D = 1, 4, 2, 128, 32
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, KH, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, KH, S, D)), jnp.float32)
    live = 40
    k_dirty = k.at[:, :, live:].set(1e6)
    v_dirty = v.at[:, :, live:].set(-1e6)
    lengths = jnp.asarray([live], jnp.int32)
    clean = dec_ops.decode_attention(q, k, v, lengths, bk=64)
    dirty = dec_ops.decode_attention(q, k_dirty, v_dirty, lengths, bk=64)
    np.testing.assert_allclose(np.asarray(clean), np.asarray(dirty), rtol=1e-5, atol=1e-5)
