"""Scan kernels (scan_mxu, scan_tile) vs pure-jnp oracle — shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.scan_mxu import ops as mxu_ops
from repro.kernels.scan_mxu import ref as mxu_ref
from repro.kernels.scan_tile import ops as tile_ops

SHAPES = [(1, 1), (1, 128), (3, 100), (8, 256), (5, 513), (16, 1024), (2, 4096)]
DTYPES = [jnp.int32, jnp.float32]


@pytest.mark.parametrize("impl", ["mxu", "tile"])
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_row_scan_matches_ref(impl, shape, dtype):
    rng = np.random.default_rng(hash((impl, shape, str(dtype))) % 2**32)
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(0, 2, shape), dtype)  # insertion-mask regime
    else:
        x = jnp.asarray(rng.standard_normal(shape), dtype)
    ops = mxu_ops if impl == "mxu" else tile_ops
    got = ops.row_scan(x)
    want = mxu_ref.row_scan(x)
    assert got.shape == want.shape and got.dtype == want.dtype
    if dtype == jnp.int32:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        # matmul-scan reduction order differs from cumsum → f32 rounding skew
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_mxu_scan_exact_for_large_mask_rows():
    """Carry path stays exact (int32) well past f32's 2^24 window per tile."""
    n = 1 << 15
    x = jnp.ones((1, n), jnp.int32)
    got = mxu_ops.row_scan(x)
    assert int(got[0, -1]) == n


def test_scan_is_per_row_independent():
    x = jnp.asarray([[1, 1, 1, 1], [0, 1, 0, 1]], jnp.int32)
    got = np.asarray(mxu_ops.row_scan(x))
    np.testing.assert_array_equal(got, [[1, 2, 3, 4], [0, 1, 1, 2]])
