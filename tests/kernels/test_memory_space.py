"""Memory-space parity: vmem and hbm tilings vs the jnp oracles, bit-exact.

The three indirection kernel families (paged, push_back, flatten) each run
under two ``GridPlan`` tilings (kernels/common): all-VMEM-resident and
HBM-resident with scalar-prefetch tables.  Both must be **bit-identical** to
the jnp references across dtypes and ragged shapes — the deterministic
matrix below pins a curated grid; the hypothesis properties fuzz it.

The dispatch sweep additionally pins the MXU dispatch-matmul permutation
(``dispatch="mxu"``) to the exact one-hot path across the
``MXU_DISPATCH_WAVE`` threshold.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # property tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.core import ggarray as gg
from repro.core import indexing
from repro.kernels import common
from repro.kernels.flatten import ops as flatten_ops
from repro.kernels.paged import ops as paged_ops
from repro.kernels.push_back import ops as pb_ops

SPACES = ["vmem", "hbm"]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]


def _values(rng, shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.integers(-1000, 1000, shape), dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _assert_trees_equal(got, want, msg):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=msg)


# --------------------------------------------------------------------------
# resolve helpers
# --------------------------------------------------------------------------

def test_resolve_memory_space_contract(monkeypatch):
    monkeypatch.delenv("REPRO_MEMORY_SPACE", raising=False)
    assert common.resolve_memory_space("hbm") == "hbm"
    assert common.resolve_memory_space("vmem") == "vmem"
    # interpret mode (this container) defaults to vmem…
    assert common.resolve_memory_space(None, None) == "vmem"
    # …explicit non-interpret defaults to hbm (the TPU serving default)
    monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    assert common.resolve_memory_space(None, False) == "hbm"
    # env overrides the default but not an explicit argument
    monkeypatch.setenv("REPRO_MEMORY_SPACE", "hbm")
    assert common.resolve_memory_space(None, True) == "hbm"
    assert common.resolve_memory_space("vmem", True) == "vmem"
    with pytest.raises(ValueError):
        common.resolve_memory_space("smem")


def test_resolve_dispatch_threshold():
    thr = common.MXU_DISPATCH_WAVE
    assert common.resolve_dispatch("auto", thr - 1, jnp.float32) == "onehot"
    assert common.resolve_dispatch("auto", thr, jnp.float32) == "mxu"
    assert common.resolve_dispatch("auto", thr, jnp.bfloat16) == "mxu"
    assert common.resolve_dispatch("auto", thr, jnp.int16) == "mxu"
    # wide ints / f64 can exceed the f32 mantissa the MXU accumulates in
    assert common.resolve_dispatch("auto", thr, jnp.int32) == "onehot"
    assert common.resolve_dispatch("auto", thr, jnp.float64) == "onehot"
    assert common.resolve_dispatch("mxu", 1, jnp.float32) == "mxu"
    assert common.resolve_dispatch("onehot", 10 * thr, jnp.float32) == "onehot"


# --------------------------------------------------------------------------
# deterministic parity matrix (runs without hypothesis)
# --------------------------------------------------------------------------

def _fleet(rng, S, N, P, npages):
    pages = np.full((N, P), -1, np.int32)
    perm = rng.permutation(S)
    k = 0
    for i, c in enumerate(npages):
        for p in range(c):
            pages[i, p] = perm[k]
            k += 1
    return jnp.asarray(pages)


@pytest.mark.parametrize("space", SPACES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
@pytest.mark.parametrize("shape", [(9, 3, 5, 3), (8, 4, 4, 1), (5, 2, 3, 4)])
def test_paged_gather_parity(space, dtype, shape):
    S, T, N, P = shape
    rng = np.random.default_rng(zlib.crc32(repr((space, str(dtype), shape)).encode()))
    pool = _values(rng, (S, T, 2), dtype)
    npages = rng.integers(0, P + 1, N)
    npages[0] = min(P, S // max(N, 1))
    pages = _fleet(rng, S, N, P, np.minimum(npages, S // max(N, 1)))
    got = paged_ops.paged_gather(pool, pages, memory_space=space)
    want = paged_ops.paged_gather(pool, pages, use_ref=True)
    _assert_trees_equal(got, want, f"gather {space} {dtype} {shape}")


@pytest.mark.parametrize("space", SPACES)
@pytest.mark.parametrize("lengths", [[9, 2, 8, 1, 12], [1, 1, 1, 1, 1], [0, 5, 0, 3, 7]])
def test_paged_attend_parity(space, lengths):
    rng = np.random.default_rng(zlib.crc32(repr((space, lengths)).encode()))
    S, T, N, P = 13, 4, 5, 3
    KH, G, D = 2, 3, 8
    pages = _fleet(rng, S, N, P, [3, 1, 2, 1, 3])
    kp = jnp.asarray(rng.standard_normal((S, T, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((S, T, KH, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((N, KH, G, D)), jnp.float32)
    lengths = jnp.asarray(lengths, jnp.int32)
    got = paged_ops.paged_attend(q, kp, vp, pages, lengths, memory_space=space)
    want = paged_ops.paged_attend(q, kp, vp, pages, lengths, use_ref=True)
    _assert_trees_equal(got, want, f"attend {space}")


def _ownership(pages, S, T):
    owners = np.full((S,), -1, np.int32)
    bases = np.zeros((S,), np.int32)
    pg = np.asarray(pages)
    for i in range(pg.shape[0]):
        for p in range(pg.shape[1]):
            if pg[i, p] >= 0:
                owners[pg[i, p]] = i
                bases[pg[i, p]] = p * T
    return jnp.asarray(owners), jnp.asarray(bases)


@pytest.mark.parametrize("space", SPACES)
@pytest.mark.parametrize("dispatch", ["onehot", "mxu"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
def test_slab_append_parity(space, dispatch, dtype):
    rng = np.random.default_rng(zlib.crc32(repr((space, dispatch, str(dtype))).encode()))
    S, T, N, P, m = 14, 4, 4, 4, 5
    pages = _fleet(rng, S, N, P, [4, 2, 3, 4])
    owners, bases = _ownership(pages, S, T)
    sizes = jnp.asarray([7, 1, 5, 10], jnp.int32)
    pool = _values(rng, (S, T, 3), dtype)
    elems = _values(rng, (N, m, 3), dtype)
    mask = jnp.asarray(rng.random((N, m)) > 0.4)
    args = (pool, owners, bases, sizes, elems, mask)
    got = paged_ops.slab_append(*args, memory_space=space, dispatch=dispatch)
    want = paged_ops.slab_append(*args, use_ref=True)
    _assert_trees_equal(got, want, f"slab_append {space} {dispatch}")


@pytest.mark.parametrize("space", SPACES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
@pytest.mark.parametrize("nblocks,b0,nlev,m", [(5, 3, 3, 7), (8, 1, 4, 2), (3, 4, 2, 11)])
def test_push_back_parity(space, dtype, nblocks, b0, nlev, m):
    rng = np.random.default_rng(
        zlib.crc32(repr((space, str(dtype), nblocks, b0, nlev, m)).encode())
    )
    arr = gg.init(nblocks, b0, dtype=dtype, nbuckets=nlev)
    elems = _values(rng, (nblocks, m), dtype)
    mask = jnp.asarray(rng.random((nblocks, m)) > 0.3)
    sizes = jnp.asarray(
        rng.integers(0, indexing.capacity(b0, nlev) + 1, nblocks), jnp.int32
    )
    got = pb_ops.push_back_fused(
        arr.buckets, sizes, b0, elems, mask, memory_space=space
    )
    want = pb_ops.push_back_fused(arr.buckets, sizes, b0, elems, mask, use_ref=True)
    _assert_trees_equal(got, want, f"push_back {space} {dtype}")


@pytest.mark.parametrize("space", SPACES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
@pytest.mark.parametrize("nblocks,b0,nlev", [(4, 2, 3), (5, 3, 3), (13, 1, 5), (3, 2, 4)])
def test_flatten_parity(space, dtype, nblocks, b0, nlev):
    rng = np.random.default_rng(
        zlib.crc32(repr((space, str(dtype), nblocks, b0, nlev)).encode())
    )
    arr = gg.init(nblocks, b0, dtype=dtype, nbuckets=nlev)
    per = rng.integers(0, indexing.capacity(b0, nlev) + 1, nblocks)
    m = max(int(per.max()), 1)
    elems = _values(rng, (nblocks, m), dtype)
    mask = jnp.asarray(np.arange(m)[None, :] < per[:, None])
    arr, _ = gg.push_back(arr, elems, mask)
    got = flatten_ops.flatten_segmented(
        arr.buckets, arr.sizes, arr.b0, memory_space=space
    )
    want = flatten_ops.flatten_segmented(
        arr.buckets, arr.sizes, arr.b0, use_ref=True
    )
    _assert_trees_equal(got, want, f"flatten {space} {dtype}")


# --------------------------------------------------------------------------
# MXU dispatch-matmul vs one-hot permutation across the wave threshold
# --------------------------------------------------------------------------

@pytest.mark.parametrize("space", SPACES)
@pytest.mark.parametrize(
    "m", [4, common.MXU_DISPATCH_WAVE - 1, common.MXU_DISPATCH_WAVE, 200]
)
def test_mxu_dispatch_matches_onehot_across_threshold(space, m):
    rng = np.random.default_rng(zlib.crc32(repr((space, m)).encode()))
    nblocks, b0, nlev = 4, 8, 4
    arr = gg.init(nblocks, b0, dtype=jnp.float32, nbuckets=nlev)
    elems = jnp.asarray(rng.standard_normal((nblocks, m)), jnp.float32)
    mask = jnp.asarray(rng.random((nblocks, m)) > 0.25)
    sizes = jnp.asarray(rng.integers(0, 2 * b0, nblocks), jnp.int32)
    outs = {
        d: pb_ops.push_back_fused(
            arr.buckets, sizes, b0, elems, mask, memory_space=space, dispatch=d
        )
        for d in ("onehot", "mxu", "auto")
    }
    _assert_trees_equal(outs["mxu"], outs["onehot"], f"mxu vs onehot m={m} {space}")
    _assert_trees_equal(outs["auto"], outs["onehot"], f"auto m={m} {space}")


# --------------------------------------------------------------------------
# hypothesis fuzzing (skips gracefully without hypothesis; CI runs in full)
# --------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_property_push_back_spaces_bitwise(seed):
    """Any (space, dtype, ragged sizes, wave) → fused == oracle, both spaces."""
    rng = np.random.default_rng(seed)
    nblocks = int(rng.integers(1, 10))
    b0 = int(rng.integers(1, 6))
    nlev = int(rng.integers(1, 5))
    m = int(rng.integers(1, 24))
    dtype = DTYPES[int(rng.integers(0, len(DTYPES)))]
    arr = gg.init(nblocks, b0, dtype=dtype, nbuckets=nlev)
    elems = _values(rng, (nblocks, m), dtype)
    mask = jnp.asarray(rng.random((nblocks, m)) > rng.random())
    sizes = jnp.asarray(
        rng.integers(0, indexing.capacity(b0, nlev) + 2, nblocks), jnp.int32
    )
    want = pb_ops.push_back_fused(arr.buckets, sizes, b0, elems, mask, use_ref=True)
    for space in SPACES:
        got = pb_ops.push_back_fused(
            arr.buckets, sizes, b0, elems, mask, memory_space=space
        )
        _assert_trees_equal(got, want, f"push_back seed={seed} {space}")


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_property_paged_spaces_bitwise(seed):
    """Any (space, dtype, fleet layout, wave) → paged kernels == oracles."""
    rng = np.random.default_rng(seed)
    N = int(rng.integers(1, 7))
    P = int(rng.integers(1, 5))
    T = int(rng.integers(1, 6))
    S = N * P + int(rng.integers(0, 5))
    m = int(rng.integers(1, 12))
    dtype = DTYPES[int(rng.integers(0, len(DTYPES)))]
    pages = _fleet(rng, S, N, P, rng.integers(0, P + 1, N))
    pool = _values(rng, (S, T, 2), dtype)
    owners, bases = _ownership(pages, S, T)
    sizes = jnp.asarray(rng.integers(0, P * T + 1, N), jnp.int32)
    elems = _values(rng, (N, m, 2), dtype)
    mask = jnp.asarray(rng.random((N, m)) > rng.random())
    gather_want = paged_ops.paged_gather(pool, pages, use_ref=True)
    ap_args = (pool, owners, bases, sizes, elems, mask)
    append_want = paged_ops.slab_append(*ap_args, use_ref=True)
    for space in SPACES:
        got = paged_ops.paged_gather(pool, pages, memory_space=space)
        _assert_trees_equal(got, gather_want, f"gather seed={seed} {space}")
        got = paged_ops.slab_append(*ap_args, memory_space=space)
        _assert_trees_equal(got, append_want, f"append seed={seed} {space}")


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_property_flatten_spaces_bitwise(seed):
    """Any (space, dtype, ragged fill) → segmented flatten == oracle."""
    rng = np.random.default_rng(seed)
    nblocks = int(rng.integers(1, 14))
    b0 = int(rng.integers(1, 5))
    nlev = int(rng.integers(1, 5))
    dtype = DTYPES[int(rng.integers(0, len(DTYPES)))]
    arr = gg.init(nblocks, b0, dtype=dtype, nbuckets=nlev)
    per = rng.integers(0, indexing.capacity(b0, nlev) + 1, nblocks)
    m = max(int(per.max()), 1)
    elems = _values(rng, (nblocks, m), dtype)
    mask = jnp.asarray(np.arange(m)[None, :] < per[:, None])
    arr, _ = gg.push_back(arr, elems, mask)
    want = flatten_ops.flatten_segmented(arr.buckets, arr.sizes, arr.b0, use_ref=True)
    for space in SPACES:
        got = flatten_ops.flatten_segmented(
            arr.buckets, arr.sizes, arr.b0, memory_space=space
        )
        _assert_trees_equal(got, want, f"flatten seed={seed} {space}")
