"""Crossover regression: every "auto" resolver pins to kernels/tuning.py.

The measured thresholds live in ONE module (``repro.kernels.tuning``); the
kernels' ``"auto"`` resolvers and the benchmark sweeps both import from it.
These tests pin (a) the committed values — so a re-tune is a deliberate,
reviewed edit here and there together, never a silent drift — (b) the
resolver routing on both sides of each crossover, and (c) that the
``"auto"`` route is numerically identical to the path it resolves to (the
whole point of a *resolver*: auto changes speed, never values).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ggarray as gg
from repro.kernels import common, tuning


def test_committed_thresholds():
    # Re-measured for this revision (interpret mode; see tuning.py docstring
    # for the sweep numbers).  Edit tuning.py AND this pin together.
    assert tuning.FUSED_PUSH_BACK_MIN_WAVE == 32
    assert tuning.MXU_DISPATCH_WAVE == 256
    # common.py re-exports the tuning value — one source of truth
    assert common.MXU_DISPATCH_WAVE == tuning.MXU_DISPATCH_WAVE


@pytest.mark.parametrize(
    "m,want",
    [
        (1, "scan"),  # the serving decode append — one lane per sequence
        (31, "scan"),
        (32, "fused"),
        (512, "fused"),
    ],
)
def test_push_back_auto_routes_on_wave_width(m, want):
    assert tuning.resolve_push_back_method("auto", m) == want


def test_push_back_explicit_methods_pass_through():
    assert tuning.resolve_push_back_method("scan", 10**9) == "scan"
    assert tuning.resolve_push_back_method("fused", 1) == "fused"


@pytest.mark.parametrize(
    "m,dtype,want",
    [
        (255, jnp.float32, "onehot"),  # below the crossover
        (256, jnp.float32, "mxu"),
        (256, jnp.bfloat16, "mxu"),
        (256, jnp.int8, "mxu"),
        (256, jnp.int32, "onehot"),  # wide ints exceed the f32 mantissa
        (4096, jnp.int32, "onehot"),
    ],
)
def test_dispatch_auto_routes_on_wave_and_dtype(m, dtype, want):
    assert common.resolve_dispatch("auto", m, dtype) == want


def test_dispatch_explicit_methods_pass_through():
    assert common.resolve_dispatch("onehot", 10**9, jnp.float32) == "onehot"
    assert common.resolve_dispatch("mxu", 1, jnp.float64) == "mxu"


def _wave(rng, nblocks, m):
    elems = jnp.asarray(rng.standard_normal((nblocks, m)), jnp.float32)
    mask = jnp.asarray(rng.random((nblocks, m)) < 0.6)
    return elems, mask


@pytest.mark.parametrize("m", [1, 31, 32, 40])
def test_auto_push_back_bit_exact_across_the_crossover(m):
    """auto == scan == fused values on waves straddling the threshold —
    m=1 is the decode append that the re-tune moved back to scan."""
    rng = np.random.default_rng(m)
    arrs = {meth: gg.init(4, 4, dtype=jnp.float32, nbuckets=1) for meth in
            ("auto", "scan", "fused")}
    pos = {}
    for meth in arrs:
        arr = gg.ensure_capacity(arrs[meth], m)
        rng2 = np.random.default_rng(m)  # same wave for every method
        elems, mask = _wave(rng2, 4, m)
        arrs[meth], pos[meth] = gg.push_back(arr, elems, mask, method=meth)
    for meth in ("scan", "fused"):
        np.testing.assert_array_equal(np.asarray(pos["auto"]), np.asarray(pos[meth]))
        for a, b in zip(arrs["auto"].buckets, arrs[meth].buckets):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(arrs["auto"].sizes), np.asarray(arrs[meth].sizes)
        )
