"""Paged kernels (gather / attend / slab-append) vs their jnp oracles.

All comparisons are exact (``assert_array_equal``): interpret-mode kernels
mirror the references op-for-op, so any drift is a real indexing bug.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.paged import ops


def _fleet(rng, S, T, N, P, npages):
    """Disjoint random slab assignment for N arrays."""
    pages = np.full((N, P), -1, np.int32)
    perm = rng.permutation(S)
    k = 0
    for i, c in enumerate(npages):
        for p in range(c):
            pages[i, p] = perm[k]
            k += 1
    return jnp.asarray(pages)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("item", [(), (3,), (2, 2)])
def test_paged_gather_matches_ref(dtype, item):
    rng = np.random.default_rng(0)
    S, T, N, P = 11, 4, 5, 3
    pool = jnp.asarray(
        rng.integers(-50, 50, (S, T, *item)).astype(np.dtype(dtype))
    )
    pages = _fleet(rng, S, T, N, P, [3, 0, 2, 1, 3])
    got = ops.paged_gather(pool, pages)
    want = ops.paged_gather(pool, pages, use_ref=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # page −1 reads as zeros
    assert not np.asarray(got)[1].any()


@pytest.mark.parametrize("lengths", [[9, 2, 8, 1, 12], [1, 1, 1, 1, 1]])
def test_paged_attend_matches_ref_bitwise(lengths):
    rng = np.random.default_rng(1)
    S, T, N, P = 13, 4, 5, 3
    KH, G, D = 2, 3, 8
    pages = _fleet(rng, S, T, N, P, [3, 1, 2, 1, 3])
    kp = jnp.asarray(rng.standard_normal((S, T, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((S, T, KH, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((N, KH, G, D)), jnp.float32)
    lengths = jnp.asarray(lengths, jnp.int32)
    got = ops.paged_attend(q, kp, vp, pages, lengths)
    want = ops.paged_attend(q, kp, vp, pages, lengths, use_ref=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("item", [(), (2, 3)])
@pytest.mark.parametrize("masked", [False, True])
def test_slab_append_matches_ref_bitwise(item, masked):
    rng = np.random.default_rng(2)
    S, T, N, P, m = 14, 4, 4, 4, 3
    npages = [4, 2, 3, 4]
    pages = np.asarray(_fleet(rng, S, T, N, P, npages))
    owners = np.full((S,), -1, np.int32)
    bases = np.zeros((S,), np.int32)
    for i in range(N):
        for p in range(P):
            if pages[i, p] >= 0:
                owners[pages[i, p]] = i
                bases[pages[i, p]] = p * T
    sizes = np.asarray([7, 1, 5, 10], np.int32)
    pool = jnp.asarray(rng.standard_normal((S, T, *item)), jnp.float32)
    elems = jnp.asarray(rng.standard_normal((N, m, *item)), jnp.float32)
    mask = jnp.asarray(rng.random((N, m)) > 0.4 if masked else np.ones((N, m), bool))
    args = (pool, jnp.asarray(owners), jnp.asarray(bases), jnp.asarray(sizes), elems, mask)
    got = ops.slab_append(*args)
    want = ops.slab_append(*args, use_ref=True)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # round-trip: gathering back reads the wave at the assigned positions
    new_pool, new_sizes, pos = got
    view = np.asarray(ops.paged_gather(new_pool, jnp.asarray(pages)))
    pos_np, mask_np = np.asarray(pos), np.asarray(mask)
    for i in range(N):
        for lane in range(m):
            if mask_np[i, lane]:
                np.testing.assert_array_equal(
                    view[i, pos_np[i, lane]], np.asarray(elems[i, lane])
                )


def test_slab_append_leaves_unowned_slabs_untouched():
    rng = np.random.default_rng(3)
    S, T, N, m = 10, 4, 2, 5
    pool = jnp.asarray(rng.standard_normal((S, T)), jnp.float32)
    owners = np.full((S,), -1, np.int32)
    owners[4] = 0  # only slab 4 owned
    bases = np.zeros((S,), np.int32)
    sizes = jnp.zeros((N,), jnp.int32)
    elems = jnp.ones((N, m), jnp.float32) * 9.0
    mask = jnp.asarray(np.asarray([[True] * 4 + [False], [True] * 5]))
    new_pool, new_sizes, _ = ops.slab_append(
        pool, jnp.asarray(owners), jnp.asarray(bases), sizes, elems, mask
    )
    before, after = np.asarray(pool), np.asarray(new_pool)
    untouched = [s for s in range(S) if s != 4]
    np.testing.assert_array_equal(after[untouched], before[untouched])
    np.testing.assert_array_equal(after[4], [9.0] * 4)
    # array 1 owns nothing: its writes drop, but its count still advances
    np.testing.assert_array_equal(np.asarray(new_sizes), [4, 5])
