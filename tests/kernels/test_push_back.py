"""Fused push-back kernel vs the jnp scan+scatter oracle — bit-exact parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ggarray as gg
from repro.kernels.push_back import ops as pb_ops

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]


def _random_wave(rng, nblocks, m, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        elems = rng.integers(-1000, 1000, (nblocks, m))
    else:
        elems = rng.standard_normal((nblocks, m))
    mask = rng.random((nblocks, m)) < 0.6
    return jnp.asarray(elems, dtype), jnp.asarray(mask)


@pytest.mark.parametrize("dtype", DTYPES, ids=str)
@pytest.mark.parametrize(
    "nblocks,b0,waves",
    [
        (4, 4, [3, 5, 2]),  # tile-aligned-ish rows
        (5, 3, [1, 7, 4, 6]),  # non-tile-aligned nblocks
        (2, 2, [9]),  # single wave spanning several levels
        (8, 1, [1, 1, 1, 1, 1]),  # b0=1: smallest buckets
        (3, 4, [130]),  # m past one lane tile
    ],
)
def test_round_trip_matches_oracle_bit_exact(dtype, nblocks, b0, waves):
    rng = np.random.default_rng(hash((str(dtype), nblocks, b0, len(waves))) % 2**32)
    fused = gg.init(nblocks, b0, dtype=dtype, nbuckets=1)
    oracle = gg.init(nblocks, b0, dtype=dtype, nbuckets=1)
    for m in waves:
        elems, mask = _random_wave(rng, nblocks, m, dtype)
        fused = gg.ensure_capacity(fused, m)
        oracle = gg.ensure_capacity(oracle, m)
        fused, pos_f = gg.push_back(fused, elems, mask, method="fused")
        oracle, pos_o = gg.push_back(oracle, elems, mask, method="scan")
        np.testing.assert_array_equal(np.asarray(pos_f), np.asarray(pos_o))
    for a, b in zip(fused.buckets, oracle.buckets):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(fused.sizes), np.asarray(oracle.sizes))
    # and the flattened views agree
    fa, ta = gg.flatten(fused)
    fb, tb = gg.flatten(oracle)
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    assert int(ta) == int(tb)


def test_ops_kernel_matches_use_ref():
    rng = np.random.default_rng(7)
    arr = gg.init(6, 2, nbuckets=3)
    elems, mask = _random_wave(rng, 6, 11, jnp.float32)
    sizes = jnp.asarray(rng.integers(0, 5, (6,)), jnp.int32)
    got = pb_ops.push_back_fused(arr.buckets, sizes, arr.b0, elems, mask)
    want = pb_ops.push_back_fused(
        arr.buckets, sizes, arr.b0, elems, mask, use_ref=True
    )
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_empty_wave_is_identity():
    arr = gg.init(2, 2, nbuckets=2)
    arr, _ = gg.push_back(arr, jnp.ones((2, 3)))
    out, pos = gg.push_back(arr, jnp.zeros((2, 0)), method="fused")
    assert pos.shape == (2, 0)
    np.testing.assert_array_equal(np.asarray(out.sizes), np.asarray(arr.sizes))
    for a, b in zip(out.buckets, arr.buckets):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_overflow_drops_match_oracle():
    """Past-capacity writes are dropped identically (mode='drop' parity)."""
    fused = gg.init(2, 2, nbuckets=1)  # capacity 2 per block
    oracle = gg.init(2, 2, nbuckets=1)
    elems = jnp.arange(10, dtype=jnp.float32).reshape(2, 5)
    fused, pos_f = gg.push_back(fused, elems, method="fused")
    oracle, pos_o = gg.push_back(oracle, elems, method="scan")
    np.testing.assert_array_equal(np.asarray(pos_f), np.asarray(pos_o))
    for a, b in zip(fused.buckets, oracle.buckets):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nonscalar_items_fall_back_to_jnp_path():
    arr = gg.init(2, 2, item_shape=(3,), nbuckets=2)
    elems = jnp.ones((2, 2, 3))
    got, pos = gg.push_back(arr, elems, method="fused")
    want, pos_w = gg.push_back(arr, elems, method="scan")
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(pos_w))
    for a, b in zip(got.buckets, want.buckets):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int_mask_counts_lanes_not_values():
    arr = gg.init(1, 4, nbuckets=2)
    mask = jnp.asarray([[3, 0, 7]], jnp.int32)  # two truthy lanes
    for method in ("fused", "scan"):
        out, pos = gg.push_back(arr, jnp.asarray([[1.0, 2.0, 3.0]]), mask, method=method)
        assert int(out.sizes[0]) == 2, method
        np.testing.assert_array_equal(np.asarray(pos), [[0, -1, 1]])
