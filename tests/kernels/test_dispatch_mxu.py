"""Dispatch/combine one-hot matmul kernels vs scatter/gather oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dispatch_mxu import ops, ref


@pytest.mark.parametrize("T,S,D", [(8, 16, 8), (100, 64, 32), (128, 128, 128), (300, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_matches_ref(T, S, D, dtype):
    rng = np.random.default_rng(hash((T, S, D, str(dtype))) % 2**32)
    x = jnp.asarray(rng.standard_normal((T, D)), dtype)
    # unique slots for kept tokens (push_back semantics), ~20% dropped
    perm = rng.permutation(S)[:T] if S >= T else rng.permutation(S).repeat(2)[:T]
    pos = np.where(rng.random(T) < 0.8, perm % S, -1).astype(np.int32)
    got = ops.dispatch(x, jnp.asarray(pos), S)
    want = ref.dispatch(x, jnp.asarray(pos), S)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("T,S,D", [(8, 16, 8), (64, 256, 32), (130, 100, 16)])
def test_combine_matches_ref(T, S, D):
    rng = np.random.default_rng(hash((T, S, D)) % 2**32)
    buf = jnp.asarray(rng.standard_normal((S, D)), jnp.float32)
    pos = np.where(rng.random(T) < 0.9, rng.integers(0, S, T), -1).astype(np.int32)
    got = ops.combine(buf, jnp.asarray(pos), T)
    want = ref.combine(buf, jnp.asarray(pos), T)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dispatch_then_combine_roundtrip():
    T, S, D = 32, 64, 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    pos = jnp.asarray(rng.permutation(S)[:T].astype(np.int32))
    buf = ops.dispatch(x, pos, S)
    back = ops.combine(buf, pos, T)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-5, atol=1e-5)
