"""Flatten kernels vs the core GGArray flatten (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ggarray as gg
from repro.kernels.flatten import ops, ref


def _make_gg(nblocks, b0, nbuckets, fill, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    arr = gg.init(nblocks, b0, dtype=dtype, nbuckets=nbuckets)
    per = rng.integers(0, fill + 1, nblocks)
    m = int(per.max()) if per.max() else 1
    elems = jnp.asarray(rng.standard_normal((nblocks, m)), dtype)
    mask = jnp.asarray(np.arange(m)[None, :] < per[:, None])
    arr, _ = gg.push_back(arr, elems, mask)
    return arr


@pytest.mark.parametrize("nblocks,b0,nbuckets", [(4, 2, 3), (8, 4, 2), (16, 8, 4), (3, 1, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_compact_blocks_matches_ref(nblocks, b0, nbuckets, dtype):
    arr = _make_gg(nblocks, b0, nbuckets, fill=b0 * 2, dtype=dtype,
                   seed=hash((nblocks, b0, nbuckets)) % 2**31)
    got = ops.compact_blocks(arr.buckets, arr.b0)
    want = ref.compact_blocks(arr.buckets, arr.b0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nblocks,b0,nbuckets", [(4, 2, 3), (8, 4, 3)])
def test_kernel_flatten_matches_core_flatten(nblocks, b0, nbuckets):
    arr = _make_gg(nblocks, b0, nbuckets, fill=b0 * 3, seed=7)
    got = ops.flatten(arr.buckets, arr.sizes, arr.b0)
    want, total = gg.flatten(arr)
    n = int(total)
    np.testing.assert_allclose(
        np.asarray(got)[:n], np.asarray(want)[:n], rtol=1e-5, atol=1e-5
    )
