"""Flatten kernels vs the core GGArray flatten (shape/dtype sweep).

Round-trip matrix: the segmented-gather kernel (O(n)), the legacy dispatch
matmul (O(n²)), the pure-jnp refs, and ``core.ggarray.flatten`` must agree
exactly across dtypes, ragged ``sizes``, and non-tile-aligned ``nblocks``;
``from_flat`` must invert any of them.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ggarray as gg
from repro.core import indexing
from repro.kernels.flatten import kernel, ops, ref


def _make_gg(nblocks, b0, nbuckets, fill, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    arr = gg.init(nblocks, b0, dtype=dtype, nbuckets=nbuckets)
    per = rng.integers(0, fill + 1, nblocks)
    m = int(per.max()) if per.max() else 1
    if jnp.issubdtype(dtype, jnp.integer):
        elems = jnp.asarray(rng.integers(-1000, 1000, (nblocks, m)), dtype)
    else:
        elems = jnp.asarray(rng.standard_normal((nblocks, m)), dtype)
    mask = jnp.asarray(np.arange(m)[None, :] < per[:, None])
    arr, _ = gg.push_back(arr, elems, mask)
    return arr


@pytest.mark.parametrize("nblocks,b0,nbuckets", [(4, 2, 3), (8, 4, 2), (16, 8, 4), (3, 1, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_compact_blocks_matches_ref(nblocks, b0, nbuckets, dtype):
    arr = _make_gg(nblocks, b0, nbuckets, fill=b0 * 2, dtype=dtype,
                   seed=hash((nblocks, b0, nbuckets)) % 2**31)
    got = ops.compact_blocks(arr.buckets, arr.b0)
    want = ref.compact_blocks(arr.buckets, arr.b0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nblocks,b0,nbuckets", [(4, 2, 3), (8, 4, 3)])
@pytest.mark.parametrize("impl", ["segmented", "dispatch"])
def test_kernel_flatten_matches_core_flatten(nblocks, b0, nbuckets, impl):
    arr = _make_gg(nblocks, b0, nbuckets, fill=b0 * 3, seed=7)
    got = ops.flatten(arr.buckets, arr.sizes, arr.b0, impl=impl)
    want, total = gg.flatten(arr)
    n = int(total)
    np.testing.assert_allclose(
        np.asarray(got)[:n], np.asarray(want)[:n], rtol=1e-5, atol=1e-5
    )


# Non-tile-aligned nblocks (3, 5, 13) and ragged fills: the segmented kernel's
# overhang tiles must mask correctly; dead slots must come back exactly zero.
@pytest.mark.parametrize(
    "nblocks,b0,nbuckets", [(3, 2, 4), (5, 3, 3), (13, 1, 5), (8, 8, 1)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_segmented_matches_all_paths(nblocks, b0, nbuckets, dtype):
    # crc32, not hash(): str hashing is salted per-process, and the test data
    # must be reproducible across runs.
    seed = zlib.crc32(repr((nblocks, b0, nbuckets, str(dtype))).encode())
    arr = _make_gg(nblocks, b0, nbuckets, fill=indexing.capacity(b0, nbuckets),
                   dtype=dtype, seed=seed)
    want, total = gg.flatten(arr)
    want = np.asarray(want)
    seg = np.asarray(ops.flatten_segmented(arr.buckets, arr.sizes, arr.b0))
    seg_ref = np.asarray(
        ops.flatten_segmented(arr.buckets, arr.sizes, arr.b0, use_ref=True)
    )
    disp = np.asarray(ops.flatten_dispatch(arr.buckets, arr.sizes, arr.b0))
    # exact equality — all paths move the same bits, no arithmetic on values
    np.testing.assert_array_equal(seg, want)
    np.testing.assert_array_equal(seg_ref, want)
    np.testing.assert_array_equal(disp, want)
    n = int(total)
    assert not np.any(seg[n:]), "dead slots must be zero"


@pytest.mark.parametrize("empty_blocks", [(), (0,), (0, 2, 3)])
def test_segmented_handles_empty_blocks(empty_blocks):
    nblocks, b0, nbuckets = 4, 2, 3
    rng = np.random.default_rng(11)
    arr = gg.init(nblocks, b0, dtype=jnp.float32, nbuckets=nbuckets)
    per = rng.integers(1, b0 * 3, nblocks)
    for b in empty_blocks:
        per[b] = 0
    m = int(per.max())
    elems = jnp.asarray(rng.standard_normal((nblocks, m)), jnp.float32)
    mask = jnp.asarray(np.arange(m)[None, :] < per[:, None])
    arr, _ = gg.push_back(arr, elems, mask)
    want, _ = gg.flatten(arr)
    got = ops.flatten_segmented(arr.buckets, arr.sizes, arr.b0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segmented_empty_array_is_all_zero():
    arr = gg.init(4, 2, dtype=jnp.float32, nbuckets=3)
    got = np.asarray(ops.flatten_segmented(arr.buckets, arr.sizes, arr.b0))
    assert got.shape == (arr.capacity,) and not np.any(got)


@pytest.mark.parametrize("impl", ["segmented", "dispatch"])
def test_flatten_from_flat_round_trip(impl):
    """flatten → from_flat → flatten is the identity on live elements."""
    arr = _make_gg(5, 3, 3, fill=3 * 4, seed=23)
    flat = ops.flatten(arr.buckets, arr.sizes, arr.b0, impl=impl)
    n = int(jnp.sum(arr.sizes))
    back = gg.from_flat(flat, n, nblocks=arr.nblocks, b0=arr.b0)
    flat2, total2 = gg.flatten(back)
    assert int(total2) == n
    np.testing.assert_allclose(
        np.asarray(flat2)[:n], np.asarray(flat)[:n], rtol=1e-6
    )


def test_segmented_gather_pallas_direct_tile_overhang():
    """Capacity not a multiple of the seg tile exercises the clamp path."""
    nblocks, cap = 3, 100  # total 300, tile 256 → one overhang tile
    rng = np.random.default_rng(3)
    compact = jnp.asarray(rng.standard_normal((nblocks, cap)), jnp.float32)
    sizes = jnp.asarray([100, 37, 0], jnp.int32)
    starts = indexing.block_starts(sizes).astype(jnp.int32)
    got = kernel.segmented_gather_pallas(compact, starts, starts + sizes, interpret=True)
    want = ref.gather_global(compact, starts, starts + sizes)
    assert got.shape == (nblocks * cap,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
