"""Attention implementation equivalence: blockwise / triangular / xla oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.models.attention import inner_attention

CFG = reduced("qwen3-32b")


@pytest.mark.parametrize("impl", ["blockwise", "blockwise_tri", "pallas"])
@pytest.mark.parametrize("S", [32, 64, 96])
def test_impls_match_xla_oracle(impl, S):
    if impl == "pallas" and S % 64:
        pytest.skip("pallas path pads to block size; compare aligned only")
    cfg = dataclasses.replace(CFG, attention_impl=impl, attention_chunk=32)
    cfg_ref = dataclasses.replace(CFG, attention_impl="xla")
    rng = np.random.default_rng(S)
    B, H, KH, Dh = 2, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, Dh)), jnp.float32)
    got = inner_attention(q, k, v, cfg, causal=True)
    want = inner_attention(q, k, v, cfg_ref, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_triangular_grad_finite():
    cfg = dataclasses.replace(CFG, attention_impl="blockwise_tri", attention_chunk=16)
    rng = np.random.default_rng(0)
    B, S, H, KH, Dh = 1, 64, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, Dh)), jnp.float32)
    g = jax.grad(lambda q: jnp.sum(inner_attention(q, k, v, cfg, causal=True) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g)))
