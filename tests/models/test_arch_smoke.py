"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, reduced
from repro.data.synthetic import make_batch
from repro.models import transformer
from repro.optim import adamw
from repro.train import step as train_step_mod

BATCH, SEQ = 2, 32


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch, rng):
    cfg = reduced(arch)
    params = transformer.init_params(rng, cfg)
    batch = make_batch(cfg, BATCH, SEQ)
    memory = None
    if cfg.n_enc_layers:
        from repro.models import encdec

        memory = encdec.encode(params["encoder"], batch["frames"], cfg)
    logits, aux = transformer.forward(
        params, batch["tokens"], cfg,
        prefix_embeds=batch.get("prefix_embeds"), memory=memory,
    )
    P = cfg.n_prefix_embeds if cfg.family == "vlm" else 0
    assert logits.shape == (BATCH, SEQ + P, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_reduces_loss_and_stays_finite(arch, rng):
    cfg = reduced(arch)
    state = train_step_mod.init_train_state(rng, cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    batch = make_batch(cfg, BATCH, SEQ)
    step_fn = jax.jit(
        lambda s, b: train_step_mod.train_step(s, b, cfg, opt_cfg)
    )
    losses = []
    for _ in range(3):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), f"{arch}: loss diverged {losses}"
    assert losses[-1] < losses[0], f"{arch}: no learning on repeated batch {losses}"
    # params stay finite
    finite = jax.tree.map(lambda p: bool(jnp.all(jnp.isfinite(p.astype(jnp.float32)))), state.params)
    assert all(jax.tree.leaves(finite)), f"{arch}: non-finite params"
