"""Custom-VJP RMSNorm: gradients match autodiff of the reference, dtypes bf16."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.modules import rms_norm


def _ref(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


@pytest.mark.parametrize("shape", [(4, 8), (2, 3, 16), (1, 5, 7, 32)])
def test_value_and_grads_match_autodiff(shape):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    w = jnp.asarray(rng.standard_normal(shape[-1:]) * 0.1 + 1.0, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(rms_norm(x, w)), np.asarray(_ref(x, w)), rtol=1e-6, atol=1e-6
    )

    def loss_custom(x, w):
        return jnp.sum(jnp.sin(rms_norm(x, w)))

    def loss_ref(x, w):
        return jnp.sum(jnp.sin(_ref(x, w)))

    gx, gw = jax.grad(loss_custom, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-5, atol=1e-5)


def test_bf16_boundary_dtypes():
    x = jnp.ones((2, 8), jnp.bfloat16)
    w = jnp.ones((8,), jnp.bfloat16)
    y, vjp = jax.vjp(lambda x, w: rms_norm(x, w), x, w)
    assert y.dtype == jnp.bfloat16
    dx, dw = vjp(jnp.ones_like(y))
    assert dx.dtype == jnp.bfloat16 and dw.dtype == jnp.bfloat16
