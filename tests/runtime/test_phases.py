"""TwoPhasePipeline lifecycle: grow → freeze → static work → thaw → regrow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ggarray as gg
from repro.runtime import FrozenArray, Phase, PhaseError, TwoPhasePipeline


def _grow_random(pipe, steps=5, seed=0):
    """Append random masked waves; return the per-block oracle lists."""
    rng = np.random.default_rng(seed)
    oracle = [[] for _ in range(pipe.nblocks)]
    for _ in range(steps):
        m = int(rng.integers(1, 7))
        elems = rng.standard_normal((pipe.nblocks, m)).astype(np.float32)
        mask = rng.random((pipe.nblocks, m)) < 0.6
        pipe.append(jnp.asarray(elems), jnp.asarray(mask))
        for b in range(pipe.nblocks):
            oracle[b].extend(elems[b][mask[b]].tolist())
    return oracle


@pytest.mark.parametrize("impl", ["segmented", "dispatch", "core"])
def test_freeze_emits_block_major_global_order(impl):
    pipe = TwoPhasePipeline(nblocks=4, b0=2, flatten_impl=impl)
    oracle = _grow_random(pipe, seed=1)
    frozen = pipe.freeze()
    want = np.concatenate([np.asarray(o, np.float32) for o in oracle])
    n = int(frozen.size)
    assert n == len(want)
    np.testing.assert_allclose(np.asarray(frozen.data)[:n], want, rtol=1e-6)
    assert not np.any(np.asarray(frozen.data)[n:]), "dead slots must be zero"
    # the freeze-time prefix table matches the per-block counts
    np.testing.assert_array_equal(
        np.asarray(frozen.block_starts),
        np.cumsum([0] + [len(o) for o in oracle[:-1]]),
    )


def test_phase_guards():
    pipe = TwoPhasePipeline(nblocks=2, b0=2)
    with pytest.raises(PhaseError):
        pipe.thaw()  # not frozen yet
    with pytest.raises(PhaseError):
        _ = pipe.frozen
    pipe.append(jnp.ones((2, 3)))
    pipe.freeze()
    assert pipe.phase is Phase.FROZEN
    with pytest.raises(PhaseError):
        pipe.append(jnp.ones((2, 1)))  # no growth while frozen
    with pytest.raises(PhaseError):
        pipe.freeze()  # double freeze
    pipe.thaw()
    assert pipe.phase is Phase.GROW


def test_frozen_read_matches_read_global():
    pipe = TwoPhasePipeline(nblocks=4, b0=2)
    _grow_random(pipe, seed=3)
    arr = pipe.array
    frozen = pipe.freeze()
    n = int(frozen.size)
    idx = jnp.arange(n)
    np.testing.assert_allclose(
        np.asarray(pipe.read(idx)),
        np.asarray(gg.read_global(arr, idx)),
        rtol=1e-6,
    )


def test_map_frozen_touches_only_live_slots():
    pipe = TwoPhasePipeline(nblocks=2, b0=2)
    pipe.append(jnp.ones((2, 3)))
    frozen = pipe.freeze()
    n = int(frozen.size)
    pipe.map_frozen(lambda x: x * 10.0)
    data = np.asarray(pipe.frozen.data)
    np.testing.assert_allclose(data[:n], 10.0)
    assert not np.any(data[n:])
    with pytest.raises(ValueError):
        pipe.map_frozen(lambda x: x[:1])  # shape-changing fn rejected


def test_thaw_zero_copy_then_regrow_then_refreeze():
    pipe = TwoPhasePipeline(nblocks=2, b0=2)
    pipe.append(jnp.asarray([[1.0, 2.0], [3.0, 4.0]]))
    n0 = int(pipe.freeze().size)
    pipe.thaw()
    pipe.append(jnp.full((2, 1), 9.0))
    frozen = pipe.freeze()
    assert int(frozen.size) == n0 + 2
    np.testing.assert_allclose(
        np.asarray(frozen.data)[: n0 + 2], [1, 2, 9, 3, 4, 9]
    )
    assert pipe.stats.freezes == 2 and pipe.stats.thaws == 1


def test_thaw_rebalance_redistributes_evenly():
    pipe = TwoPhasePipeline(nblocks=4, b0=2)
    # all load on block 0
    mask = jnp.asarray([[True] * 8] + [[False] * 8] * 3)
    pipe.append(jnp.broadcast_to(jnp.arange(8.0), (4, 8)), mask)
    pipe.freeze()
    pipe.thaw(rebalance=True)
    sizes = np.asarray(pipe.sizes)
    assert sizes.sum() == 8 and sizes.max() == 2, sizes


def test_append_loop_is_host_sync_free_and_stats_lazy(monkeypatch):
    """Steady pipeline appends never read device memory; freeze stays lazy."""
    calls = {"n": 0}
    real_get = jax.device_get

    def spy(x):
        calls["n"] += 1
        return real_get(x)

    pipe = TwoPhasePipeline(nblocks=2, b0=4, nbuckets=4)  # capacity 60/block
    wave = jnp.ones((2, 3))
    pipe.append(wave)  # warm the executable
    monkeypatch.setattr(jax, "device_get", spy)
    with jax.transfer_guard("disallow"):
        for _ in range(5):
            pipe.append(wave)
        pipe.freeze()  # lazy elements_frozen: no device_get either
    assert calls["n"] == 0
    assert pipe.stats.host_syncs == 0
    assert pipe.stats.freezes == 1
    # materializing the lazy counter is the one explicit read
    assert pipe.stats.elements_frozen == 36
    assert calls["n"] == 1


def test_frozen_array_is_a_pytree():
    pipe = TwoPhasePipeline(nblocks=2, b0=2)
    pipe.append(jnp.ones((2, 2)))
    frozen = pipe.freeze()

    @jax.jit
    def total(fz: FrozenArray):
        return jnp.sum(jnp.where(fz.live_mask(), fz.data, 0.0))

    assert float(total(frozen)) == 4.0


def test_item_shape_falls_back_to_core_flatten():
    pipe = TwoPhasePipeline(nblocks=2, b0=2, item_shape=(3,))
    pipe.append(jnp.ones((2, 2, 3)))
    frozen = pipe.freeze()
    assert frozen.data.shape == (pipe.memory_elems(), 3)
    assert int(frozen.size) == 4
    np.testing.assert_allclose(np.asarray(frozen.data)[:4], 1.0)
