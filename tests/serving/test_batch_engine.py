"""BatchEngine (policy="paged"): continuous batching over the slab arena.

Acceptance (ISSUE 3): ≥ 8 concurrent ragged-length sequences through one
shared pool, total pool capacity < 2× peak live tokens + one slab per
sequence, paged attend bit-exact vs the ggarray-policy oracle.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced
from repro.models import transformer
from repro.serving import kvcache
from repro.serving.engine import BatchEngine, Engine


def _setup(arch="qwen2.5-3b", **over):
    cfg = reduced(arch, cache_b0=4, **over)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


RAGGED_PROMPTS = [
    [1, 2, 3],
    [4, 5],
    [6, 7, 8, 9, 10],
    [11],
    [12, 13],
    [3, 1, 4, 1, 5, 9],
    [2, 6],
    [5, 3, 5, 8, 9, 7, 9, 3],
    [2, 7, 1, 8],
    [6, 6, 6],
]


def test_paged_attend_bit_exact_vs_ggarray_oracle():
    """kvcache-level: identical K/V traces → bitwise-identical attention."""
    cfg, _ = _setup()
    B, KH, DH, H = 3, cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    rng = np.random.default_rng(5)
    n = 21
    ks = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    lengths = jnp.asarray([n, 6, 1], jnp.int32)
    outs = {}
    for policy in ("ggarray", "paged"):
        cache = kvcache.init_cache(cfg, B, n, policy, dtype=jnp.float32)
        cache = kvcache.fill_from_prefill(cache, ks[:, :10], vs[:, :10])
        for t in range(10, n):
            cache = kvcache.append(cache, ks[:, t : t + 1], vs[:, t : t + 1], jnp.int32(t))
        outs[policy] = np.asarray(kvcache.attend(cache, q, lengths, cfg))
    np.testing.assert_array_equal(outs["paged"], outs["ggarray"])


def test_batch_engine_serves_ragged_fleet_within_pool_bound():
    """≥ 8 concurrent ragged sequences; capacity < 2·peak_live + T·nseq;
    greedy tokens identical to the ggarray-policy Engine."""
    cfg, params = _setup()
    T_new = 9
    want = Engine(params, cfg, policy="ggarray", max_len=64).generate(
        RAGGED_PROMPTS, max_new_tokens=T_new, temperature=0.0
    )
    be = BatchEngine(params, cfg, max_batch=8)
    rids = [be.submit(p, T_new) for p in RAGGED_PROMPTS]
    out = be.run()
    for i, rid in enumerate(rids):
        assert out[rid] == want[i], f"request {i} diverged from ggarray oracle"
    # the fleet really was concurrent and the pool really was shared
    assert be.stats.admitted == len(RAGGED_PROMPTS)
    assert be.stats.completed == len(RAGGED_PROMPTS)
    assert be.stats.decode_steps < len(RAGGED_PROMPTS) * (T_new - 1), (
        "continuous batching must overlap sequences"
    )
    # acceptance bound: capacity < 2× peak live tokens + one slab/sequence
    slab = cfg.slab_tokens
    bound = 2 * be.stats.peak_live_tokens + slab * be.B
    assert be.stats.peak_pool_tokens < bound, (
        f"pool {be.stats.peak_pool_tokens} ≥ bound {bound}"
    )
    assert be.stats.reused_slabs > 0, "completed sequences' slabs must recycle"
    # scheduling itself is host-sync-free: no stop-token drain ever fired;
    # the only device→host reads are the two final run() drains (the token
    # stream + the per-request first tokens), all audited by site.
    syncs = be.obs.registry.counter("serve.host_syncs")
    assert syncs.value(site="stop_drain") == 0, "must be host-sync-free"
    assert syncs.value(site="stream_drain") == 1
    assert syncs.value(site="first_token_drain") == 1
    assert be.stats.host_syncs == 2 == syncs.total()
    be.check_free_list()


def test_batch_engine_admits_more_requests_than_slots():
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=3)
    rids = [be.submit(p, 5) for p in RAGGED_PROMPTS[:7]]
    out = be.run()
    for rid, prompt in zip(rids, RAGGED_PROMPTS[:7]):
        assert len(out[rid]) == len(prompt) + 5
    be.check_free_list()
    assert be.alloc.live_count == 0, "all slabs must be released at drain"


def test_batch_engine_stop_token_evicts_early():
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=2, stop_token=None)
    rid = be.submit([1, 2, 3], 6)
    out = be.run()
    tok = out[rid][4]  # first decoded token — use it as the stop token
    be2 = BatchEngine(params, cfg, max_batch=2, stop_token=int(tok))
    rid2 = be2.submit([1, 2, 3], 6)
    out2 = be2.run()
    assert len(out2[rid2]) <= len(out[rid])
    assert be2.stats.host_syncs > 0  # stop detection is the one read/step
    be2.check_free_list()


def test_batch_engine_quota_is_enforced():
    from repro.pool import QuotaExceeded

    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=2, quota_slabs=1)
    be.submit(list(range(1, 12)), 4)  # 11 tokens: needs 3 slabs of 4
    with pytest.raises(QuotaExceeded):
        be.run()


def test_batch_engine_pallas_attend_close_to_levels():
    cfg, params = _setup()
    cfgp = dataclasses.replace(cfg, paged_attend_impl="pallas")
    prompts = RAGGED_PROMPTS[:4]
    out_lv = BatchEngine(params, cfg, max_batch=4).run_all(prompts, 6)
    out_pl = BatchEngine(params, cfgp, max_batch=4).run_all(prompts, 6)
    # fp accumulation order differs (flash per-page vs level walk); greedy
    # argmax almost always agrees — require ≥ 3 of 4 identical streams
    same = sum(out_lv[i] == out_pl[i] for i in range(len(prompts)))
    assert same >= len(prompts) - 1


def test_batch_engine_quant_cache_pools_are_int8():
    """cache_quant stores int8 codes + bf16 scales in the pools and still
    decodes the same tokens as the quantized ggarray Engine."""
    cfg, params = _setup(cache_quant=True)
    be = BatchEngine(params, cfg, max_batch=2)
    for i in be._attn_slots():
        assert be.caches[i]["k_pool"].dtype == jnp.int8
        assert be.caches[i]["ks_pool"].dtype == jnp.bfloat16
    prompts = RAGGED_PROMPTS[:3]
    want = Engine(params, cfg, policy="ggarray", max_len=64).generate(
        prompts, max_new_tokens=5, temperature=0.0
    )
    assert be.run_all(prompts, 5) == want
    be.check_free_list()


def test_batch_engine_peak_live_counts_admissions():
    """max_new_tokens=1 requests never decode; peak live must still count
    their prefill context (the capacity-bound denominator)."""
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=2)
    be.run_all([[1, 2, 3, 4, 5]] * 3, 1)
    assert be.stats.decode_steps == 0
    assert be.stats.peak_live_tokens >= 5


def test_batch_engine_mamba_hybrid_arch():
    """Hybrid (attention + SSM) stacks serve through the paged pool too.

    Prompts are equal-length: the batched Engine oracle right-pads ragged
    prompts through the Mamba recurrence (pad tokens enter the state), so
    only the unpadded case is an exact reference.
    """
    cfg, params = _setup("jamba-v0.1-52b")
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8], [2, 7, 1, 8], [9, 9, 9, 9]]
    want = Engine(params, cfg, policy="ggarray", max_len=64).generate(
        prompts, max_new_tokens=5, temperature=0.0
    )
    be = BatchEngine(params, cfg, max_batch=4)
    rids = [be.submit(p, 5) for p in prompts]
    out = be.run()
    for i, rid in enumerate(rids):
        assert out[rid] == want[i]
    # ragged prompts (incl. shorter than the conv window) still serve fine
    be2 = BatchEngine(params, cfg, max_batch=2)
    outs = be2.run_all([[1], [2, 3], [4, 5, 6, 7, 8]], 4)
    assert [len(o) for o in outs] == [5, 6, 9]
    be2.check_free_list()


@pytest.mark.parametrize("schedule", ("doubling", "tz"))
def test_batch_engine_extent_pool_matches_oracle_zero_copy(schedule):
    """Segmented extent pool (ISSUE 7): token-for-token parity with the
    ggarray oracle, and growth never memcpys a live pool byte."""
    cfg, params = _setup()
    T_new = 6
    want = Engine(params, cfg, policy="ggarray", max_len=64).generate(
        RAGGED_PROMPTS, max_new_tokens=T_new, temperature=0.0
    )
    be = BatchEngine(params, cfg, max_batch=8, grow_chunk=schedule)
    rids = [be.submit(p, T_new) for p in RAGGED_PROMPTS]
    out = be.run()
    for i, rid in enumerate(rids):
        assert out[rid] == want[i], f"request {i} diverged under {schedule}"
    assert be.stats.pool_grow_events > 0, "fleet must have outgrown the seed"
    assert be.stats.pool_copied_bytes == 0, "extent growth must never memcpy"
    assert sum(s > 0 for s in be._extent_sizes) > 1
    be.check_free_list()


def test_batch_engine_growth_counts_reserved_slabs():
    """In-flight chunked-prefill reservations are committed demand: doubling
    growth sizes off live + reserved, so converting those reservations to
    claims cannot trigger an immediate second grow.  With the accounting in
    place, grow events stay O(log final slabs)."""
    import math

    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=4, grow_chunk="doubling")
    prompts = [list(range(1, 17)), list(range(3, 15)), list(range(2, 12))]
    rids = [be.submit(p, 4) for p in prompts]
    out = be.run()
    assert all(len(out[r]) == len(p) + 4 for r, p in zip(rids, prompts))
    total = sum(be._extent_sizes)
    assert be.stats.pool_grow_events <= math.ceil(math.log2(max(total, 2))) + 1
    assert be.stats.pool_copied_bytes == 0
    be.check_free_list()
