"""KV-cache policies: equivalence across policies + growth semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.serving import kvcache

CFG = reduced("qwen3-32b", cache_b0=4)  # qk_norm GQA family, tiny
B, KH, DH = 2, CFG.n_kv_heads, CFG.head_dim
H = CFG.n_heads


def _rand_kv(key, n):
    k1, k2 = jax.random.split(key)
    return (
        jax.random.normal(k1, (B, n, KH, DH), jnp.float32),
        jax.random.normal(k2, (B, n, KH, DH), jnp.float32),
    )


@pytest.mark.parametrize("policy", ["static", "semistatic", "ggarray"])
def test_append_then_attend_matches_naive(policy):
    key = jax.random.PRNGKey(0)
    n = 13
    ks, vs = _rand_kv(key, n)
    cache = kvcache.init_cache(CFG, B, 32, policy, dtype=jnp.float32)
    for t in range(n):
        cache = kvcache.append(cache, ks[:, t : t + 1], vs[:, t : t + 1], jnp.int32(t))
    q = jax.random.normal(jax.random.PRNGKey(7), (B, 1, H, DH), jnp.float32)
    got = kvcache.attend(cache, q, jnp.int32(n), CFG)
    # naive oracle
    g = H // KH
    qf = q[:, 0].reshape(B, KH, g, DH) * DH**-0.5
    s = jnp.einsum("bkgd,blkd->bkgl", qf, ks[:, :n])
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bkgl,blkd->bkgd", p, vs[:, :n]).reshape(B, 1, H, DH)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_policies_agree_with_each_other():
    key = jax.random.PRNGKey(1)
    n = 9
    ks, vs = _rand_kv(key, n)
    q = jax.random.normal(jax.random.PRNGKey(3), (B, 1, H, DH), jnp.float32)
    outs = {}
    for policy in ("static", "semistatic", "ggarray"):
        cache = kvcache.init_cache(CFG, B, 16, policy, dtype=jnp.float32)
        cache = kvcache.fill_from_prefill(cache, ks, vs)
        outs[policy] = np.asarray(kvcache.attend(cache, q, jnp.int32(n), CFG))
    np.testing.assert_allclose(outs["static"], outs["ggarray"], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(outs["static"], outs["semistatic"], rtol=2e-5, atol=2e-5)


def test_per_sequence_lengths_mask_correctly():
    key = jax.random.PRNGKey(2)
    ks, vs = _rand_kv(key, 8)
    cache = kvcache.init_cache(CFG, B, 16, "ggarray", dtype=jnp.float32)
    cache = kvcache.fill_from_prefill(cache, ks, vs)
    q = jax.random.normal(jax.random.PRNGKey(5), (B, 1, H, DH), jnp.float32)
    lengths = jnp.asarray([3, 8], jnp.int32)
    got = kvcache.attend(cache, q, lengths, CFG)
    # sequence 0 must equal attending over only its first 3 entries
    cache3 = kvcache.init_cache(CFG, B, 16, "ggarray", dtype=jnp.float32)
    cache3 = kvcache.fill_from_prefill(cache3, ks[:, :3], vs[:, :3])
    want0 = kvcache.attend(cache3, q, jnp.int32(3), CFG)
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(want0)[0], rtol=2e-5, atol=2e-5)


def test_ggarray_growth_copy_free_and_capacity_bound():
    cache = kvcache.init_cache(CFG, B, 8, "ggarray", dtype=jnp.float32)
    before = {k: v for k, v in cache.items()}
    grown = kvcache.grow_ggarray(cache, CFG)
    for k in before:
        assert grown[k] is before[k], "existing buckets must not be copied"
    # §V bound: capacity < 2n + b0 at every fill level
    from repro.core import indexing

    for n in (5, 9, 30, 101):
        lv = kvcache.needed_levels(CFG.cache_b0, n)
        cap = indexing.capacity(CFG.cache_b0, lv)
        assert n <= cap < 2 * n + CFG.cache_b0


def test_append_past_static_capacity_truncates():
    cache = kvcache.init_cache(CFG, B, 4, "static", dtype=jnp.float32)
    k = jnp.ones((B, 1, KH, DH))
    before = np.asarray(cache["k"]).copy()
    cache = kvcache.append(cache, k, k, jnp.int32(4))  # out of range
    np.testing.assert_array_equal(np.asarray(cache["k"]), before)
