"""Property test: all cache policies decode identically on random traces."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # property tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.configs import reduced
from repro.serving import kvcache

CFG = reduced("qwen3-32b", cache_b0=4)
B, KH, DH, H = 2, CFG.n_kv_heads, CFG.head_dim, CFG.n_heads


@given(
    st.integers(1, 30),  # trace length
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_policies_equivalent_over_random_traces(n, seed):
    rng = np.random.default_rng(seed)
    ks = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, n + 1, B), jnp.int32)
    outs = {}
    for policy in ("static", "semistatic", "ggarray", "paged"):
        cache = kvcache.init_cache(CFG, B, max(n, 8), policy, dtype=jnp.float32)
        # interleave fill styles: bulk prefill then per-step appends
        split = int(rng.integers(0, n + 1))
        cache = kvcache.fill_from_prefill(cache, ks[:, :split], vs[:, :split])
        for t in range(split, n):
            cache = kvcache.append(cache, ks[:, t : t + 1], vs[:, t : t + 1], jnp.int32(t))
        outs[policy] = np.asarray(kvcache.attend(cache, q, lengths, CFG))
    np.testing.assert_allclose(outs["static"], outs["ggarray"], rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(outs["static"], outs["semistatic"], rtol=3e-5, atol=3e-5)
    # the paged walk reproduces the ggarray bucket walk bit-for-bit
    np.testing.assert_array_equal(outs["paged"], outs["ggarray"])
