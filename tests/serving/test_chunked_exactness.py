"""Chunked prefill is token-for-token identical to monolithic admission.

The DESIGN.md §7 bit-exactness contract: splitting a prompt into bucketed
chunks must not change a single sampled token versus (a) the same
``BatchEngine`` admitting monolithically and (b) the plain ggarray
``Engine``.  Exactness holds because chunk boundaries land on the
monolithic attention grid (``prefill_chunk % attention_chunk == 0``) so the
online-softmax partition of *live* score lanes is unchanged, pad lanes
contribute exactly ``0.0`` (``exp(MASK_VALUE − m)`` underflows), and the
static first-chunk flag keeps single-chunk prompts on the oracle's own
``Q = min(chunk, L)`` Mamba grid while resumed chunks keep the full grid.

Multi-chunk prompts (L > attention_chunk = 32) are the regression surface —
the admission default flipped to chunked, so these lengths exercise prefix
attends over already-scattered slabs and resumed Mamba state.
"""
import jax
import numpy as np

from repro.configs import reduced
from repro.models import transformer
from repro.serving.engine import BatchEngine, Engine


def _setup(arch="qwen2.5-3b"):
    cfg = reduced(arch, cache_b0=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(lengths, seed=11):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 50, L)] for L in lengths]


def test_chunked_matches_monolithic_and_engine_multichunk():
    """Attention-only stack, lengths spanning 1–3 chunks of C=32."""
    cfg, params = _setup()
    prompts = _prompts([33, 40, 64, 70, 5])
    t_new = 4
    want = Engine(params, cfg, policy="ggarray", max_len=80).generate(
        prompts, max_new_tokens=t_new, temperature=0.0
    )
    chunked = BatchEngine(params, cfg, max_batch=3, admission="chunked")
    mono = BatchEngine(params, cfg, max_batch=3, admission="monolithic")
    got_c = chunked.run_all(prompts, t_new)
    got_m = mono.run_all(prompts, t_new)
    for i in range(len(prompts)):
        assert got_c[i] == want[i], f"chunked diverged from Engine on {i}"
        assert got_m[i] == want[i], f"monolithic diverged from Engine on {i}"
    # it really chunked: 2+2+2+3+1 chunk executions across the fleet
    assert chunked.stats.prefill_chunks == sum(-(-L // 32) for L in (33, 40, 64, 70, 5))
    chunked.check_free_list()


def test_chunked_matches_engine_hybrid_equal_length():
    """Hybrid (Mamba+attn) stack vs the batched Engine oracle.

    Equal-length prompts only: the oracle right-pads ragged batches
    through the Mamba recurrence, so raggedness is covered by the
    chunked-vs-monolithic test below instead.
    """
    cfg, params = _setup("jamba-v0.1-52b")
    prompts = _prompts([40, 40, 40], seed=3)
    t_new = 4
    want = Engine(params, cfg, policy="ggarray", max_len=64).generate(
        prompts, max_new_tokens=t_new, temperature=0.0
    )
    be = BatchEngine(params, cfg, max_batch=3, admission="chunked")
    assert be.run_all(prompts, t_new) == want
    assert be.stats.prefill_chunks == 6  # 40 = 32 + 8-token exact tail
    be.check_free_list()


def test_chunked_matches_monolithic_hybrid_ragged_with_reuse():
    """Ragged hybrid prompts through max_batch=2: exercises slot *reuse*
    (a resumed chunk must not seed Mamba state from the previous tenant)
    and prefill/decode interleaving, token-for-token vs monolithic."""
    cfg, params = _setup("jamba-v0.1-52b")
    prompts = _prompts([33, 40, 37], seed=7)
    t_new = 4
    got_c = BatchEngine(params, cfg, max_batch=2, admission="chunked").run_all(
        prompts, t_new
    )
    got_m = BatchEngine(params, cfg, max_batch=2, admission="monolithic").run_all(
        prompts, t_new
    )
    assert got_c == got_m
