"""Copy-on-write prefix caching (DESIGN.md §10).

Three layers under test:

* the host trie (``serving/prefix.py``): publish/match roundtrip, exact
  token verification on truncated-hash collisions, LRU leaf eviction;
* refcount plumbing (``pool/planner.py``): claim/addref/release
  conservation, SHARED-owner handoff, double-free and free-alias guards —
  property-tested over interleaved submit/append(COW)/complete/evict;
* the engine (``serving/engine.py``): a shared-prefix fleet is
  token-for-token identical to cold-start, fully cached prompts admit with
  zero prefill chunks, COW never mutates a shared slab, and the pool grows
  sublinearly in fleet size.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # property tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.pool import PageBook, SlabAllocator
from repro.serving.prefix import PrefixCache, block_hash


# ---------------------------------------------------------------- allocator
def test_allocator_addref_release_semantics():
    al = SlabAllocator(4)
    ids = al.claim(0, 2)
    al.addref(ids[:1])
    assert al.refcount[ids[0]] == 2 and al.alias_claims == 1
    freed = al.release(ids, tenant=0)
    assert list(freed) == [int(ids[1])], "shared slab must survive release"
    assert al.owner[ids[0]] == SlabAllocator.SHARED  # claimant departed
    assert not al.free[ids[0]] and al.free[ids[1]]
    freed = al.release(ids[:1])  # last reference → actually freed
    assert list(freed) == [int(ids[0])] and al.free[ids[0]]
    with pytest.raises(RuntimeError):
        al.release(ids[:1])  # double free
    with pytest.raises(RuntimeError):
        al.addref(ids[:1])  # aliasing a free slab indexes dead data
    al.check()


# ---------------------------------------------------------------- the trie
def _book(n=16, ntenants=4):
    book = PageBook(ntenants)
    book.grow(n)
    return book


def test_publish_match_roundtrip():
    book = _book()
    px = PrefixCache(book.alloc, slab_tokens=4)
    prompt = list(range(1, 11))  # two full blocks + a 2-token partial tail
    ids, _ = book.claim(0, 3)
    assert px.publish(prompt, book.pages_of[0]) == 2  # partial never cached
    blocks, got = px.match(prompt)
    assert blocks == 2 and list(got) == [int(ids[0]), int(ids[1])]
    blocks, got = px.match(list(range(1, 5)))  # one-block prefix
    assert blocks == 1 and got[0] == ids[0]
    blocks, _ = px.match([1, 2, 3, 4, 9, 9, 9, 9])  # diverges at block 2
    assert blocks == 1
    assert px.match([9, 9, 9, 9])[0] == 0  # cold miss
    book.release(0)
    assert book.alloc.refcount[ids[0]] == 1, "trie keeps cached slabs alive"
    assert book.alloc.free[ids[2]], "uncached tail freed with its owner"
    book.alloc.check()


def _collide(bits=8, prefix=(7, 7, 7)):
    """Two distinct blocks with equal truncated hash (birthday search)."""
    seen = {}
    for x in range(1 << 16):
        blk = prefix + (x,)
        h = block_hash(blk, bits)
        if h in seen:
            return seen[h], blk
        seen[h] = blk
    raise AssertionError("no collision found")


def test_hash_collision_never_aliases_wrong_slab():
    a, b = _collide()
    assert a != b and block_hash(a, 8) == block_hash(b, 8)
    book = _book()
    px = PrefixCache(book.alloc, slab_tokens=4, hash_bits=8)
    ids_a, _ = book.claim(0, 1)
    px.publish(list(a), book.pages_of[0])
    blocks, got = px.match(list(b))
    assert blocks == 0 and len(got) == 0, "colliding block served wrong slab"
    # both blocks coexist under the same edge key, each resolving exactly
    ids_b, _ = book.claim(1, 1)
    px.publish(list(b), book.pages_of[1])
    assert px.match(list(a))[1][0] == ids_a[0]
    assert px.match(list(b))[1][0] == ids_b[0]


def test_lru_eviction_prefers_cold_leaves_and_cascades():
    book = _book()
    px = PrefixCache(book.alloc, slab_tokens=2)
    book.claim(0, 2)
    px.publish([1, 2, 3, 4], book.pages_of[0])
    chain = list(book.pages_of[0])
    book.release(0)
    book.claim(1, 1)
    px.publish([9, 9], book.pages_of[1])
    cold = list(book.pages_of[1])
    book.release(1)
    px.match([1, 2, 3, 4])  # touch the chain → the lone block is coldest
    assert list(px.evict(1)) == cold
    # the interior node only goes after its leaf: cascading eviction
    assert set(int(s) for s in px.evict(2)) == set(chain)
    assert len(px) == 0 and book.alloc.live_count == 0
    book.alloc.check()


def test_evict_skips_referenced_slabs():
    book = _book()
    px = PrefixCache(book.alloc, slab_tokens=2)
    book.claim(0, 1)
    px.publish([5, 6], book.pages_of[0])
    assert len(px.evict(5)) == 0, "tenant still aliases the slab"
    book.release(0)
    assert len(px.evict(5)) == 1


# ------------------------------------------------- refcount conservation
T = 4
PREFIXES = [
    tuple(range(10, 10 + 2 * T)),  # two blocks
    tuple(range(10, 10 + 3 * T)),  # extends the first (shared trie path)
    tuple(range(90, 90 + T)),  # disjoint
]


class _Sim:
    """Host-only engine stand-in: PageBook + PrefixCache + a shadow copy of
    every slab's written tokens.  ``check`` asserts the §10 invariants after
    every event: Σ(page-table refs + trie refs) == refcount, a slab is free
    iff nothing references it, and every cached node's slab still holds
    exactly the tokens it was published with (COW never mutated it)."""

    def __init__(self, ntenants=3):
        self.book = PageBook(ntenants)
        self.alloc = self.book.alloc
        self.px = PrefixCache(self.alloc, slab_tokens=T, hash_bits=6)
        self.data = {}  # slab id → tokens written into it
        self.seq = {}  # busy tenant → sequence so far
        self.N = ntenants
        self.cows = 0

    def _grow(self, k):
        short = self.book.shortfall(k)
        if short:
            self.book.grow(short)

    def submit(self, tenant, pidx, suffix):
        if tenant in self.seq:
            return
        prompt = list(PREFIXES[pidx % len(PREFIXES)])
        prompt += [200 + s for s in range(suffix)]
        blocks, ids = self.px.match(prompt)
        self.alloc.addref(ids)  # pin, as the engine does pre-admission
        for j, s in enumerate(ids):  # collision safety, end to end
            assert self.data[int(s)] == prompt[j * T : (j + 1) * T]
        self.book.adopt(tenant, ids)
        need = max(-(-len(prompt) // T), 1) - blocks
        self._grow(need)
        fresh, _ = self.book.claim(tenant, need)
        for j, s in zip(range(blocks, blocks + need), fresh):
            self.data[int(s)] = prompt[j * T : (j + 1) * T]
        if blocks * T >= len(prompt):  # full hit: decode rewrites the last
            prompt = prompt[:-1]  # prompt token (engine arms Lp−1)
        self.seq[tenant] = prompt

    def append(self, tenant, tok):
        if tenant not in self.seq:
            return
        pos = len(self.seq[tenant])
        page = pos // T
        if page >= int(self.book.npages[tenant]):
            self._grow(1)
            (s,), _ = self.book.claim(tenant, 1)
            self.data[int(s)] = []
        slab = self.book.pages_of[tenant][page]
        if int(self.alloc.refcount[slab]) > 1:  # copy-on-write
            self._grow(1)
            new = int(self.alloc.claim(tenant, 1)[0])
            self.book.replace(tenant, page, new)
            self.data[new] = list(self.data[slab])
            self.alloc.release(np.asarray([slab], np.int32), tenant=tenant)
            self.cows += 1
            slab = new
        self.data[slab] = self.data[slab][: pos % T] + [tok]
        self.seq[tenant].append(tok)

    def complete(self, tenant):
        if tenant not in self.seq:
            return
        self.px.publish(self.seq[tenant], self.book.pages_of[tenant])
        for f in self.book.release(tenant):
            self.data.pop(int(f))
        del self.seq[tenant]

    def evict(self, k):
        for f in self.px.evict(k):
            self.data.pop(int(f))

    def check(self):
        self.alloc.check()
        refs = np.zeros((self.alloc.n_slabs,), np.int64)
        for t in range(self.N):
            for s in self.book.pages_of[t]:
                refs[s] += 1
        for s in self.px.cached_slabs():
            refs[s] += 1
        assert (refs == self.alloc.refcount).all(), "refcount conservation"
        assert ((refs > 0) == ~self.alloc.free).all(), (
            "slab freed while referenced (or live without references)"
        )
        for node in self.px._lru:  # COW contract: cached data never mutates
            assert tuple(self.data[node.slab][: len(node.tokens)]) == node.tokens


def _run_ops(ops):
    sim = _Sim()
    for kind, t, v in ops:
        t %= sim.N
        if kind == 0:
            sim.submit(t, v, v % 3)
        elif kind == 1:
            sim.append(t, 300 + v)
        elif kind == 2:
            sim.complete(t)
        else:
            sim.evict(v % 4 + 1)
        sim.check()
    for t in list(sim.seq):
        sim.complete(t)
        sim.check()
    return sim


def test_refcount_conservation_scripted():
    """Deterministic walk through every interesting transition: cold fill,
    publish, partial hit, full hit with a COW rewrite, pressure eviction."""
    sim = _run_ops(
        [
            (0, 0, 1),  # cold: prefix 1 (3 blocks) + 1-token tail
            (1, 0, 1),
            (2, 0, 0),  # complete → publishes 3 blocks
            (0, 1, 0),  # full hit on prefix 1 → decode rewrite pending
            (1, 1, 5),  # the rewrite lands in a shared slab → must COW
            (0, 2, 3),  # partial hit: 2-block overlap via the shared path
            (1, 2, 6),
            (3, 0, 2),  # evict under pressure (referenced slabs survive)
            (2, 1, 0),
            (2, 2, 0),
            (3, 0, 9),
        ]
    )
    assert sim.cows >= 1, "the full-hit rewrite never copied"


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 3), st.integers(0, 2), st.integers(0, 40)
        ),
        max_size=60,
    )
)
def test_refcount_conservation_property(ops):
    """Interleaved submit/append/complete/evict never breaks conservation,
    never frees a referenced slab, and never mutates a shared slab."""
    _run_ops(ops)


# ---------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def _engine_setup():
    import jax

    from repro.configs import reduced
    from repro.models import transformer

    cfg = reduced("qwen2.5-3b", cache_b0=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prefix_cache_bit_exact_and_skips_prefill(_engine_setup):
    """A shared-prefix fleet reuses the cached prompt: bit-exact outputs vs
    cold-start, zero prefill chunks on the fully cached duplicate, one chunk
    per uncached suffix, ≥1 COW copy, sublinear pool growth."""
    from repro.serving.engine import BatchEngine

    cfg, params = _engine_setup
    base = [int(t) for t in np.random.default_rng(2).integers(1, 50, 36)]
    prompts = [base, base + [3, 1], base + [7, 7, 7, 2, 9], base]
    t_new = 4
    cold = BatchEngine(params, cfg, max_batch=4, admission="chunked")
    want = cold.run_all(prompts, t_new)

    warm = BatchEngine(params, cfg, max_batch=4, prefix_cache=True)
    r0 = warm.submit(prompts[0], t_new)
    assert warm.run()[r0] == want[0]
    chunks_cold = warm.stats.prefill_chunks  # 36 tokens = 2 chunks of C=32
    rids = [warm.submit(p, t_new) for p in prompts[1:]]
    out = warm.run()
    for rid, w in zip(rids, want[1:]):
        assert out[rid] == w, "prefix reuse changed a sampled token"
    assert warm.stats.prefix_hits == 3
    assert warm.stats.prefix_tokens_reused == 3 * len(base)
    # suffix-only prefill: one chunk each for the two extensions, zero for
    # the duplicate (≥90% chunk reduction on the fully cached prompt)
    assert warm.stats.prefill_chunks - chunks_cold == 2
    assert warm.stats.cow_copies >= 1, "full hit decoded into a shared slab"
    assert warm.alloc.n_slabs < cold.alloc.n_slabs, "prefix stored once"
    events = warm.obs.tracer.events
    full_hits = [
        e for e in events if e["name"] == "prefix_hit" and e["attrs"]["full"]
    ]
    assert len(full_hits) == 1
    firsts = [e for e in events if e["name"] == "first_token"]
    assert {e["attrs"]["rid"] for e in firsts} == {r0, *rids}, (
        "every request records TTFT exactly once (full hits on first decode)"
    )
    warm.check_free_list()


def test_prefix_cache_with_extent_pool_zero_copy(_engine_setup):
    """Prefix aliasing composes with segmented extents: COW copies route
    through extent-local slab copies and growth still never memcpys."""
    from repro.serving.engine import BatchEngine

    cfg, params = _engine_setup
    base = [int(t) for t in np.random.default_rng(5).integers(1, 50, 8)]
    prompts = [base, base + [2, 4], base]
    cold = BatchEngine(params, cfg, max_batch=2, admission="chunked")
    want = cold.run_all(prompts, 3)
    be = BatchEngine(
        params, cfg, max_batch=2, grow_chunk="doubling", prefix_cache=True
    )
    r0 = be.submit(prompts[0], 3)
    assert be.run()[r0] == want[0]
    rids = [be.submit(p, 3) for p in prompts[1:]]
    out = be.run()
    assert [out[r] for r in rids] == want[1:]
    assert be.stats.prefix_hits == 2
    assert be.stats.cow_copies >= 1
    assert be.stats.pool_copied_bytes == 0, "extent growth must never memcpy"
    be.check_free_list()


def test_prefix_cache_requires_chunked_attention(_engine_setup):
    import jax

    from repro.configs import reduced
    from repro.models import transformer
    from repro.serving.engine import BatchEngine

    cfg, params = _engine_setup
    with pytest.raises(ValueError, match="chunked"):
        BatchEngine(params, cfg, admission="monolithic", prefix_cache=True)
    cfg_h = reduced("jamba-v0.1-52b", cache_b0=4)
    params_h = transformer.init_params(jax.random.PRNGKey(0), cfg_h)
    with pytest.raises(ValueError, match="attention-only"):
        BatchEngine(params_h, cfg_h, prefix_cache=True)
