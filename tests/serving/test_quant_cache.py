"""int8 KV cache (§Perf cell A): accuracy + memory accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.serving import kvcache

CFG = reduced("qwen3-32b", cache_b0=4)
CFGQ = dataclasses.replace(CFG, cache_quant=True)
B, KH, DH, H = 2, CFG.n_kv_heads, CFG.head_dim, CFG.n_heads


def _fill(cfg, ks, vs, n):
    c = kvcache.init_cache(cfg, B, 32, "ggarray",
                           dtype=None if cfg.cache_quant else jnp.float32)
    return kvcache.fill_from_prefill(c, ks, vs)


@pytest.mark.parametrize("policy", ["ggarray", "static"])
def test_quant_attend_close_to_exact(policy):
    rng = np.random.default_rng(0)
    n = 13
    ks = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    exact = kvcache.init_cache(CFG, B, 32, policy, dtype=jnp.float32)
    exact = kvcache.fill_from_prefill(exact, ks, vs)
    quant = kvcache.init_cache(CFGQ, B, 32, policy)
    quant = kvcache.fill_from_prefill(quant, ks, vs)
    out_e = kvcache.attend(exact, q, jnp.int32(n), CFG)
    out_q = kvcache.attend(quant, q, jnp.int32(n), CFGQ)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_q), atol=0.05)


def test_quant_append_path_matches_fill_path():
    rng = np.random.default_rng(1)
    n = 9
    ks = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((B, n, KH, DH)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, DH)), jnp.float32)
    filled = kvcache.init_cache(CFGQ, B, 32, "ggarray")
    filled = kvcache.fill_from_prefill(filled, ks, vs)
    stepped = kvcache.init_cache(CFGQ, B, 32, "ggarray")
    for t in range(n):
        stepped = kvcache.append(stepped, ks[:, t : t + 1], vs[:, t : t + 1], jnp.int32(t))
    a = kvcache.attend(filled, q, jnp.int32(n), CFGQ)
    b = kvcache.attend(stepped, q, jnp.int32(n), CFGQ)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_quant_halves_cache_bytes():
    exact = kvcache.init_cache(reduced("qwen3-32b", cache_b0=64, dtype="bfloat16"), B, 256, "ggarray")
    quant = kvcache.init_cache(
        dataclasses.replace(reduced("qwen3-32b", cache_b0=64), cache_quant=True), B, 256, "ggarray"
    )
    ratio = kvcache.cache_bytes(quant) / kvcache.cache_bytes(exact)
    assert ratio < 0.6  # int8 + small scale overhead vs bf16


def test_quant_growth_adds_scale_levels():
    c = kvcache.init_cache(CFGQ, B, 8, "ggarray")
    g = kvcache.grow_ggarray(c, CFGQ)
    lv = kvcache._levels(g)
    assert f"ks{lv-1}" in g and f"vs{lv-1}" in g
    for key in c:
        assert g[key] is c[key]  # copy-free
