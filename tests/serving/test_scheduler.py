"""Scheduler invariants — property-tested without a model.

The admission scheduler is pure host state over a ``PageBook``, so the §7
serving invariants are checkable by simulation: this file acts as the engine
(claiming slabs per chunk task, releasing on completion) and asserts after
every event that

* slabs are conserved: pages owned by busy slots == allocator live count,
  and the allocator's own free-list/owner cross-checks pass;
* no slab is double-claimed (every claimed id was free, every id released
  exactly once);
* reservations never exceed the free list, and an admitted request can
  always cover its remaining chunks from its reservation — even while a
  decode-growth adversary claims unreserved slabs between chunks;
* admission is FIFO within equal slab need, with bounded skip-ahead
  (no request starves: the aged head blocks the queue until it fits);
* chunk plans tile ``[0, L)`` exactly, widths drawn from the bucket set
  (or the exact tail when ``exact_tail=True``).
"""
import collections

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # property tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.pool import PageBook, QuotaExceeded
from repro.serving.scheduler import ChunkTask, Scheduler, bucket_for, bucket_widths


# ---------------------------------------------------------------- examples
def test_bucket_widths_geometric():
    assert bucket_widths(4, 32) == (4, 8, 16, 32)
    assert bucket_widths(8, 8) == (8,)
    assert bucket_widths(3, 20) == (3, 6, 12, 20)  # capped at chunk
    assert bucket_widths(64, 32) == (32,)  # b0 above chunk collapses
    with pytest.raises(ValueError):
        bucket_widths(0, 32)


def test_bucket_for_smallest_cover():
    bk = (4, 8, 16, 32)
    assert bucket_for(1, bk) == 4
    assert bucket_for(4, bk) == 4
    assert bucket_for(5, bk) == 8
    assert bucket_for(32, bk) == 32
    with pytest.raises(ValueError):
        bucket_for(33, bk)


def _mk(nslots=3, slab_tokens=4, chunk=8, **kw):
    book = PageBook(nslots, quota_slabs=kw.pop("quota_slabs", None))
    sched = Scheduler(book, slab_tokens=slab_tokens, chunk=chunk, **kw)
    return book, sched


def _grow(book):
    def ensure(short):
        book.grow(short)
        return True

    return ensure


def _run_prefill(book, sched):
    """Drive every planned chunk to completion; return the executed tasks."""
    done = []
    while sched.prefilling:
        for task in sched.next_chunks():
            if task.new_slabs:
                book.claim(task.slot, task.new_slabs, from_reservation=True)
            sched.chunk_done(task)
            done.append(task)
    return done


def test_chunks_tile_prompt_exactly():
    book, sched = _mk(chunk=8, slab_tokens=4)
    sched.submit(7, length=21)  # 8 + 8 + 5 → widths 8, 8, 8 (bucketed)
    assert [r for r, _, _ in sched.admit(_grow(book))] == [7]
    tasks = _run_prefill(book, sched)
    assert [(t.t0, t.live, t.width, t.final) for t in tasks] == [
        (0, 8, 8, False),
        (8, 8, 8, False),
        (16, 5, 8, True),
    ]
    assert sum(t.new_slabs for t in tasks) == sched.slabs_for(21)
    assert sched.decoding == [0] and not sched.prefilling


def test_exact_tail_skips_padding():
    book, sched = _mk(chunk=8, slab_tokens=4, exact_tail=True)
    sched.submit(0, length=21)
    sched.admit(_grow(book))
    tasks = _run_prefill(book, sched)
    assert [t.width for t in tasks] == [8, 8, 5]  # tail unpadded
    assert tasks[-1].final


def test_match_hook_shrinks_reservation_to_uncached_suffix():
    """Prefix-cache hook (DESIGN.md §10): a cached prefix shrinks the
    reservation to the uncached suffix and prefill starts at its t0; a
    fully cached prompt admits with zero prefill chunks."""
    book, sched = _mk(chunk=8, slab_tokens=4)
    cached = {7: 8, 8: 12, 9: 0}  # rid → cached prefix tokens
    sched.submit(7, length=14)  # 2 of 4 slabs cached → reserve 2
    sched.submit(8, length=12)  # fully cached → reserve 0, no prefill
    sched.submit(9, length=5)  # cold → whole need reserved
    admits = sched.admit(_grow(book), match=lambda r, L: cached[r])
    assert [(r, need) for r, _, need in admits] == [(7, 2), (8, 0), (9, 2)]
    slot = {r: s for r, s, _ in admits}
    assert sched.phase[slot[8]] == "decode" and slot[8] not in sched.prefilling
    assert int(sched.t0[slot[7]]) == 8 and int(sched.t0[slot[9]]) == 0
    # the caller aliases cached slabs before chunks run; model the trie as
    # an off-slot holder and alias into the admitted slot
    book.grow(2)
    cached_ids = book.alloc.claim(99, 2)  # stand-in for trie-held slabs
    book.alias(slot[7], cached_ids)
    tasks = _run_prefill(book, sched)
    assert [(t.rid, t.t0, t.live, t.final) for t in tasks] == [
        (7, 8, 6, True),  # suffix-only chunk, resumed at the cached t0
        (9, 0, 5, True),
    ]
    assert sum(t.new_slabs for t in tasks if t.rid == 7) == 2


def test_fifo_within_equal_need():
    book, sched = _mk(nslots=4)
    for rid, L in enumerate([9, 9, 9]):  # identical slab need
        sched.submit(rid, L)
    admitted = [r for r, _, _ in sched.admit(_grow(book))]
    assert admitted == [0, 1, 2]


def test_skip_ahead_admits_smaller_later_request():
    book, sched = _mk(nslots=2)
    book.grow(2)  # fixed 2-slab pool, no growth allowed
    sched.submit(0, length=40)  # needs 10 slabs — can never fit
    sched.submit(1, length=4)  # needs 1 slab — fits now
    admitted = [r for r, _, _ in sched.admit(lambda s: False)]
    assert admitted == [1]
    assert [w.rid for w in sched.pending] == [0]


def test_starved_head_blocks_queue():
    book, sched = _mk(nslots=2, starvation_limit=2)
    book.grow(2)
    sched.submit(0, length=12)  # needs 3 — never fits the 2-slab pool
    sched.submit(1, length=4)  # needs 1 — skips ahead (skip #1 for the head)
    assert [r for r, _, _ in sched.admit(lambda s: False)] == [1]
    slot1 = sched.rid_of_slot.index(1)
    book.release(slot1), sched.complete(slot1)
    sched.submit(2, length=4)  # would fit, but the head has now aged out…
    assert sched.admit(lambda s: False) == []  # skip #2 → head-of-line block
    # Growth lets the aged head in; FIFO resumes behind it.
    assert [r for r, _, _ in sched.admit(_grow(book))] == [0, 2]


def test_quota_breach_raises_and_preserves_queue():
    book, sched = _mk(nslots=2, quota_slabs=2)
    sched.submit(0, length=4)
    sched.submit(1, length=40)  # needs 10 > quota 2: can never admit
    sched.submit(2, length=4)
    with pytest.raises(QuotaExceeded):
        sched.admit(_grow(book))
    # rid=0 admitted before the raise; 1 and 2 still queued, in order.
    assert sched.rid_of_slot[0] == 0
    assert [w.rid for w in sched.pending] == [1, 2]


def test_reservation_shields_prefill_from_decode_growth():
    book, sched = _mk(nslots=2, chunk=8, slab_tokens=4)
    book.grow(6)
    sched.submit(0, length=24)  # needs 6 slabs — reserve all of them
    sched.admit(lambda s: False)
    assert book.alloc.reserved_total == 6
    # A decode tenant sees free − reserved: claiming 1 unreserved slab is a
    # shortfall even though 6 slabs are physically free.
    assert book.shortfall(1, tenant=1) == 1
    # The prefill itself draws from its reservation unimpeded.
    tasks = _run_prefill(book, sched)
    assert sum(t.new_slabs for t in tasks) == 6
    assert book.alloc.reserved_total == 0
    book.alloc.check()


# ------------------------------------------------------------- telemetry
def test_queue_metrics_against_hand_scheduled_trace():
    """Starvation-limit skip-ahead and per-request queue wait, asserted
    event-for-event against a hand-scheduled trace (ISSUE 8 satellite):

    tick 0: rid0 (needs 3 > 2-slab pool) skipped (#1); rid1 admitted, wait 0.
    tick 1: rid1 done; rid2 submitted; rid0 skipped (#2) → aged head blocks
            the queue, so rid2 (which would fit) is NOT admitted.
    tick 2: growth lets rid0 in (wait 2 ticks); rid2 follows (wait 1).
    """
    book, sched = _mk(nslots=2, starvation_limit=2)
    book.grow(2)
    reg = sched.obs.registry
    skips = reg.counter("sched.starvation_skips")
    blocks = reg.counter("sched.head_blocks")
    waits = reg.histogram("sched.queue_wait_ticks")

    sched.submit(0, length=12)  # needs 3 — never fits the 2-slab pool
    sched.submit(1, length=4)
    assert [r for r, _, _ in sched.admit(lambda s: False)] == [1]
    assert skips.total() == 1 and blocks.total() == 0
    assert waits.values(rid=1) == [0.0]

    slot1 = sched.rid_of_slot.index(1)
    book.release(slot1), sched.complete(slot1)
    sched.submit(2, length=4)
    assert sched.admit(lambda s: False) == []  # skip #2 → head-of-line block
    assert skips.total() == 2 and blocks.total() == 1
    assert waits.count() == 1, "nothing admitted while the head blocks"

    assert [r for r, _, _ in sched.admit(_grow(book))] == [0, 2]
    assert waits.values(rid=0) == [2.0]  # waited ticks 0 and 1
    assert waits.values(rid=2) == [1.0]  # submitted at tick 1, admitted at 2
    assert skips.total() == 2 and blocks.total() == 1  # growth ended the block
    # the timeline saw the same story, in order
    names = [e["name"] for e in sched.obs.tracer.events]
    assert names == ["starve_skip", "starve_skip", "head_block"]
    assert sched.obs.tracer.events[-1]["attrs"] == {"rid": 0}


def test_queue_wait_zero_for_immediate_admission():
    book, sched = _mk(nslots=3)
    for rid in range(3):
        sched.submit(rid, length=4)
    assert len(sched.admit(_grow(book))) == 3
    waits = sched.obs.registry.histogram("sched.queue_wait_ticks")
    assert waits.values() == [0.0, 0.0, 0.0]
    assert sched.tick == 1  # exactly one completed admit round


# ---------------------------------------------------------------- property
@given(
    st.lists(st.integers(min_value=1, max_value=40), min_size=1, max_size=12),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_scheduler_simulation_invariants(lengths, seed):
    rng = np.random.default_rng(seed)
    nslots, slab_tokens, chunk = 3, 4, 8
    pool_cap = 16  # fixed pool: growth allowed up to the cap, then refused
    book = PageBook(nslots)
    sched = Scheduler(book, slab_tokens=slab_tokens, chunk=chunk)

    def ensure(short):
        if book.alloc.n_slabs + short > pool_cap:
            return False
        book.grow(short)
        return True

    submit_order = list(range(len(lengths)))
    need_of = {r: sched.slabs_for(L) for r, L in zip(submit_order, lengths)}
    for rid, L in zip(submit_order, lengths):
        sched.submit(rid, L)

    admitted_order: list[int] = []
    chunks_of: dict[int, list[ChunkTask]] = collections.defaultdict(list)
    completed: set[int] = set()
    live_ids: set[int] = set()  # slabs currently claimed by any slot

    def check_conservation():
        book.alloc.check()  # free list ∪ owned partition, reservation ledger
        owned = sum(int(book.npages[s]) for s in range(nslots))
        assert owned == book.alloc.live_count
        assert book.alloc.reserved_total <= book.alloc.free_count

    for _ in range(500):
        if not sched.busy:
            break
        for rid, slot, need in sched.admit(ensure):
            admitted_order.append(rid)
            assert need == need_of[rid]
        check_conservation()
        for task in sched.next_chunks():
            if task.new_slabs:
                ids, _ = book.claim(task.slot, task.new_slabs, from_reservation=True)
                got = set(ids.tolist())
                assert not got & live_ids  # no slab double-claimed
                live_ids |= got
                for i in got:
                    assert book.alloc.owner[i] == task.slot
            sched.chunk_done(task)
            chunks_of[task.rid].append(task)
            check_conservation()
        # Decode phase: adversarial growth claims + probabilistic completion.
        for slot in list(sched.decoding):
            if rng.random() < 0.3 and book.shortfall(1, tenant=slot) == 0:
                ids, _ = book.claim(slot, 1)  # growth — never touches reserved
                assert not set(ids.tolist()) & live_ids
                live_ids |= set(ids.tolist())
                check_conservation()
            if rng.random() < 0.5:
                freed = set(book.release(slot).tolist())
                assert freed <= live_ids  # released exactly what was claimed
                live_ids -= freed
                completed.add(sched.rid_of_slot[slot])
                sched.complete(slot)
                check_conservation()
    else:
        pytest.fail("scheduler did not drain in 500 steps (starvation?)")

    # Everyone ran: admitted exactly once, completed, chunks tile [0, L).
    assert sorted(admitted_order) == submit_order
    assert completed == set(submit_order)
    for rid, L in zip(submit_order, lengths):
        tasks = chunks_of[rid]
        t0 = 0
        for t in tasks:
            assert t.t0 == t0 and t.live >= 1
            assert t.width in sched.buckets and t.width >= t.live
            t0 += t.live
        assert t0 == L and tasks[-1].final
        assert sum(t.new_slabs for t in tasks) == need_of[rid]
    # FIFO within equal slab need (deterministic ensure → a skipped need
    # blocks every equal need behind it in the same scan).
    pos = {r: i for i, r in enumerate(admitted_order)}
    for a in submit_order:
        for b in submit_order:
            if a < b and need_of[a] == need_of[b]:
                assert pos[a] < pos[b], (a, b, admitted_order)
