"""freeze_cache / thaw_cache and the two_phase serving policy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.serving import kvcache


def _filled_ggarray_cache(cfg, B=2, steps=13, seed=0):
    rng = np.random.default_rng(seed)
    c = kvcache.init_cache(cfg, B, steps + 4, "ggarray")
    shp = (B, 1, cfg.n_kv_heads, cfg.head_dim)
    for t in range(steps):
        k = jnp.asarray(rng.standard_normal(shp), jnp.float32)
        v = jnp.asarray(rng.standard_normal(shp), jnp.float32)
        c = kvcache.append(c, k, v, t)
    return c


@pytest.mark.parametrize("quant", [False, True])
def test_freeze_thaw_round_trip_and_attend_parity(quant):
    cfg = reduced("qwen3-32b", cache_b0=4, cache_quant=quant)
    steps = 13
    c = _filled_ggarray_cache(cfg, steps=steps, seed=1)
    rng = np.random.default_rng(2)
    q = jnp.asarray(
        rng.standard_normal((2, 1, cfg.n_heads, cfg.head_dim)), jnp.float32
    )
    a_gg = kvcache.attend(c, q, steps, cfg)

    frozen = kvcache.freeze_cache(c)
    assert "k" in frozen and "k0" not in frozen, "freeze must emit static layout"
    a_frozen = kvcache.attend(frozen, q, steps, cfg)
    np.testing.assert_allclose(
        np.asarray(a_gg), np.asarray(a_frozen), rtol=2e-5, atol=2e-5
    )

    thawed = kvcache.thaw_cache(frozen, cfg.cache_b0)
    assert set(thawed) == set(c)
    for key in c:
        np.testing.assert_array_equal(
            np.asarray(c[key]), np.asarray(thawed[key]), err_msg=key
        )


def test_freeze_preserves_passthrough_keys_and_is_idempotent():
    cfg = reduced("qwen3-32b", cache_b0=4)
    c = _filled_ggarray_cache(cfg, steps=5)
    cross = jnp.ones((2, 7, cfg.n_kv_heads, cfg.head_dim))
    c = dict(c, cross_k=cross, cross_v=cross)
    frozen = kvcache.freeze_cache(c)
    np.testing.assert_array_equal(np.asarray(frozen["cross_k"]), np.asarray(cross))
    again = kvcache.freeze_cache(frozen)
    assert set(again) == set(frozen), "freeze of a static cache is a no-op"


def test_frozen_decode_appends_until_capacity():
    """A frozen cache behaves like a static cache for in-capacity appends."""
    cfg = reduced("qwen3-32b", cache_b0=4)
    steps = 5
    c = _filled_ggarray_cache(cfg, steps=steps, seed=3)
    frozen = kvcache.freeze_cache(c)
    cap = frozen["k"].shape[-3]
    rng = np.random.default_rng(4)
    shp = (2, 1, cfg.n_kv_heads, cfg.head_dim)
    k = jnp.asarray(rng.standard_normal(shp), jnp.float32)
    v = jnp.asarray(rng.standard_normal(shp), jnp.float32)
    frozen = kvcache.append(frozen, k, v, steps)
    np.testing.assert_array_equal(
        np.asarray(frozen["k"][:, steps]), np.asarray(k[:, 0])
    )
    assert steps + 1 <= cap


def test_engine_two_phase_matches_ggarray():
    from repro.models import transformer
    from repro.serving.engine import Engine

    cfg = reduced("qwen2.5-3b", cache_b0=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3], [4, 5]]
    outs, stats = {}, {}
    for policy in ("ggarray", "two_phase"):
        eng = Engine(params, cfg, policy=policy, max_len=64)
        outs[policy] = eng.generate(prompts, max_new_tokens=10, temperature=0.0)
        stats[policy] = eng.stats
    assert outs["two_phase"] == outs["ggarray"], "freeze must not change decode"
    tp = stats["two_phase"]
    assert tp.freeze_events >= 1, "prefill handoff must freeze"
    # frozen decode keeps one cache structure per capacity level → compiles
    # bounded by growth events, same as ggarray
    assert tp.compiles <= tp.grow_events + 1
