"""Serving steps + engine: prefill/decode consistency and growth dynamics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.models import transformer
from repro.serving import steps
from repro.serving.engine import Engine

ARCHS_DECODE = ["qwen3-32b", "qwen2.5-3b", "jamba-v0.1-52b", "mamba2-2.7b", "seamless-m4t-large-v2"]


def _setup(arch, **over):
    cfg = reduced(arch, **over)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("arch", ARCHS_DECODE)
def test_decode_matches_forward(arch):
    """Prefill(n) + decode(1) logits == forward(n+1) last-position logits."""
    cfg, params = _setup(arch)
    B, S = 2, 16
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size, jnp.int32)
    memory = None
    kwargs = {}
    if cfg.n_enc_layers:
        from repro.models import encdec
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.02
        memory = encdec.encode(params["encoder"], frames.astype(jnp.float32), cfg)
        kwargs["memory"] = memory

    # ground truth: full forward over S+1 tokens
    logits_full, _ = transformer.forward(params, toks, cfg, memory=memory)
    want = np.asarray(logits_full[:, -1])

    # serve path: prefill S tokens, decode token S
    _, caches = steps.prefill(params, toks[:, :S], cfg, capacity_hint=S + 4, **kwargs)
    got, _ = steps.decode_step(params, toks[:, S], caches, jnp.int32(S), cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("policy", ["static", "semistatic", "ggarray"])
def test_decode_policies_identical_logits(policy):
    cfg, params = _setup("qwen2.5-3b", cache_policy=policy)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0, cfg.vocab_size, jnp.int32)
    _, caches = steps.prefill(params, toks[:, :S], cfg, capacity_hint=S + 2, policy=policy)
    got, _ = steps.decode_step(params, toks[:, S], caches, jnp.int32(S), cfg)
    logits_full, _ = transformer.forward(params, toks, cfg)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(logits_full[:, -1]), rtol=5e-4, atol=5e-4
    )


def test_engine_ggarray_grows_without_copy_and_matches_semistatic():
    cfg, params = _setup("qwen2.5-3b", cache_b0=4)
    prompts = [[1, 2, 3], [4, 5]]
    outs = {}
    stats = {}
    for policy in ("ggarray", "semistatic"):
        eng = Engine(params, cfg, policy=policy, max_len=64)
        outs[policy] = eng.generate(prompts, max_new_tokens=14, temperature=0.0)
        stats[policy] = eng.stats
    assert outs["ggarray"] == outs["semistatic"], "policies must decode identically"
    assert stats["ggarray"].grow_events >= 1
    assert stats["ggarray"].copied_bytes == 0, "GGArray growth must be copy-free"
    assert stats["semistatic"].copied_bytes > 0, "semistatic growth must copy"
    # O(log n) structure recompiles for ggarray
    assert stats["ggarray"].compiles <= stats["ggarray"].grow_events + 1


def test_engine_static_serves_within_preallocated_max():
    cfg, params = _setup("qwen2.5-3b")
    eng = Engine(params, cfg, policy="static", max_len=32)
    out = eng.generate([[1, 2, 3]], max_new_tokens=6)
    assert len(out[0]) == 3 + 6
    assert eng.stats.grow_events == 0
