"""Trace-count regression: bucketed padding bounds prefill compilation.

Ten requests with ten *distinct* prompt lengths must compile a number of
prefill traces bounded by the bucket table — at most one per
(bucket width, first-chunk flag) pair — never one per length.  This is the
whole point of bucketed admission: O(log chunk) traces for arbitrary
length fleets.  The engines here pre-carve the pool (``initial_slabs``)
and page table (``max_pages_hint``) so the pool-shape components of the
trace key stay constant and the bound is exact.

A second engine over the same config must hit the shared jit cache and
compile *nothing*: the step functions are module-level ``lru_cache``
factories keyed on the frozen ``ModelConfig``, not per-instance closures —
verified with a ``jax.monitoring`` compile-event spy.
"""
import jax
import jax.monitoring
import numpy as np

from repro.configs import reduced
from repro.models import transformer
from repro.serving.engine import BatchEngine

DISTINCT_LENGTHS = [1, 2, 3, 5, 7, 9, 13, 21, 33, 40]
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


def _setup():
    cfg = reduced("qwen2.5-3b", cache_b0=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 50, L)] for L in lengths]


def test_ten_lengths_compile_bucket_bounded_traces():
    cfg, params = _setup()
    prompts = _prompts(DISTINCT_LENGTHS)
    assert len({len(p) for p in prompts}) == len(prompts)  # all distinct
    be = BatchEngine(
        params, cfg, max_batch=4, initial_slabs=64, max_pages_hint=16
    )
    be.run_all(prompts, 2)
    n_buckets = len(be.sched.buckets)
    assert be.stats.prefill_traces <= 2 * n_buckets, (
        f"{be.stats.prefill_traces} prefill traces for {n_buckets} buckets"
    )
    assert be.stats.prefill_traces < len(prompts), (
        "trace count scaled with distinct lengths — bucketing is broken"
    )
    # every prompt token ran: ceil(L / C) chunks per request
    C = be.sched.C
    assert be.stats.prefill_chunks == sum(-(-L // C) for L in DISTINCT_LENGTHS)
    # the pre-carve really did pin the pool: no demand growth → no key churn
    assert be.stats.pool_grow_events == 0


def test_second_engine_compiles_nothing():
    cfg, params = _setup()
    prompts = _prompts([5, 33, 40])
    kw = dict(max_batch=2, initial_slabs=32, max_pages_hint=16)
    first = BatchEngine(params, cfg, **kw).run_all(prompts, 3)

    compiles: list[str] = []

    def spy(event, duration, **attrs):
        if event == COMPILE_EVENT:
            compiles.append(event)

    jax.monitoring.register_event_duration_secs_listener(spy)
    try:
        warm = BatchEngine(params, cfg, **kw).run_all(prompts, 3)
    finally:
        from jax._src import monitoring as _mon

        _mon._unregister_event_duration_listener_by_callback(spy)
    assert warm == first
    assert not compiles, (
        f"warm engine recompiled {len(compiles)} traces — the jit cache "
        "is per-instance instead of shared"
    )
