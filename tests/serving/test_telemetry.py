"""Unified telemetry (ISSUE 8): timeline ⇔ legacy stats reconciliation and
the zero-sync contract on the decode hot path.

Acceptance: a ``BatchEngine.run()`` over ≥ 8 ragged requests produces a
timeline export (JSON + Chrome trace) whose per-request TTFT/TPOT and
per-step pool gauges reconcile **exactly** with the legacy ``BatchStats``
view, and a transfer-guard test proves the instrumentation adds zero
device→host transfers to the append/decode hot path.
"""
import json

import jax
import pytest

from repro.configs import reduced
from repro.models import transformer
from repro.serving.engine import BatchEngine, Engine

from test_batch_engine import RAGGED_PROMPTS, _setup


def test_timeline_export_reconciles_with_legacy_stats(tmp_path):
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=8)
    rids = [be.submit(p, 7) for p in RAGGED_PROMPTS]
    assert len(rids) >= 8
    out = be.run()
    assert all(len(out[r]) == len(p) + 7 for r, p in zip(rids, RAGGED_PROMPTS))

    jpath = be.obs.export_json(str(tmp_path / "serve_timeline.json"))
    cpath = be.obs.export_chrome(str(tmp_path / "serve_trace.json"))
    doc = json.loads(open(jpath).read())
    spans = doc["timeline"]["spans"]
    events = doc["timeline"]["events"]
    counters = doc["metrics"]["counters"]
    gauges = doc["metrics"]["gauges"]

    # span/event counts ⇔ legacy counters
    by = lambda n: [s for s in spans if s["name"] == n]
    ev = lambda n: [e for e in events if e["name"] == n]
    assert len(by("decode_step")) == be.stats.decode_steps > 0
    assert len(by("prefill_chunk")) == be.stats.prefill_chunks > 0
    assert len(ev("submit")) == len(RAGGED_PROMPTS)
    assert len(ev("admit")) == be.stats.admitted == len(RAGGED_PROMPTS)
    assert len(ev("complete")) == be.stats.completed == len(RAGGED_PROMPTS)
    assert len(ev("first_token")) == len(RAGGED_PROMPTS)
    assert len(ev("pool_grow")) == be.stats.pool_grow_events
    assert counters["serve.decode_steps"] == be.stats.decode_steps

    # per-request TTFT/TPOT: histogram series, timeline event, and the
    # Request record all carry the same float (recorded once)
    ttft = be.obs.registry.histogram("serve.ttft_ms")
    tpot = be.obs.registry.histogram("serve.tpot_ms")
    first_by_rid = {e["attrs"]["rid"]: e["attrs"]["ttft_ms"] for e in ev("first_token")}
    for rid in rids:
        req = be._requests[rid]
        assert ttft.values(rid=rid) == [req.ttft * 1e3]
        assert first_by_rid[rid] == req.ttft * 1e3
        assert req.ttft >= req.queue_wait >= 0
        if req.generated > 1:
            assert tpot.values(rid=rid) == [req.tpot_ms]

    # per-step pool gauges ⇔ legacy peaks, and every utilization sample is
    # internally consistent (= live / capacity of the same instant)
    assert gauges["pool.live_tokens"]["hwm"] == be.stats.peak_live_tokens
    assert gauges["pool.capacity_tokens"]["hwm"] == be.stats.peak_pool_tokens
    samples = doc["timeline"]["samples"]
    series = {}
    for s in samples:
        series.setdefault(s["name"], []).append(s["value"])
    live, cap, util = (
        series["pool.live_tokens"],
        series["pool.capacity_tokens"],
        series["pool.utilization"],
    )
    assert len(live) == len(cap) == len(util)
    assert max(live) == be.stats.peak_live_tokens
    assert max(cap) == be.stats.peak_pool_tokens
    for lv, cp, u in zip(live, cap, util):
        assert u == (lv / cp if cp else 0.0)

    # Chrome trace: structurally valid, same span population
    chrome = json.loads(open(cpath).read())
    te = chrome["traceEvents"]
    assert {e["ph"] for e in te} <= {"X", "i", "C"}
    durs = [e for e in te if e["ph"] == "X"]
    assert len(durs) == len(spans)
    for e in durs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and "name" in e


def test_decode_hot_path_adds_zero_device_to_host_transfers(monkeypatch):
    """Steady-state decode (no stop token, no prefill in flight): N fully
    instrumented step() calls issue zero device→host transfers.  The spy on
    ``jax.device_get`` is the teeth (the transfer guard cannot fire on CPU);
    recorded spans prove the telemetry was live during the guarded window.
    """
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=4)
    for p in RAGGED_PROMPTS[:4]:
        be.submit(p, 30)
    # drain admission + chunked prefill so only decode remains
    while be.sched.prefilling or be.sched.pending:
        be.step()
    assert all(be.sched.phase[r.slot] == "decode"
               for r in be._slots if r is not None)

    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    spans_before = len(be.obs.tracer.spans)
    steps_before = be.stats.decode_steps
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(5):
            be.step()
    assert calls == [], "decode hot path must not read the device"
    assert be.stats.decode_steps == steps_before + 5
    new_spans = be.obs.tracer.spans[spans_before:]
    assert [s.name for s in new_spans] == ["decode_step"] * 5


def test_host_sync_audit_counts_every_device_get(monkeypatch):
    """Satellite fix: ``stats.host_syncs`` counts ALL device→host reads —
    stop drains, the final stream/first-token drains — not just stop checks.
    A spy on ``jax.device_get`` over a whole run() must agree exactly."""
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=2, stop_token=0)
    for p in RAGGED_PROMPTS[:3]:
        be.submit(p, 5)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    be.run()
    assert be.stats.host_syncs == len(calls) > 0
    syncs = be.obs.registry.counter("serve.host_syncs")
    assert syncs.value(site="stop_drain") == be.stats.decode_steps
    assert syncs.value(site="first_token_drain") == 1
    assert syncs.value(site="stream_drain") == 1
    # the debug checker's reads are audited too
    before = syncs.total()
    be.check_free_list()
    assert syncs.value(site="free_list_debug") == syncs.total() - before > 0


def test_engine_generate_audits_token_drain(monkeypatch):
    cfg, params = _setup()
    eng = Engine(params, cfg, policy="ggarray", max_len=32)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    eng.generate([[1, 2, 3]], max_new_tokens=4)
    syncs = eng.obs.registry.counter("serve.host_syncs")
    assert syncs.value(site="token_drain") == 1
    assert len(calls) == 1, "one transfer per generation, after the loop"


def test_peak_live_tokens_sees_inflight_chunked_prefill():
    """Satellite fix: tokens already prefilled into pool slabs by in-flight
    chunks count toward the live high-water mark even while the slot's
    published length is still 0."""
    cfg, params = _setup()
    C = cfg.attention_chunk  # 32 in the reduced config
    prompt = list(range(1, 2 * C - 7))  # 2 chunks: C then C−8
    be = BatchEngine(params, cfg, max_batch=2, max_chunks_per_step=1)
    rid = be.submit(prompt, 2)
    be.step()  # admit + first chunk only — decode hasn't started
    assert be.live_tokens == 0, "published length must still be 0"
    assert be.stats.peak_live_tokens >= C, (
        f"peak {be.stats.peak_live_tokens} missed the in-flight chunk of {C}"
    )
    out = be.run()
    # ...and decode growth keeps pushing the high-water mark afterwards
    assert be.stats.peak_live_tokens >= len(prompt) + 1
    assert len(out[rid]) == len(prompt) + 2


def test_instrumented_decode_hot_path_stays_zero_sync(monkeypatch):
    """ISSUE 10 acceptance: with the device counter plane ON, steady-state
    decode still issues zero device→host transfers — counter vectors ride
    the step as device data and pend in the plane until an explicit drain."""
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=4, instrument=True)
    for p in RAGGED_PROMPTS[:4]:
        be.submit(p, 30)
    while be.sched.prefilling or be.sched.pending:
        be.step()
    be.drain_device_counters()  # flush prefill-era pends before the guard

    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    pend0 = be.devctr.pending
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(5):
            be.step()
    assert calls == [], "instrumented decode must not read the device"
    assert be.devctr.pending == pend0 + 5, "each step pends one vector"
    # the drain point works and actually saw the steps
    monkeypatch.undo()
    got = be.drain_device_counters()
    assert be.devctr.pending == 0
    assert any(v > 0 for v in got.values())


def test_instrumentation_is_bit_exact_and_counts_kernel_work():
    cfg, params = _setup()
    prompts = RAGGED_PROMPTS[:5]
    plain = BatchEngine(params, cfg, max_batch=4)
    inst = BatchEngine(params, cfg, max_batch=4, instrument=True)
    out_plain = plain.run_all(prompts, 6)
    out_inst = inst.run_all(prompts, 6)
    assert out_inst == out_plain, "counters must not perturb the tokens"
    ctr = inst.drain_device_counters()
    # the paged serving path exercises gather + attend + slab appends
    assert ctr["paged_attend.lanes"] > 0
    assert ctr["paged_gather.launches"] > 0
    assert ctr["slab_append.active_lanes"] > 0
    # drained values land in the shared registry under the device. prefix
    snap = inst.obs.snapshot()["counters"]
    assert snap["device.paged_attend.lanes"] == ctr["paged_attend.lanes"]
    # an uninstrumented engine records nothing on the plane
    assert all(v == 0 for v in plain.drain_device_counters().values())


def test_instrument_off_compiles_nothing_after_instrumented_runs():
    """The instrument flag rides the frozen config into the shared jit
    factories: an instrumented fleet must not evict or fracture the plain
    engine's traces (OFF stays provably free)."""
    import jax.monitoring

    from test_trace_count import COMPILE_EVENT

    cfg, params = _setup()
    prompts = RAGGED_PROMPTS[:3]
    kw = dict(max_batch=2, initial_slabs=32, max_pages_hint=16)
    first = BatchEngine(params, cfg, **kw).run_all(prompts, 3)
    BatchEngine(params, cfg, instrument=True, **kw).run_all(prompts, 3)

    compiles: list[str] = []

    def spy(event, duration, **attrs):
        if event == COMPILE_EVENT:
            compiles.append(event)

    jax.monitoring.register_event_duration_secs_listener(spy)
    try:
        warm = BatchEngine(params, cfg, **kw).run_all(prompts, 3)
    finally:
        from jax._src import monitoring as _mon

        _mon._unregister_event_duration_listener_by_callback(spy)
    assert warm == first
    assert not compiles, (
        f"plain engine recompiled {len(compiles)} traces after an "
        "instrumented engine ran — the instrument flag leaked into the key"
    )


def test_views_share_one_registry():
    """The legacy stats views are reads of the same registry the timeline
    snapshots — not copies that can drift."""
    cfg, params = _setup()
    be = BatchEngine(params, cfg, max_batch=2)
    be.run_all(RAGGED_PROMPTS[:2], 3)
    snap = be.obs.snapshot()
    assert snap["counters"]["serve.admitted"] == be.stats.admitted
    assert snap["counters"]["serve.completed"] == be.stats.completed
    assert (
        snap["gauges"]["pool.live_tokens"]["hwm"] == be.stats.peak_live_tokens
    )
    assert be.stats._reg is be.obs.registry is be.sched.obs.registry
