"""GGArray token-packing pipeline: order, balance, and phase transition."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # property tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.data.packing import Packer


def test_pack_preserves_all_tokens():
    p = Packer(nblocks=2, b0=4)
    docs = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10]]
    for d in docs:
        p.add_document(d)
    assert p.total_tokens == sum(len(d) for d in docs)
    out = p.pack(batch=2, seq=8, pad_id=0)
    got = sorted(np.asarray(out["tokens"]).reshape(-1)[np.asarray(out["loss_mask"]).reshape(-1)])
    assert got == sorted(t for d in docs for t in d)


def test_blocks_stay_balanced():
    p = Packer(nblocks=4, b0=4)
    for i in range(12):
        p.add_document([i] * 5)
    sizes = np.asarray(p.sizes)
    assert sizes.max() - sizes.min() <= 5  # greedy least-loaded balance


@given(st.lists(st.integers(1, 12), min_size=1, max_size=10), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_token_conservation(doc_lens, seed):
    rng = np.random.default_rng(seed)
    p = Packer(nblocks=2, b0=4)
    all_tokens = []
    for n in doc_lens:
        doc = rng.integers(1, 1000, n).tolist()
        all_tokens += doc
        p.add_document(doc)
    total = len(all_tokens)
    out = p.pack(batch=1, seq=max(total, 1))
    got = np.asarray(out["tokens"]).reshape(-1)[: total]
    assert sorted(got.tolist()) == sorted(all_tokens)


def test_arena_backend_matches_pipeline_backend():
    """Same documents through shared-pool slabs → identical packed batches."""
    rng = np.random.default_rng(11)
    docs = [rng.integers(1, 500, rng.integers(1, 30)).tolist() for _ in range(20)]
    outs = {}
    for backend in ("pipeline", "arena"):
        p = Packer(nblocks=4, b0=16, backend=backend)
        for d in docs:
            p.add_document(d)
        outs[backend] = p.pack(batch=4, seq=48)
        p.add_document([1, 2, 3])  # ingestion resumes after pack (thaw)
    np.testing.assert_array_equal(
        np.asarray(outs["pipeline"]["tokens"]), np.asarray(outs["arena"]["tokens"])
    )
    np.testing.assert_array_equal(
        np.asarray(outs["pipeline"]["loss_mask"]),
        np.asarray(outs["arena"]["loss_mask"]),
    )


def test_arena_backend_is_sync_free():
    p = Packer(nblocks=4, b0=16, backend="arena")
    for i in range(10):
        p.add_document([i] * 7)
    assert p.stats.host_syncs == 0
