"""Subprocess worker: runs a small model on an 8-device host mesh and prints
parity results. Launched by test_multidevice.py with its own XLA_FLAGS."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.synthetic import make_batch
from repro.distributed import sharding as sh
from repro.distributed.context import activation_mesh
from repro.models import transformer
from repro.optim import adamw
from repro.train import step as train_mod


def elastic_main(tmpdir: str) -> None:
    """Save under a (2,4) mesh, restore onto (4,2) and (1,1) — values exact."""
    from repro.checkpoint import ckpt

    cfg = configs.reduced("qwen2.5-3b")
    state = train_mod.init_train_state(jax.random.PRNGKey(3), cfg)
    mesh_a = jax.make_mesh((2, 4), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh_a = sh.param_shardings(state.params, cfg, mesh_a)
    params_a = jax.tree.map(jax.device_put, state.params, sh_a)
    ckpt.save(tmpdir, 1, params_a)

    results = {}
    for shape in ((4, 2), (1, 1)):
        mesh_b = jax.make_mesh(shape, ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sh_b = sh.param_shardings(state.params, cfg, mesh_b)
        restored, _ = ckpt.restore(tmpdir, 1, state.params, shardings=sh_b)
        diff = max(
            float(jnp.max(jnp.abs(a.astype(jnp.float32) - np.asarray(b, np.float32))))
            for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state.params))
        )
        results[f"mesh{shape}"] = diff
    print(json.dumps({"devices": jax.device_count(), "elastic_max_diff": max(results.values()), **results}))


def main() -> None:
    assert jax.device_count() == 8, jax.device_count()
    if len(sys.argv) > 2 and sys.argv[1] == "elastic":
        elastic_main(sys.argv[2])
        return
    arch = sys.argv[1] if len(sys.argv) > 1 else "dbrx-132b"
    # reduced MoE family: 4 experts → tp=4 EP; batch 4 → dp=2
    cfg = configs.reduced(arch)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    batch = make_batch(cfg, 4, 32)
    state = train_mod.init_train_state(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)

    # single-device reference
    ref_state, ref_metrics = jax.jit(
        lambda s, b: train_mod.train_step(s, b, cfg, opt_cfg)
    )(state, batch)
    ref_loss = float(ref_metrics["loss"])

    # sharded run under the mesh (params/batch constrained via shardings)
    shardings = sh.param_shardings(state.params, cfg, mesh)
    sharded_params = jax.tree.map(jax.device_put, state.params, shardings)
    sharded_state = train_mod.TrainState(
        params=sharded_params, opt=adamw.init(sharded_params), ef=None
    )
    with mesh, activation_mesh(mesh):
        out_state, metrics = jax.jit(
            lambda s, b: train_mod.train_step(s, b, cfg, opt_cfg)
        )(sharded_state, batch)
        loss = float(metrics["loss"])

    # gradient-updated params parity (spot check a few leaves)
    ref_leaves = jax.tree.leaves(ref_state.params)
    got_leaves = jax.tree.leaves(out_state.params)
    max_diff = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - np.asarray(b, np.float32))))
        for a, b in zip(got_leaves[:8], ref_leaves[:8])
    )
    print(json.dumps({
        "devices": jax.device_count(),
        "ref_loss": ref_loss,
        "sharded_loss": loss,
        "loss_diff": abs(ref_loss - loss),
        "param_max_diff": max_diff,
    }))


if __name__ == "__main__":
    main()
