"""Multi-device parity: the sharded (mesh + shard_map MoE) train step must
match the single-device run. Runs in a subprocess with 8 host devices so the
main test session keeps its real device count."""
import json
import os
import subprocess
import sys

import jax.sharding
import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_mesh_worker.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "..", "src")

# launch/mesh.py builds meshes with explicit AxisType annotations, which the
# container's jax 0.4.37 predates — pre-existing failures, green-or-skip here.
needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable (container jax 0.4.37; "
    "launch/mesh.py needs a newer jax)",
)


@needs_axis_type
@pytest.mark.parametrize("arch", ["dbrx-132b", "qwen2.5-3b"])
def test_sharded_train_step_matches_single_device(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, WORKER, arch],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"worker failed:\n{out.stdout}\n{out.stderr[-3000:]}"
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["devices"] == 8
    # Two tolerated effects: fp32 reduction-order skew, and (MoE archs) the
    # shard-local dispatch capacity — per-shard buffers drop at local
    # boundaries vs one global boundary, a documented semantic of the
    # production path (models/moe.py). Both stay well under these bounds.
    assert result["loss_diff"] < 2e-2, result
    assert result["param_max_diff"] < 5e-2, result


@needs_axis_type
def test_elastic_reshard_across_mesh_shapes(tmp_path):
    """Checkpoint saved under a (2,4) mesh restores bit-exactly onto (4,2)
    and (1,1) meshes — the elastic-scaling path."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, WORKER, "elastic", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"worker failed:\n{out.stdout}\n{out.stderr[-3000:]}"
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["elastic_max_diff"] == 0.0, result
