"""Loss masking (packing pipeline → train step integration)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced
from repro.data.packing import Packer
from repro.models import transformer
from repro.train.step import loss_fn


def test_masked_loss_ignores_padding():
    cfg = reduced("qwen2.5-3b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size, jnp.int32)
    full_mask = jnp.ones((2, 16), bool)
    loss_full, _ = loss_fn(params, {"tokens": toks, "loss_mask": full_mask}, cfg)
    loss_nomask, _ = loss_fn(params, {"tokens": toks}, cfg)
    np.testing.assert_allclose(float(loss_full), float(loss_nomask), rtol=1e-6)

    # corrupting only *masked-out* positions must not change the loss
    half = full_mask.at[:, 8:].set(False)
    toks_dirty = toks.at[:, 9:].set(3)  # targets 9.. are masked (shifted by 1)
    l1, _ = loss_fn(params, {"tokens": toks, "loss_mask": half}, cfg)
    # note: dirty tokens would change hidden states of masked positions only
    # for targets — inputs beyond position 8 still feed forward, so compare
    # against the same inputs with masked targets zeroed influence:
    l2, _ = loss_fn(params, {"tokens": toks, "loss_mask": half}, cfg)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    assert abs(float(l1) - float(loss_full)) > 1e-6  # mask actually selects


def test_packer_to_train_step_end_to_end():
    cfg = reduced("qwen2.5-3b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    p = Packer(nblocks=2, b0=8)
    for d in ([1, 2, 3, 4], [5, 6], [7, 8, 9]):
        p.add_document(d)
    batch = p.pack(batch=2, seq=8)
    loss, metrics = loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
