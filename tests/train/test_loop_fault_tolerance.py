"""Fault tolerance: crash → restart → bitwise-identical trajectory; elastic restore."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import reduced
from repro.train import loop as loop_mod
from repro.train import step as step_mod


CFG = reduced("qwen1.5-0.5b", n_layers=2)


def _loop(tmp, **kw):
    base = dict(steps=8, batch=2, seq=16, ckpt_dir=tmp, ckpt_every=3, log_every=100)
    base.update(kw)
    return loop_mod.LoopConfig(**base)


def test_crash_resume_matches_uninterrupted(tmp_path):
    # uninterrupted reference
    ref = loop_mod.run(CFG, _loop(str(tmp_path / "ref")))["losses"]

    # crashed run: fails at step 5 (after the step-3 checkpoint)
    d = str(tmp_path / "crash")
    with pytest.raises(RuntimeError, match="injected failure"):
        loop_mod.run(CFG, _loop(d, fail_at_step=5))
    # restart — resumes from step 3 and finishes
    out = loop_mod.run(CFG, _loop(d))
    assert out["start_step"] == 3
    np.testing.assert_array_equal(np.asarray(out["losses"]), np.asarray(ref[3:]))


def test_async_checkpoint_resume(tmp_path):
    d = str(tmp_path / "async")
    loop_mod.run(CFG, _loop(d, async_ckpt=True, steps=6))
    assert ckpt.latest_step(d) == 6


def test_checkpoint_roundtrip_exact(tmp_path):
    state = step_mod.init_train_state(jax.random.PRNGKey(0), CFG)
    path = ckpt.save(str(tmp_path), 7, state, metadata={"next_step": 7})
    restored, meta = ckpt.restore(str(tmp_path), 7, state)
    assert meta["next_step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable (container jax 0.4.37; "
    "launch/mesh.py needs a newer jax)",
)
def test_elastic_restore_onto_mesh_shardings(tmp_path):
    """A host-saved checkpoint restores under explicit (1,1) mesh shardings."""
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_host_mesh

    state = step_mod.init_train_state(jax.random.PRNGKey(1), CFG)
    ckpt.save(str(tmp_path), 1, state.params)
    mesh = make_host_mesh(1, 1)
    shardings = sh.param_shardings(state.params, CFG, mesh)
    restored, _ = ckpt.restore(str(tmp_path), 1, state.params, shardings=shardings)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_tmp_dir_is_ignored(tmp_path):
    d = tmp_path / "step_00000009.tmp"
    d.mkdir(parents=True)
    assert ckpt.latest_step(str(tmp_path)) is None


def test_grad_compression_error_feedback_converges():
    """int8-compressed grads with error feedback still reduce loss.

    Deflaked: a 6-step run compared single-step losses, which sat inside the
    quantization noise floor (~0.007 margin).  Run past the 5-step LR warmup
    and compare window means so one noisy step can't flip the verdict; the
    seed is fixed (LoopConfig.seed=0) so the trajectory is reproducible.
    """
    out = loop_mod.run(
        CFG, loop_mod.LoopConfig(steps=20, batch=2, seq=16, grad_compression=True, log_every=100)
    )
    losses = np.asarray(out["losses"])
    assert losses[-3:].mean() < losses[:3].mean()
    assert np.all(np.isfinite(losses))
