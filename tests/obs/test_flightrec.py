"""Flight recorder (ISSUE 10): bounded event ring, postmortem bundles, the
offline loader, and the acceptance scenario — an engineered refcount
violation in a live ``BatchEngine`` must produce a bundle that round-trips
through ``repro.obs.dump`` and names the offending slab id.
"""
import json

import jax
import pytest

from repro.obs import FlightRecorder, ServingTimeline
from repro.obs import dump as dump_mod
from repro.obs.flightrec import SCHEMA


# --------------------------------------------------------------------------
# ring + bundle mechanics
# --------------------------------------------------------------------------

def test_ring_is_bounded_and_keeps_the_most_recent_events():
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.note("tick", i=i)
    assert len(fr) == 4
    b = fr.bundle(reason="test")
    assert b["events_recorded"] == 10
    assert [e["attrs"]["i"] for e in b["events"]] == [6, 7, 8, 9]
    seqs = [e["seq"] for e in b["events"]]
    assert seqs == sorted(seqs)


def test_timeline_events_feed_the_ring_automatically():
    tl = ServingTimeline(flight_capacity=8)
    tl.event("admit", rid=3)
    tl.event("complete", rid=3)
    names = [e["name"] for e in tl.flight.events]
    assert names == ["admit", "complete"]
    assert tl.flight.events[0]["attrs"]["rid"] == 3


def test_bundle_round_trips_through_loader(tmp_path):
    fr = FlightRecorder(capacity=8)
    fr.note("grow", slabs=2)
    err = AssertionError("refcounts drift from page tables: [5]")
    path = fr.dump(
        reason="refcount_mismatch",
        error=err,
        state={"invariant": {"offending_slabs": [5]}, "n_slabs": 8},
        metrics={"counters": {"serve.admitted": 1}},
        device_counters={"slab_append.waves": 3.0},
        directory=str(tmp_path),
    )
    assert path is not None and path.startswith(str(tmp_path))
    b = dump_mod.load_bundle(path)
    assert b["schema"] == SCHEMA
    assert b["reason"] == "refcount_mismatch"
    assert b["error"]["type"] == "AssertionError"
    assert b["state"]["invariant"]["offending_slabs"] == [5]
    assert b["device_counters"]["slab_append.waves"] == 3.0
    assert fr.last_bundle["reason"] == "refcount_mismatch"
    # the pretty-printer runs end to end and surfaces the headline facts
    text = dump_mod.summarize(b)
    assert "refcount_mismatch" in text
    assert "5" in text


def test_dump_without_directory_keeps_bundle_in_process(monkeypatch):
    monkeypatch.delenv("REPRO_FLIGHTREC_DIR", raising=False)
    fr = FlightRecorder()
    assert fr.dump(reason="x", state={}) is None
    assert fr.last_bundle["reason"] == "x"


def test_dump_env_var_directory(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FLIGHTREC_DIR", str(tmp_path / "artifacts"))
    fr = FlightRecorder()
    path = fr.dump(reason="env_target", state={})
    assert path is not None
    assert json.load(open(path))["reason"] == "env_target"


def test_dump_main_cli_smoke(tmp_path, capsys):
    fr = FlightRecorder()
    fr.note("admit", rid=0)
    path = fr.dump(reason="smoke", state={"n_slots": 2}, directory=str(tmp_path))
    assert dump_mod.main([path]) == 0
    out = capsys.readouterr().out
    assert "smoke" in out and "admit" in out


def test_loader_rejects_non_bundles(tmp_path):
    p = tmp_path / "junk.json"
    p.write_text(json.dumps({"schema": "other/1"}))
    with pytest.raises(ValueError):
        dump_mod.load_bundle(str(p))


def test_jsonable_handles_numpy_state():
    import numpy as np

    fr = FlightRecorder()
    fr.note("ev", ids=np.asarray([1, 2]), val=np.float32(0.5))
    b = fr.bundle(reason="np", state={"refs": np.asarray([0, 1])})
    json.dumps(b)  # fully serializable
    assert b["events"][0]["attrs"]["ids"] == [1, 2]
    assert b["state"]["refs"] == [0, 1]


# --------------------------------------------------------------------------
# acceptance: engineered invariant violation → named offending slab
# --------------------------------------------------------------------------

def _engine():
    from repro.configs import reduced
    from repro.models import transformer
    from repro.serving.engine import BatchEngine

    cfg = reduced("qwen2.5-3b", cache_b0=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return BatchEngine(params, cfg, max_batch=2, instrument=True)


def test_refcount_violation_dumps_bundle_naming_offending_slab(
    tmp_path, monkeypatch
):
    monkeypatch.setenv("REPRO_FLIGHTREC_DIR", str(tmp_path))
    be = _engine()
    be.submit(list(range(1, 10)), 8)
    for _ in range(3):
        be.step()
    claimed = [s for s in range(be.alloc.n_slabs) if not be.alloc.free[s]]
    assert claimed, "the request must hold at least one slab"
    be.alloc.refcount[claimed[0]] += 1  # engineered corruption
    with pytest.raises(AssertionError):
        be.check_free_list()
    assert be.obs.flight.last_path is not None
    b = dump_mod.load_bundle(be.obs.flight.last_path)
    assert b["reason"] == "refcount_mismatch"
    inv = b["state"]["invariant"]
    assert inv["check"] == "refcount_conservation"
    assert inv["offending_slabs"] == [claimed[0]]
    exp = inv["expected_refcount"][claimed[0]]
    act = inv["actual_refcount"][claimed[0]]
    assert act == exp + 1
    # the bundle carries live context: scheduler state, events, counters
    assert b["state"]["scheduler"]["phase"].count("decode") == 1
    assert b["events"], "ring must hold the admit/step events"
    assert any(v > 0 for v in (b["device_counters"] or {}).values())
    # the postmortem renderer names the slab too
    assert str(claimed[0]) in dump_mod.summarize(b)


def test_engine_step_failure_is_dumped_once(monkeypatch, tmp_path):
    """A failure inside step() writes one bundle; nested handlers must not
    double-dump the same exception."""
    monkeypatch.setenv("REPRO_FLIGHTREC_DIR", str(tmp_path))
    be = _engine()
    be.submit([1, 2, 3], 4)
    boom = RuntimeError("injected")

    def explode():
        raise boom

    monkeypatch.setattr(be, "_step_inner", explode)
    with pytest.raises(RuntimeError):
        be.step()
    first = be.obs.flight.last_path
    assert first is not None
    assert dump_mod.load_bundle(first)["reason"] == "step_failure"
    with pytest.raises(RuntimeError):
        be.step()  # same exception object re-raised → already marked
    assert be.obs.flight.last_path == first


def test_arena_invariant_violation_dumps_bundle(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FLIGHTREC_DIR", str(tmp_path))
    import jax.numpy as jnp

    from repro.pool.arena import SlabArena

    import numpy as np

    ar = SlabArena(3, 4, initial_slabs=2, instrument=True)
    elems = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)
    ar.append(elems, np.ones((3, 2), bool))  # claim slabs first
    ar.check_invariants()  # clean arena passes
    ar.alloc.refcount[0] += 1
    with pytest.raises(AssertionError):
        ar.check_invariants()
    b = dump_mod.load_bundle(ar.flight.last_path)
    assert b["reason"] == "refcount_mismatch"
    assert b["state"]["invariant"]["offending_slabs"] == [0]
