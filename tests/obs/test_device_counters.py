"""Device counter plane (ISSUE 10): slot layout, tape scoping, the
zero-sync drain contract, and in-kernel counters vs their jnp oracles.

The parity tests are exact (``assert_array_equal`` on whole counter
vectors): the ops wrappers promise the in-kernel block and the ``use_ref``
oracle count the *same padded-wave geometry*, so any drift means the
instrumentation is lying about the kernel it rides.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ggarray as gg
from repro.kernels.flatten import ops as fl_ops
from repro.kernels.paged import ops as pg_ops
from repro.kernels.push_back import ops as pb_ops
from repro.obs import DeviceCounterPlane, MetricsRegistry, device

SPACES = ["vmem", "hbm"]


# --------------------------------------------------------------------------
# layout + tape
# --------------------------------------------------------------------------

def test_slot_layout_is_fixed_and_packs_round_trip():
    assert len(device.SLOTS) == device.NSLOTS <= device.CTR_LANES
    assert len(set(device.SLOTS)) == device.NSLOTS  # no duplicate names
    vec = device.pack(**{"push_back.waves": 3, "paged_attend.masked_lanes": 7})
    d = device.as_dict(vec)
    assert d["push_back.waves"] == 3.0
    assert d["paged_attend.masked_lanes"] == 7.0
    assert sum(d.values()) == 10.0  # unnamed slots stay zero
    # from_block reads row 0's leading lanes of the in-kernel block
    blk = jnp.zeros((device.CTR_ROWS, device.CTR_LANES), jnp.int32)
    blk = blk.at[0, device.SLOT_INDEX["flatten.rows_touched"]].set(11)
    assert device.as_dict(device.from_block(blk))["flatten.rows_touched"] == 11.0


def test_record_is_noop_without_a_tape_and_nests_innermost():
    device.record(device.pack(**{"push_back.waves": 99}))  # must not raise
    assert not device.recording()
    with device.tape() as outer:
        device.record(device.pack(**{"push_back.waves": 1}))
        with device.tape() as inner:
            assert device.recording()
            device.record(device.pack(**{"push_back.waves": 10}))
        device.record(device.pack(**{"push_back.waves": 2}))
    assert not device.recording()
    assert device.as_dict(outer.total())["push_back.waves"] == 3.0
    assert device.as_dict(inner.total())["push_back.waves"] == 10.0
    # an empty tape still totals to a well-formed zero vector
    with device.tape() as t:
        pass
    assert sum(device.as_dict(t.total()).values()) == 0.0


def test_plane_never_syncs_until_counters_are_read(monkeypatch):
    """add() and flush() are device-only; the single drain point is the
    registry read — same contract as ``Counter.add_lazy``."""
    reg = MetricsRegistry()
    plane = DeviceCounterPlane(reg)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    plane.add(device.pack(**{"slab_append.waves": 1, "slab_append.lanes": 128}))
    plane.add(device.pack(**{"slab_append.waves": 1, "slab_append.lanes": 128}))
    assert plane.pending == 2
    assert calls == [], "add() must be a list append"
    plane.flush()
    assert plane.pending == 0
    assert calls == [], "flush() hands device scalars to add_lazy — no sync"
    got = plane.counters()
    assert len(calls) > 0, "counters() is the drain point"
    assert got["slab_append.waves"] == 2.0
    assert got["slab_append.lanes"] == 256.0
    # drained counters live under the device. prefix in the shared registry
    assert reg.counter("device.slab_append.waves").total() == 2.0


# --------------------------------------------------------------------------
# in-kernel counters == jnp oracle, per kernel family
# --------------------------------------------------------------------------

def _fleet(rng, S, N, P, npages):
    pages = np.full((N, P), -1, np.int32)
    perm = rng.permutation(S)
    k = 0
    for i, c in enumerate(npages):
        for p in range(c):
            pages[i, p] = perm[k]
            k += 1
    return jnp.asarray(pages)


@pytest.mark.parametrize("memory_space", SPACES)
def test_push_back_counters_match_oracle(memory_space):
    rng = np.random.default_rng(3)
    nblocks, b0, m = 5, 2, 11
    arr = gg.init(nblocks, b0, nbuckets=2)
    elems = jnp.asarray(rng.standard_normal((nblocks, m)), jnp.float32)
    mask = jnp.asarray(rng.random((nblocks, m)) < 0.6)
    sizes = jnp.asarray(rng.integers(0, 5, (nblocks,)), jnp.int32)
    groups = (arr.buckets, arr.buckets)
    outs = pb_ops.push_back_fused_multi(
        groups, sizes, b0, (elems, elems), mask,
        memory_space=memory_space, instrument=True,
    )
    want = pb_ops.push_back_fused_multi(
        groups, sizes, b0, (elems, elems), mask, use_ref=True, instrument=True,
    )
    np.testing.assert_array_equal(np.asarray(outs[3]), np.asarray(want[3]))
    d = device.as_dict(outs[3])
    assert d["push_back.waves"] == 1.0
    assert d["push_back.active_lanes"] == float(jnp.sum(mask))
    assert d["push_back.lanes"] >= d["push_back.active_lanes"]
    assert d["push_back.lanes"] >= nblocks * m
    assert d["push_back.padded_lanes"] == d["push_back.lanes"] - nblocks * m
    # the data outputs are untouched by instrumentation
    plain = pb_ops.push_back_fused_multi(
        groups, sizes, b0, (elems, elems), mask, memory_space=memory_space,
    )
    for g_i, g_p in zip(outs[0], plain[0]):
        for a, b in zip(g_i, g_p):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(outs[2]), np.asarray(plain[2]))


def test_push_back_degenerate_empty_wave_counts_zero():
    arr = gg.init(3, 2, nbuckets=1)
    sizes = jnp.zeros((3,), jnp.int32)
    elems = jnp.zeros((3, 0), jnp.float32)
    mask = jnp.zeros((3, 0), bool)
    outs = pb_ops.push_back_fused_multi(
        (arr.buckets,), sizes, 2, (elems,), mask, instrument=True,
    )
    assert sum(device.as_dict(outs[3]).values()) == 0.0


@pytest.mark.parametrize("memory_space", SPACES)
def test_paged_gather_counters_match_oracle(memory_space):
    rng = np.random.default_rng(4)
    S, T, N, P = 11, 4, 5, 3
    pool = jnp.asarray(rng.standard_normal((S, T, 3)), jnp.float32)
    pages = _fleet(rng, S, N, P, [3, 0, 2, 1, 3])
    out, vec = pg_ops.paged_gather(
        pool, pages, memory_space=memory_space, instrument=True
    )
    want_out, want_vec = pg_ops.paged_gather(
        pool, pages, use_ref=True, memory_space=memory_space, instrument=True
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want_out))
    np.testing.assert_array_equal(np.asarray(vec), np.asarray(want_vec))
    d = device.as_dict(vec)
    live = int(np.sum(np.asarray(pages) >= 0))
    assert d["paged_gather.tiles"] == float(live)
    # masked tiles cover the −1 entries plus the walk's row-tile padding
    assert d["paged_gather.masked_tiles"] >= float(N * P - live)
    assert d["paged_gather.launches"] >= 1.0


@pytest.mark.parametrize("memory_space", SPACES)
def test_paged_attend_counters_match_oracle(memory_space):
    rng = np.random.default_rng(5)
    S, T, N, P, KH, G, D = 13, 4, 5, 3, 2, 3, 8
    pages = _fleet(rng, S, N, P, [3, 1, 2, 1, 3])
    kp = jnp.asarray(rng.standard_normal((S, T, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((S, T, KH, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((N, KH, G, D)), jnp.float32)
    lengths = jnp.asarray([9, 2, 8, 1, 12], jnp.int32)
    out, vec = pg_ops.paged_attend(
        q, kp, vp, pages, lengths, memory_space=memory_space, instrument=True
    )
    want_out, want_vec = pg_ops.paged_attend(
        q, kp, vp, pages, lengths, use_ref=True, instrument=True
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want_out))
    np.testing.assert_array_equal(np.asarray(vec), np.asarray(want_vec))
    d = device.as_dict(vec)
    # visited tiles carry T score lanes each; waste = lanes past kv_len
    assert d["paged_attend.lanes"] == d["paged_attend.tiles"] * T
    assert 0 < d["paged_attend.masked_lanes"] < d["paged_attend.lanes"]
    assert d["paged_attend.tiles_skipped"] > 0  # −1 pages were gated off


@pytest.mark.parametrize("memory_space", SPACES)
def test_flatten_counters_match_oracle(memory_space):
    rng = np.random.default_rng(6)
    nblocks, b0 = 5, 2
    arr = gg.init(nblocks, b0, nbuckets=1)
    per = rng.integers(0, 7, nblocks)
    m = max(int(per.max()), 1)
    elems = jnp.asarray(rng.standard_normal((nblocks, m)), jnp.float32)
    mask = jnp.asarray(np.arange(m)[None, :] < per[:, None])
    arr, _ = gg.push_back(arr, elems, mask)
    out, vec = fl_ops.flatten_segmented(
        arr.buckets, arr.sizes, arr.b0,
        memory_space=memory_space, instrument=True,
    )
    want_out, want_vec = fl_ops.flatten_segmented(
        arr.buckets, arr.sizes, arr.b0, use_ref=True, instrument=True
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want_out))
    np.testing.assert_array_equal(np.asarray(vec), np.asarray(want_vec))
    d = device.as_dict(vec)
    # span_rows counts elements (Σ sizes); rows_touched counts compact-block
    # rows the gather visited — nonzero whenever there is anything to move
    assert d["flatten.span_rows"] == float(jnp.sum(arr.sizes))
    assert d["flatten.launches"] == 1.0
    assert d["flatten.rows_touched"] > 0


def test_slab_append_counters_report_wave_occupancy():
    rng = np.random.default_rng(7)
    S, T, N, P, m = 14, 4, 4, 4, 3
    pages = np.asarray(_fleet(rng, S, N, P, [4, 2, 3, 4]))
    owners = np.full((S,), -1, np.int32)
    bases = np.zeros((S,), np.int32)
    for i in range(N):
        for p in range(P):
            if pages[i, p] >= 0:
                owners[pages[i, p]] = i
                bases[pages[i, p]] = p * T
    sizes = np.asarray([7, 1, 5, 10], np.int32)
    pool = jnp.asarray(rng.standard_normal((S, T)), jnp.float32)
    elems = jnp.asarray(rng.standard_normal((N, m)), jnp.float32)
    mask = jnp.asarray(rng.random((N, m)) > 0.4)
    outs = pg_ops.slab_append(
        pool, jnp.asarray(owners), jnp.asarray(bases), jnp.asarray(sizes),
        elems, mask, instrument=True,
    )
    assert len(outs) == 4
    d = device.as_dict(outs[3])
    assert d["slab_append.waves"] == 1.0
    assert d["slab_append.active_lanes"] == float(jnp.sum(mask))
    assert d["slab_append.lanes"] >= N * m
    plain = pg_ops.slab_append(
        pool, jnp.asarray(owners), jnp.asarray(bases), jnp.asarray(sizes),
        elems, mask,
    )
    assert len(plain) == 3  # instrumentation off → bare outputs


# --------------------------------------------------------------------------
# provably free when off
# --------------------------------------------------------------------------

def test_instrument_off_trace_is_unchanged_by_instrumented_traces():
    """Tracing an instrumented program must not contaminate later
    uninstrumented traces (a leaked tape would)."""
    rng = np.random.default_rng(8)
    arr = gg.init(4, 2, nbuckets=1)
    elems = jnp.asarray(rng.standard_normal((4, 5)), jnp.float32)
    mask = jnp.asarray(rng.random((4, 5)) < 0.6)
    sizes = jnp.zeros((4,), jnp.int32)

    def run_off(b, s, e, mk):
        return pb_ops.push_back_fused(b, s, 2, e, mk)

    before = str(jax.make_jaxpr(run_off)(arr.buckets, sizes, elems, mask))
    with device.tape():
        pb_ops.push_back_fused(
            arr.buckets, sizes, 2, elems, mask, instrument=True
        )
    after = str(jax.make_jaxpr(run_off)(arr.buckets, sizes, elems, mask))
    assert before == after


def test_instrument_flag_keys_the_shared_jit_cache():
    """``instrument`` rides the frozen ModelConfig: replace() with the same
    value is the SAME cached step callable (zero extra compiles when off);
    flipping it is a different program."""
    from repro.configs import reduced
    from repro.serving import engine as eng

    cfg = reduced("qwen2.5-3b", cache_b0=4)
    assert cfg.instrument is False
    same = dataclasses.replace(cfg, instrument=False)
    flipped = dataclasses.replace(cfg, instrument=True)
    assert eng._decode_step_fn(cfg) is eng._decode_step_fn(same)
    assert eng._decode_step_fn(cfg) is not eng._decode_step_fn(flipped)
    assert eng._prefill_chunk_fn(cfg) is eng._prefill_chunk_fn(same)
