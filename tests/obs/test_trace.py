"""Span tracer: nesting, instants, counter samples, JSON + Chrome export."""
import json

from repro.obs import ServingTimeline, Tracer


def test_spans_record_nesting_depth_and_attrs():
    tr = Tracer()
    with tr.span("outer", rid=1):
        with tr.span("inner", rid=1, chunk=0):
            pass
        with tr.span("inner", rid=1, chunk=1):
            pass
    assert [s.name for s in tr.spans] == ["inner", "inner", "outer"]
    assert [s.depth for s in tr.spans] == [1, 1, 0]
    outer = tr.spans[-1]
    inner0 = tr.spans[0]
    assert outer.t0_us <= inner0.t0_us
    assert outer.dur_us >= inner0.dur_us
    assert inner0.attrs == {"rid": 1, "chunk": 0}


def test_span_records_even_when_body_raises():
    tr = Tracer()
    try:
        with tr.span("boom"):
            raise RuntimeError("x")
    except RuntimeError:
        pass
    assert [s.name for s in tr.spans] == ["boom"]


def test_events_and_samples_are_ordered():
    tr = Tracer()
    tr.event("admit", rid=0)
    tr.sample("pool.utilization", 0.5)
    tr.event("complete", rid=0)
    data = tr.to_json()
    assert [e["name"] for e in data["events"]] == ["admit", "complete"]
    assert data["events"][0]["ts_us"] <= data["events"][1]["ts_us"]
    assert data["samples"][0]["value"] == 0.5


def test_chrome_trace_structure():
    tr = Tracer()
    with tr.span("prefill_chunk", rid=3):
        pass
    tr.event("admit", rid=3)
    tr.sample("pool.utilization", 0.25)
    doc = tr.to_chrome()
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert phs == {"X", "i", "C"}
    dur = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert dur["name"] == "prefill_chunk" and dur["args"] == {"rid": 3}
    assert dur["dur"] >= 0 and "ts" in dur
    cnt = next(e for e in doc["traceEvents"] if e["ph"] == "C")
    assert cnt["args"] == {"value": 0.25}


def test_exports_round_trip_through_files(tmp_path):
    tl = ServingTimeline()
    tl.registry.counter("serve.admitted").inc()
    with tl.span("decode_step", step=0):
        pass
    tl.gauge_sample("pool.utilization", 0.75)
    jpath = tl.export_json(str(tmp_path / "timeline.json"))
    cpath = tl.export_chrome(str(tmp_path / "trace.json"))
    loaded = json.loads(open(jpath).read())
    assert loaded["metrics"]["counters"]["serve.admitted"] == 1
    # gauge_sample writes both surfaces: registry gauge AND timeline sample
    assert loaded["metrics"]["gauges"]["pool.utilization"]["value"] == 0.75
    assert loaded["timeline"]["samples"][0]["value"] == 0.75
    chrome = json.loads(open(cpath).read())
    assert {e["name"] for e in chrome["traceEvents"]} == {
        "decode_step", "pool.utilization"
    }


def test_chrome_trace_timestamps_are_monotonic_per_tid():
    """Perfetto importer contract (ISSUE 10 satellite): every (pid, tid)
    stream is emitted in nondecreasing ts order, complete durations are
    non-negative, and every event names a track."""
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
        tr.event("mid")
        with tr.span("inner2"):
            pass
    tr.sample("pool.utilization", 0.5)
    tr.event("late")
    tr.sample("pool.utilization", 0.75)
    doc = tr.to_chrome()
    streams = {}
    for e in doc["traceEvents"]:
        assert "tid" in e and "pid" in e, f"{e['ph']} event lost its track"
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
        streams.setdefault((e["pid"], e["tid"]), []).append(e["ts"])
    for ts in streams.values():
        assert ts == sorted(ts), "per-tid timestamps must be monotonic"


def test_chrome_counter_samples_match_registry_series(tmp_path):
    """Every ``ph: C`` event is one recorded gauge sample, value-for-value,
    and the final sample agrees with the registry's current gauge value."""
    import json as _json

    tl = ServingTimeline()
    recorded = [0.25, 0.5, 0.125]
    for v in recorded:
        tl.gauge_sample("pool.utilization", v)
    tl.gauge_sample("pool.live_tokens", 7)
    cpath = tl.export_chrome(str(tmp_path / "trace.json"))
    te = _json.loads(open(cpath).read())["traceEvents"]
    util = [e for e in te if e["ph"] == "C" and e["name"] == "pool.utilization"]
    assert [e["args"]["value"] for e in util] == recorded
    assert [e["ts"] for e in util] == sorted(e["ts"] for e in util)
    assert util[-1]["args"]["value"] == tl.registry.gauge("pool.utilization").value()
    live = [e for e in te if e["ph"] == "C" and e["name"] == "pool.live_tokens"]
    assert [e["args"]["value"] for e in live] == [7]


def test_jax_annotation_passthrough_smoke():
    """jax_annotations=True wraps span bodies in jax.profiler.TraceAnnotation
    without changing the recorded spans."""
    tr = Tracer(jax_annotations=True)
    with tr.span("annotated"):
        pass
    assert [s.name for s in tr.spans] == ["annotated"]
