"""Metrics registry: counters/gauges/histograms, labels, lazy device drains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import MetricsRegistry


def test_counter_labels_are_series():
    reg = MetricsRegistry()
    c = reg.counter("serve.host_syncs")
    c.inc(site="stop_drain")
    c.inc(site="stop_drain")
    c.inc(3, site="stream_drain")
    assert c.value(site="stop_drain") == 2
    assert c.value(site="stream_drain") == 3
    assert c.value(site="never") == 0
    assert c.total() == 5
    snap = reg.snapshot()["counters"]
    assert snap["serve.host_syncs{site=stop_drain}"] == 2
    assert snap["serve.host_syncs{site=stream_drain}"] == 3


def test_counter_lazy_device_scalars_drain_once(monkeypatch):
    """add_lazy keeps scalars on device; reading drains ALL of them with one
    device_get — the registry-level host-sync-free contract."""
    reg = MetricsRegistry()
    c = reg.counter("runtime.elements_frozen")
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    c.add_lazy(jnp.int32(10))
    c.add_lazy(jnp.int32(20))
    c.add_lazy(jnp.int32(12))
    assert calls == [], "recording must not touch the host"
    assert c.total() == 42
    assert len(calls) == 1, "three pending scalars must drain in one transfer"
    assert c.total() == 42  # already drained: no second transfer
    assert len(calls) == 1


def test_gauge_tracks_high_water_mark():
    reg = MetricsRegistry()
    g = reg.gauge("pool.live_tokens")
    for v in (3, 11, 7, 0):
        g.set(v)
    assert g.value() == 0
    assert g.hwm() == 11
    snap = reg.snapshot()["gauges"]["pool.live_tokens"]
    assert snap == {"value": 0, "hwm": 11}


def test_gauge_fn_reads_live_callback():
    reg = MetricsRegistry()
    state = {"syncs": 0}
    reg.gauge_fn("pool.host_syncs", lambda: state["syncs"])
    state["syncs"] = 7
    assert reg.snapshot()["gauges"]["pool.host_syncs"]["value"] == 7


def test_histogram_quantiles_and_series():
    reg = MetricsRegistry()
    h = reg.histogram("serve.ttft_ms")
    for i, v in enumerate([10.0, 20.0, 30.0, 40.0]):
        h.observe(v, rid=i % 2)
    assert h.count() == 4
    assert h.values(rid=0) == [10.0, 30.0]
    assert h.quantile(0.5) == pytest.approx(25.0)
    snap = reg.snapshot()["histograms"]["serve.ttft_ms"]
    assert snap["count"] == 4 and snap["max"] == 40.0
    assert snap["series"]["serve.ttft_ms{rid=1}"]["count"] == 2
    with pytest.raises(ValueError):
        h.quantile(0.5, rid=99)


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.gauge_fn("x", lambda: 0)


def test_get_or_create_returns_same_object():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.names() == ["a"]


def test_snapshot_is_json_safe():
    import json

    reg = MetricsRegistry()
    reg.counter("c").inc(2)
    reg.gauge("g").set(1.5)
    reg.histogram("h").observe(3.0)
    json.dumps(reg.snapshot())  # must not raise (no numpy scalars leak)


def test_histogram_is_exact_below_the_reservoir_cap():
    from repro.obs.registry import RESERVOIR_CAP

    reg = MetricsRegistry()
    h = reg.histogram("exact")
    vals = [float(i) for i in range(100)]
    for v in vals:
        h.observe(v)
    assert h.values() == vals  # every observation stored, in order
    assert h.count() == 100 and h.sum() == sum(vals)
    assert h.quantile(0.0) == 0.0 and h.quantile(1.0) == 99.0
    assert 100 < RESERVOIR_CAP


def test_histogram_memory_is_bounded_past_the_cap():
    """Satellite 1: per-labelset storage caps at RESERVOIR_CAP while
    count/sum/mean stay exact running totals."""
    from repro.obs.registry import RESERVOIR_CAP

    reg = MetricsRegistry()
    h = reg.histogram("bounded")
    n = RESERVOIR_CAP + 500
    for i in range(n):
        h.observe(float(i), rid=0)
    assert len(h.values(rid=0)) == RESERVOIR_CAP
    assert h.count(rid=0) == n
    assert h.sum(rid=0) == float(n * (n - 1) // 2)
    # the reservoir holds real observations and a sane spread
    kept = h.values(rid=0)
    assert all(0 <= v < n for v in kept)
    q = h.quantile(0.5, rid=0)
    assert 0 <= q < n
    # other label sets are independent reservoirs
    h.observe(1.0, rid=1)
    assert h.values(rid=1) == [1.0]


def test_histogram_reservoir_is_deterministic_per_metric_name():
    from repro.obs.registry import RESERVOIR_CAP

    def fill(reg):
        h = reg.histogram("det")
        for i in range(RESERVOIR_CAP + 200):
            h.observe(float(i))
        return h.values()

    assert fill(MetricsRegistry()) == fill(MetricsRegistry())
