"""End-to-end system test: train → checkpoint → restore → serve.

One pass through every major subsystem on a tiny model: the fault-tolerant
training loop produces a checkpoint; a fresh process-state restores it; the
serving engine decodes from the trained weights with the GGArray cache and
agrees with the static-cache engine token-for-token.
"""
import jax
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.serving.engine import Engine
from repro.train import loop as loop_mod
from repro.train import step as step_mod


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = configs.reduced("qwen2.5-3b", cache_b0=8)
    d = str(tmp_path / "ckpt")

    # --- train a few steps with checkpointing ---
    out = loop_mod.run(
        cfg,
        loop_mod.LoopConfig(steps=6, batch=2, seq=16, ckpt_dir=d, ckpt_every=3, log_every=100),
    )
    # fresh batch each step (deterministic stream) → no monotonicity claim;
    # convergence itself is asserted in tests/models on repeated batches
    assert all(np.isfinite(out["losses"]))
    step = ckpt.latest_step(d)
    assert step == 6

    # --- restore into a fresh state ---
    fresh = step_mod.init_train_state(jax.random.PRNGKey(0), cfg)
    restored, meta = ckpt.restore(d, step, fresh)
    assert meta["next_step"] == 6

    # --- serve from the trained params; policies agree ---
    prompts = [[1, 2, 3], [7, 8]]
    outs = {}
    for policy in ("ggarray", "static"):
        eng = Engine(restored.params, cfg, policy=policy, max_len=64)
        outs[policy] = eng.generate(prompts, max_new_tokens=10)
    assert outs["ggarray"] == outs["static"]
    assert all(len(o) == len(p) + 10 for o, p in zip(outs["ggarray"], prompts))
