"""Roofline machinery: HLO collective parser (loop-aware) + jaxpr counter."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import flops as flops_mod
from repro.analysis import roofline

HLO_SNIPPET = """
ENTRY %main.10 (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ag = f32[128,256]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={1}
  %ar = f32[128,64]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add.1
  %t = (s32[], f32[128,64]{1,0}) tuple(%c, %p0)
  %w = (s32[], f32[128,64]{1,0}) while(%t), condition=%cond.1, body=%body.1
}
%body.1 (p: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %cp = f32[128,64]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
}
%cond.1 (p: (s32[], f32[128,64])) -> pred[] {
  %c5 = s32[] constant(5)
  %lt = pred[] compare(%i, %c5), direction=LT
}
"""


def test_collective_parser_weights_and_loops():
    out = roofline.collective_bytes(HLO_SNIPPET)
    ag = 128 * 256 * 4 * (3 / 4)  # all-gather (n-1)/n
    ar = 128 * 64 * 4 * (2 * 3 / 4)  # all-reduce 2(n-1)/n
    cp = 128 * 64 * 4 * 5  # permute inside a 5-trip while
    assert out["all-gather"] == pytest.approx(ag)
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["collective-permute"] == pytest.approx(cp)
    assert out["total_weighted"] == pytest.approx(ag + ar + cp)


def test_jaxpr_counter_multiplies_scan_bodies():
    w = jnp.ones((64, 64))

    def one_layer(x, _):
        return x @ w, None

    def stacked(x):
        y, _ = jax.lax.scan(one_layer, x, None, length=12)
        return y

    got = flops_mod.count_fn(stacked, jax.ShapeDtypeStruct((8, 64), jnp.float32))
    assert got["flops"] == pytest.approx(12 * 2 * 8 * 64 * 64)


def test_jaxpr_counter_sees_remat_recompute():
    w = jnp.ones((32, 32))

    def f(x):
        return jnp.sum(jax.checkpoint(lambda y: jnp.tanh(y @ w))(x))

    base = flops_mod.count_fn(f, jax.ShapeDtypeStruct((4, 32), jnp.float32))
    grad = flops_mod.count_fn(jax.grad(lambda x: f(x)), jax.ShapeDtypeStruct((4, 32), jnp.float32))
    # fwd (1 matmul) vs remat grad (fwd + recompute + dx matmul = 3;
    # w is a closure constant so no dw matmul exists)
    assert grad["flops"] == pytest.approx(3 * base["flops"])


def test_roofline_terms_pick_dominant_bound():
    cost = {"flops": 197e12, "bytes accessed": 819e9 / 2}
    coll = {"total_weighted": 50e9 * 2}
    t = roofline.roofline_terms(cost, coll)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(2.0)
    assert t["bound"] == "collective"


def test_model_flops_conventions():
    from repro import configs
    from repro.configs.base import SHAPES

    cfg = configs.get("qwen3-32b")
    train = roofline.model_flops(cfg, SHAPES["train_4k"], 256)
    decode = roofline.model_flops(cfg, SHAPES["decode_32k"], 256)
    assert train["params_total"] == pytest.approx(32e9, rel=0.15)
    ratio = train["model_flops_total"] / (
        6 * train["params_active"] * 4096 * 256
    )
    assert ratio == pytest.approx(1.0)
    assert decode["model_flops_total"] == pytest.approx(2 * decode["params_active"] * 128)
