"""GGArray — a dynamically growable array for TPU/XLA (paper §IV, TPU-adapted).

Structure: ``nblocks`` LFVectors, each a chain of geometric buckets (bucket
``b`` holds ``B0 * 2**b`` items).  Growth appends a bucket level **without
copying** any existing element — the property the paper contrasts against
doubling reallocation.  On TPU, bucket allocation happens at the program
boundary (XLA has no in-kernel malloc, DESIGN.md §2) but remains copy-free;
``push_back`` — the hot path — runs fully on device with *no cross-block
communication*, preserving the paper's block-local synchronization domain
(block ↦ mesh shard under ``shard_map``).

The pytree has one array per bucket level, shaped ``(nblocks, B0*2**b, *item)``
(uniform-level allocation; see DESIGN.md §2 for the skew analysis), plus a
``sizes: (nblocks,)`` vector.  ``len(buckets)`` is static per compiled program;
geometric growth means only O(log n) distinct structures ever exist.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import indexing
from repro.core.insertion import insertion_offsets

__all__ = [
    "GGArray",
    "init",
    "push_back",
    "grow",
    "needs_grow",
    "ensure_capacity",
    "flatten",
    "from_flat",
    "read_global",
    "write_global",
    "gather_block",
    "map_elements",
    "total_size",
    "memory_elems",
    "block_starts",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GGArray:
    """Array of LFVectors (Fig. 2 of the paper)."""

    buckets: tuple[jax.Array, ...]  # level b: (nblocks, B0*2**b, *item_shape)
    sizes: jax.Array  # (nblocks,) int32 — per-LFVector element count
    b0: int = dataclasses.field(metadata=dict(static=True), default=8)

    # ---- static geometry ------------------------------------------------
    @property
    def nblocks(self) -> int:
        return self.buckets[0].shape[0]

    @property
    def nbuckets(self) -> int:
        return len(self.buckets)

    @property
    def item_shape(self) -> tuple[int, ...]:
        return self.buckets[0].shape[2:]

    @property
    def dtype(self):
        return self.buckets[0].dtype

    @property
    def capacity_per_block(self) -> int:
        return indexing.capacity(self.b0, self.nbuckets)

    @property
    def capacity(self) -> int:
        return self.nblocks * self.capacity_per_block


def init(
    nblocks: int,
    b0: int = 8,
    item_shape: Sequence[int] = (),
    dtype: Any = jnp.float32,
    nbuckets: int = 1,
) -> GGArray:
    """Fresh empty GGArray with ``nbuckets`` pre-allocated levels."""
    if nbuckets < 1:
        raise ValueError("need at least one bucket level")
    buckets = tuple(
        jnp.zeros((nblocks, sz, *item_shape), dtype=dtype)
        for sz in indexing.bucket_sizes(b0, nbuckets)
    )
    return GGArray(buckets=buckets, sizes=jnp.zeros((nblocks,), jnp.int32), b0=b0)


# --------------------------------------------------------------------------
# Growth (paper Alg. 2 — new_bucket). Copy-free by construction.
# --------------------------------------------------------------------------

def grow(gg: GGArray, levels: int = 1) -> GGArray:
    """Append ``levels`` new bucket levels. Never touches existing buckets.

    The TPU analog of ``new_bucket``: runs at the program boundary (allocation
    is an XLA runtime concern), costs one allocation + (rarely, O(log n) times
    total) one executable-cache miss downstream. No data movement.
    """
    new_sizes = indexing.bucket_sizes(gg.b0, gg.nbuckets + levels)[gg.nbuckets :]
    new = tuple(
        jnp.zeros((gg.nblocks, sz, *gg.item_shape), dtype=gg.dtype) for sz in new_sizes
    )
    return dataclasses.replace(gg, buckets=gg.buckets + new)


def needs_grow(gg: GGArray, n_new_per_block: jax.Array | int) -> jax.Array:
    """True if any block would overflow after inserting ``n_new_per_block``."""
    return jnp.any(gg.sizes + n_new_per_block > gg.capacity_per_block)


def ensure_capacity(gg: GGArray, n_new_per_block: int) -> GGArray:
    """Host-side growth loop: grow until every block fits ``n_new_per_block`` more."""
    max_size = int(jax.device_get(jnp.max(gg.sizes)))
    nb = gg.nbuckets
    while indexing.capacity(gg.b0, nb) < max_size + n_new_per_block:
        nb += 1
    if nb > gg.nbuckets:
        gg = grow(gg, nb - gg.nbuckets)
    return gg


# --------------------------------------------------------------------------
# push_back (paper Alg. 1) — block-local, zero collectives.
# --------------------------------------------------------------------------

def _scatter_positions(
    buckets: tuple[jax.Array, ...],
    b0: int,
    pos: jax.Array,  # (nblocks, m) target in-block positions
    valid: jax.Array,  # (nblocks, m) bool
    elems: jax.Array,  # (nblocks, m, *item)
) -> tuple[jax.Array, ...]:
    """Scatter ``elems`` at in-block ``pos`` across bucket levels."""
    nbuckets = len(buckets)
    starts = indexing.bucket_starts(b0, nbuckets)
    sizes = indexing.bucket_sizes(b0, nbuckets)
    nblocks = pos.shape[0]
    rows = jnp.arange(nblocks, dtype=jnp.int32)[:, None]
    out = []
    for b in range(nbuckets):
        li = pos - starts[b]
        in_level = valid & (li >= 0) & (li < sizes[b])
        # mode="drop": out-of-level / masked-out entries use an OOB index.
        li = jnp.where(in_level, li, sizes[b])
        out.append(buckets[b].at[rows, li].set(elems, mode="drop"))
    return tuple(out)


@partial(jax.jit, static_argnames=("method",))
def push_back(
    gg: GGArray,
    elems: jax.Array,
    mask: jax.Array | None = None,
    method: str = "scan",
) -> tuple[GGArray, jax.Array]:
    """Parallel push_back of up to ``m`` elements per block (paper Alg. 1).

    ``elems: (nblocks, m, *item_shape)``; ``mask: (nblocks, m)`` selects which
    lanes insert (all, if None).  Returns the updated array and the assigned
    in-block positions ``(nblocks, m)`` (−1 where masked out).  Capacity must
    already suffice (``ensure_capacity``) — mirroring the paper, where
    ``new_bucket`` precedes the write.  Entirely block-local: the lowered HLO
    contains no cross-block collective.
    """
    if elems.ndim < 2 or elems.shape[0] != gg.nblocks:
        raise ValueError(f"elems must be (nblocks={gg.nblocks}, m, ...), got {elems.shape}")
    if mask is None:
        mask = jnp.ones(elems.shape[:2], dtype=bool)
    offsets, counts = insertion_offsets(mask, method=method)
    pos = gg.sizes[:, None] + offsets
    buckets = _scatter_positions(gg.buckets, gg.b0, pos, mask, elems)
    new = dataclasses.replace(gg, buckets=buckets, sizes=gg.sizes + counts)
    return new, jnp.where(mask, pos, -1)


# --------------------------------------------------------------------------
# Element access — rw_g (global, binary search) and rw_b (per-block).
# --------------------------------------------------------------------------

def block_starts(gg: GGArray) -> jax.Array:
    """The paper's global prefix-sum index table."""
    return indexing.block_starts(gg.sizes)


def _gather_inblock(gg: GGArray, block: jax.Array, pos: jax.Array) -> jax.Array:
    """Gather elements at per-block positions — walks the bucket chain.

    This is the paper's 'multiple pointers to reach an element': an O(log n)
    select chain, the structural reason GGArray r/w trails a flat array.
    """
    starts = indexing.bucket_starts(gg.b0, gg.nbuckets)
    sizes = indexing.bucket_sizes(gg.b0, gg.nbuckets)
    out = jnp.zeros((*pos.shape, *gg.item_shape), dtype=gg.dtype)
    for b in range(gg.nbuckets):
        li = (pos - starts[b]).clip(0, sizes[b] - 1)
        in_level = (pos >= starts[b]) & (pos < starts[b] + sizes[b])
        vals = gg.buckets[b][block, li]
        cond = in_level.reshape(in_level.shape + (1,) * len(gg.item_shape))
        out = jnp.where(cond, vals, out)
    return out


@jax.jit
def read_global(gg: GGArray, idx: jax.Array) -> jax.Array:
    """rw_g: read by global index (block-major order) via binary search."""
    starts = block_starts(gg)
    block = indexing.find_block(starts, idx)
    return _gather_inblock(gg, block, idx - starts[block])


@jax.jit
def write_global(gg: GGArray, idx: jax.Array, vals: jax.Array) -> GGArray:
    """rw_g write: scatter by global index via binary search."""
    starts = block_starts(gg)
    block = indexing.find_block(starts, idx)
    pos = idx - starts[block]
    nbuckets, b0 = gg.nbuckets, gg.b0
    bstarts = indexing.bucket_starts(b0, nbuckets)
    bsizes = indexing.bucket_sizes(b0, nbuckets)
    buckets = []
    for b in range(nbuckets):
        li = pos - bstarts[b]
        in_level = (li >= 0) & (li < bsizes[b])
        li = jnp.where(in_level, li, bsizes[b])
        buckets.append(gg.buckets[b].at[block, li].set(vals, mode="drop"))
    return dataclasses.replace(gg, buckets=tuple(buckets))


@jax.jit
def gather_block(gg: GGArray, block: jax.Array, pos: jax.Array) -> jax.Array:
    """rw_b read: caller already knows the owning block (no search)."""
    return _gather_inblock(gg, block, pos)


def map_elements(gg: GGArray, fn: Callable[[jax.Array], jax.Array]) -> GGArray:
    """rw_b: apply ``fn`` to every *live* element, bucket-parallel.

    One fused elementwise pass per bucket level with a validity mask — the
    block-structured access mode (one GPU block per array block in the paper).
    """
    starts = indexing.bucket_starts(gg.b0, gg.nbuckets)
    sizes = indexing.bucket_sizes(gg.b0, gg.nbuckets)
    buckets = []
    for b in range(gg.nbuckets):
        posn = starts[b] + jnp.arange(sizes[b], dtype=jnp.int32)[None, :]
        live = posn < gg.sizes[:, None]
        live = live.reshape(live.shape + (1,) * len(gg.item_shape))
        buckets.append(jnp.where(live, fn(gg.buckets[b]), gg.buckets[b]))
    return dataclasses.replace(gg, buckets=tuple(buckets))


# --------------------------------------------------------------------------
# Flatten — the two-phase pattern's bridge to a contiguous array (§VI.D).
# --------------------------------------------------------------------------

@jax.jit
def flatten(gg: GGArray) -> tuple[jax.Array, jax.Array]:
    """Emit a contiguous (capacity-sized) array in block-major global order.

    Returns ``(flat, total)`` where ``flat[:total]`` are the live elements in
    global order.  Capacity-shaped (XLA static shapes); slots ≥ total are 0.
    """
    starts = block_starts(gg)
    cap = gg.capacity
    flat = jnp.zeros((cap, *gg.item_shape), dtype=gg.dtype)
    bstarts = indexing.bucket_starts(gg.b0, gg.nbuckets)
    bsizes = indexing.bucket_sizes(gg.b0, gg.nbuckets)
    for b in range(gg.nbuckets):
        posn = bstarts[b] + jnp.arange(bsizes[b], dtype=jnp.int32)[None, :]
        live = posn < gg.sizes[:, None]
        tgt = jnp.where(live, starts[:, None] + posn, cap)
        flat = flat.at[tgt].set(gg.buckets[b], mode="drop")
    return flat, jnp.sum(gg.sizes)


def from_flat(
    flat: jax.Array,
    n: int,
    nblocks: int,
    b0: int = 8,
) -> GGArray:
    """Distribute ``flat[:n]`` evenly into a fresh GGArray (phase transition)."""
    per_block = -(-n // nblocks)  # ceil
    nbuckets = indexing.min_buckets_for(b0, per_block)
    gg = init(nblocks, b0, flat.shape[1:], flat.dtype, nbuckets=max(nbuckets, 1))
    src = jnp.arange(nblocks * per_block, dtype=jnp.int32).reshape(nblocks, per_block)
    mask = src < n
    elems = flat[src.clip(0, flat.shape[0] - 1)]
    gg, _ = push_back(gg, elems, mask)
    return gg


# --------------------------------------------------------------------------
# Introspection.
# --------------------------------------------------------------------------

def total_size(gg: GGArray) -> jax.Array:
    return jnp.sum(gg.sizes)


def memory_elems(gg: GGArray) -> int:
    """Allocated element slots (the §V memory-usage metric)."""
    return gg.capacity
