"""GGArray — a dynamically growable array for TPU/XLA (paper §IV, TPU-adapted).

Structure: ``nblocks`` LFVectors, each a chain of geometric buckets (bucket
``b`` holds ``B0 * 2**b`` items).  Growth appends a bucket level **without
copying** any existing element — the property the paper contrasts against
doubling reallocation.  On TPU, bucket allocation happens at the program
boundary (XLA has no in-kernel malloc, DESIGN.md §2) but remains copy-free;
``push_back`` — the hot path — runs fully on device with *no cross-block
communication*, preserving the paper's block-local synchronization domain
(block ↦ mesh shard under ``shard_map``).

The pytree has one array per bucket level, shaped ``(nblocks, B0*2**b, *item)``
(uniform-level allocation; see DESIGN.md §2 for the skew analysis), plus a
``sizes: (nblocks,)`` vector.  ``len(buckets)`` is static per compiled program;
geometric growth means only O(log n) distinct structures ever exist.

The hot path is the **amortized host-sync-free protocol** (DESIGN.md §2):
:class:`CapacityPlanner` + the donated :func:`append` keep steady-state
appends free of any device→host transfer, reading one scalar (the headroom
flag) only when a growth might be needed — O(log n) host contacts per growth
phase.  :func:`push_back` is the undonated variant for one-shot use.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import indexing
from repro.core.insertion import insertion_offsets

__all__ = [
    "GGArray",
    "init",
    "push_back",
    "append",
    "grow",
    "needs_grow",
    "ensure_capacity",
    "reserve",
    "CapacityPlanner",
    "PUSH_BACK_METHODS",
    "flatten",
    "from_flat",
    "read_global",
    "write_global",
    "gather_block",
    "map_elements",
    "total_size",
    "memory_elems",
    "block_starts",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GGArray:
    """Array of LFVectors (Fig. 2 of the paper)."""

    buckets: tuple[jax.Array, ...]  # level b: (nblocks, B0*2**b, *item_shape)
    sizes: jax.Array  # (nblocks,) int32 — per-LFVector element count
    b0: int = dataclasses.field(metadata=dict(static=True), default=8)

    # ---- static geometry ------------------------------------------------
    @property
    def nblocks(self) -> int:
        return self.buckets[0].shape[0]

    @property
    def nbuckets(self) -> int:
        return len(self.buckets)

    @property
    def item_shape(self) -> tuple[int, ...]:
        return self.buckets[0].shape[2:]

    @property
    def dtype(self):
        return self.buckets[0].dtype

    @property
    def capacity_per_block(self) -> int:
        return indexing.capacity(self.b0, self.nbuckets)

    @property
    def capacity(self) -> int:
        return self.nblocks * self.capacity_per_block


def init(
    nblocks: int,
    b0: int = 8,
    item_shape: Sequence[int] = (),
    dtype: Any = jnp.float32,
    nbuckets: int = 1,
) -> GGArray:
    """Fresh empty GGArray with ``nbuckets`` pre-allocated levels."""
    if nbuckets < 1:
        raise ValueError("need at least one bucket level")
    buckets = tuple(
        jnp.zeros((nblocks, sz, *item_shape), dtype=dtype)
        for sz in indexing.bucket_sizes(b0, nbuckets)
    )
    return GGArray(buckets=buckets, sizes=jnp.zeros((nblocks,), jnp.int32), b0=b0)


# --------------------------------------------------------------------------
# Growth (paper Alg. 2 — new_bucket). Copy-free by construction.
# --------------------------------------------------------------------------

def grow(gg: GGArray, levels: int = 1) -> GGArray:
    """Append ``levels`` new bucket levels. Never touches existing buckets.

    The TPU analog of ``new_bucket``: runs at the program boundary (allocation
    is an XLA runtime concern), costs one allocation + (rarely, O(log n) times
    total) one executable-cache miss downstream. No data movement.
    """
    new_sizes = indexing.bucket_sizes(gg.b0, gg.nbuckets + levels)[gg.nbuckets :]
    new = tuple(
        jnp.zeros((gg.nblocks, sz, *gg.item_shape), dtype=gg.dtype) for sz in new_sizes
    )
    return dataclasses.replace(gg, buckets=gg.buckets + new)


def needs_grow(gg: GGArray, n_new_per_block: jax.Array | int) -> jax.Array:
    """True if any block would overflow after inserting ``n_new_per_block``."""
    return jnp.any(gg.sizes + n_new_per_block > gg.capacity_per_block)


def reserve(
    gg: GGArray, n_new_per_block: int, *, max_size: int | None = None
) -> GGArray:
    """Lookahead capacity planner: grow until ``max_size + n`` fits per block.

    ``max_size`` is a host-known upper bound on the per-block element count;
    when the caller tracks it (see :class:`CapacityPlanner`) this performs
    **zero** device reads.  Passing ``None`` reads one device scalar — the
    legacy ``ensure_capacity`` behavior.
    """
    if max_size is None:
        max_size = int(jax.device_get(jnp.max(gg.sizes)))
    nb = gg.nbuckets
    while indexing.capacity(gg.b0, nb) < max_size + n_new_per_block:
        nb += 1
    if nb > gg.nbuckets:
        gg = grow(gg, nb - gg.nbuckets)
    return gg


def ensure_capacity(gg: GGArray, n_new_per_block: int) -> GGArray:
    """Growth loop with a per-call device read.

    Kept for one-shot/interactive use; hot loops should use a
    :class:`CapacityPlanner` (or ``reserve(..., max_size=...)``), which keeps
    the steady-state append path free of host transfers.
    """
    return reserve(gg, n_new_per_block)


class CapacityPlanner:
    """Host-side size tracking → O(log n) host contacts over a growth phase.

    The planner keeps a conservative upper bound on the max per-block size
    (each wave of ``m`` grows it by ``m``; masked-out lanes only make the
    bound pessimistic, never wrong).  ``reserve`` compares that bound against
    the static capacity:

    * bound + m ≤ capacity — the wave provably fits: no device read, no
      growth, no new executable.  This is the steady state.
    * bound + m > capacity — growth *might* be needed: read one scalar (the
      headroom flag the donated :func:`append` returned, else a fresh
      ``max(sizes)``), reset the bound to the true size, and grow if the true
      size really overflows.

    Each scalar read either halves the pessimism slack or precedes a
    geometric growth, so total host contacts stay O(log n) for steady
    appends (Tarjan & Zwick 2022's resizable-array bound, DESIGN.md §2).

    **Skewed masked loads**: when the caller passes a *host-known* mask
    (numpy / Python ints — never a device array) to ``reserve``, the planner
    advances a per-block bound vector by the actual per-block mask-lane
    counts instead of advancing the scalar bound by ``m``.  A workload that
    funnels all inserts into one block (``data/packing.py``'s greedy
    balancer is the motivating case) then syncs when *that block* nears
    capacity, not after ``capacity / m`` waves of mostly-empty lanes —
    adversarially masked loads stay at O(log n) host contacts too.
    """

    def __init__(self, size_upper_bound: int = 0):
        self.size_ub = size_upper_bound
        self.host_syncs = 0  # scalar device→host reads issued by the planner
        self.grow_events = 0
        self._headroom: tuple[jax.Array, int] | None = None  # (flag, cap then)
        self._ub_vec: "np.ndarray | None" = None  # per-block bound (mask path)

    @classmethod
    def for_array(cls, gg: GGArray) -> "CapacityPlanner":
        """Adopt an existing array: one scalar read to seed the bound."""
        planner = cls(int(jax.device_get(jnp.max(gg.sizes))))
        planner.host_syncs += 1
        return planner

    def note_append(self, gg: GGArray, headroom: jax.Array) -> None:
        """Record the device-side headroom flag a donated append returned."""
        self._headroom = (headroom, gg.capacity_per_block)

    def observed_max(self) -> int:
        """Host-read the true max per-block size (one scalar transfer)."""
        assert self._headroom is not None
        flag, cap_then = self._headroom
        self.host_syncs += 1
        return cap_then - int(jax.device_get(flag))

    def metrics(self) -> dict:
        """Host-contact accounting as plain data (for ``obs`` gauge_fn hooks
        — the planner stays importable without the telemetry layer)."""
        return {
            "planner.host_syncs": self.host_syncs,
            "planner.grow_events": self.grow_events,
            "planner.size_ub": self.size_ub,
        }

    @staticmethod
    def _host_lane_counts(mask: Any, nblocks: int) -> "np.ndarray | None":
        """Per-block enabled-lane counts iff ``mask`` is host-known.

        Device arrays return None — converting one would itself be the
        blocking transfer the planner exists to avoid.
        """
        if mask is None or isinstance(mask, jax.Array):
            return None
        arr = np.asarray(mask)
        if arr.ndim != 2 or arr.shape[0] != nblocks:
            return None
        return (arr != 0).sum(axis=1).astype(np.int64)

    def reserve(
        self, gg: GGArray, n_new_per_block: int, *, mask: Any = None
    ) -> GGArray:
        cap = gg.capacity_per_block
        counts = self._host_lane_counts(mask, gg.nblocks)
        if counts is not None:
            if self._ub_vec is None or len(self._ub_vec) != gg.nblocks:
                self._ub_vec = np.full((gg.nblocks,), self.size_ub, np.int64)
            if int((self._ub_vec + counts).max()) <= cap:
                self._ub_vec += counts  # skew-exact steady state: no contact
                self.size_ub = int(self._ub_vec.max())
                return gg
        elif self.size_ub + n_new_per_block <= cap:
            self.size_ub += n_new_per_block  # steady state: zero host contact
            if self._ub_vec is not None:
                self._ub_vec += n_new_per_block  # device mask: pessimistic
            return gg
        if counts is not None:
            # one vector transfer re-seeds the per-block bounds exactly
            sizes = np.asarray(jax.device_get(gg.sizes), np.int64)
            self.host_syncs += 1
            self._headroom = None
            self._ub_vec = sizes + counts
            self.size_ub = int(self._ub_vec.max())
            before = gg.nbuckets
            # grow for the skew-exact need, not max + m pessimism
            gg = reserve(gg, 0, max_size=self.size_ub)
            self.grow_events += gg.nbuckets - before
            return gg
        else:
            if self._headroom is not None:
                true_max = self.observed_max()
            else:
                true_max = int(jax.device_get(jnp.max(gg.sizes)))
                self.host_syncs += 1
            self.size_ub = true_max + n_new_per_block
            self._ub_vec = None  # scalar re-seed invalidates the vector bound
        before = gg.nbuckets
        gg = reserve(gg, n_new_per_block, max_size=true_max)
        self.grow_events += gg.nbuckets - before
        return gg


# --------------------------------------------------------------------------
# push_back (paper Alg. 1) — block-local, zero collectives.
# --------------------------------------------------------------------------

def _scatter_positions(
    buckets: tuple[jax.Array, ...],
    b0: int,
    pos: jax.Array,  # (nblocks, m) target in-block positions
    valid: jax.Array,  # (nblocks, m) bool
    elems: jax.Array,  # (nblocks, m, *item)
) -> tuple[jax.Array, ...]:
    """Scatter ``elems`` at in-block ``pos`` across bucket levels."""
    nbuckets = len(buckets)
    starts = indexing.bucket_starts(b0, nbuckets)
    sizes = indexing.bucket_sizes(b0, nbuckets)
    nblocks = pos.shape[0]
    rows = jnp.arange(nblocks, dtype=jnp.int32)[:, None]
    out = []
    for b in range(nbuckets):
        li = pos - starts[b]
        in_level = valid & (li >= 0) & (li < sizes[b])
        # mode="drop": out-of-level / masked-out entries use an OOB index.
        li = jnp.where(in_level, li, sizes[b])
        out.append(buckets[b].at[rows, li].set(elems, mode="drop"))
    return tuple(out)


# push_back's insertion backends: the offsets-only algorithms from
# core.insertion plus "fused", the Pallas kernel that computes offsets and
# scatters into every bucket level in one tiled pass (kernels/push_back),
# plus "auto" — the measured wave-width crossover (kernels/tuning.py):
# fused at or above FUSED_PUSH_BACK_MIN_WAVE lanes, scan below it.
PUSH_BACK_METHODS = ("atomic", "auto", "fused", "mxu", "scan", "tile")


def _push_back_impl(
    gg: GGArray,
    elems: jax.Array,
    mask: jax.Array | None,
    method: str,
) -> tuple[GGArray, jax.Array]:
    """Shared body of the jitted ``push_back`` / donated ``append``."""
    if elems.ndim < 2 or elems.shape[0] != gg.nblocks:
        raise ValueError(f"elems must be (nblocks={gg.nblocks}, m, ...), got {elems.shape}")
    if method == "auto":
        from repro.kernels.tuning import resolve_push_back_method

        method = resolve_push_back_method(method, elems.shape[1])
    if mask is None:
        mask = jnp.ones(elems.shape[:2], dtype=bool)
    if jnp.issubdtype(mask.dtype, jnp.floating):
        raise TypeError(f"mask must be bool or integer, got {mask.dtype}")
    if mask.dtype != jnp.bool_:
        mask = mask != 0  # count lanes, not values (insertion_offsets contract)
    if method == "fused" and elems.shape[1] > 0:
        from repro.kernels.push_back import ops as push_back_ops

        buckets, sizes, pos = push_back_ops.push_back_fused(
            gg.buckets, gg.sizes, gg.b0, elems, mask
        )
        return dataclasses.replace(gg, buckets=buckets, sizes=sizes), pos
    if method == "fused":  # empty waves: jnp fallback
        method = "scan"
    offsets, counts = insertion_offsets(mask, method=method)
    pos = gg.sizes[:, None] + offsets
    buckets = _scatter_positions(gg.buckets, gg.b0, pos, mask, elems)
    new = dataclasses.replace(gg, buckets=buckets, sizes=gg.sizes + counts)
    return new, jnp.where(mask, pos, -1)


@partial(jax.jit, static_argnames=("method",))
def push_back(
    gg: GGArray,
    elems: jax.Array,
    mask: jax.Array | None = None,
    method: str = "auto",
) -> tuple[GGArray, jax.Array]:
    """Parallel push_back of up to ``m`` elements per block (paper Alg. 1).

    ``elems: (nblocks, m, *item_shape)``; ``mask: (nblocks, m)`` selects which
    lanes insert (all, if None).  Returns the updated array and the assigned
    in-block positions ``(nblocks, m)`` (−1 where masked out).  Capacity must
    already suffice (``reserve``/``ensure_capacity``) — mirroring the paper,
    where ``new_bucket`` precedes the write.  Entirely block-local: the
    lowered HLO contains no cross-block collective.

    This variant does **not** donate its input (the old array stays valid) —
    hot loops should use :func:`append`, which does.
    """
    return _push_back_impl(gg, elems, mask, method)


@partial(jax.jit, static_argnames=("method",), donate_argnums=(0,))
def append(
    gg: GGArray,
    elems: jax.Array,
    mask: jax.Array | None = None,
    method: str = "auto",
) -> tuple[GGArray, jax.Array, jax.Array]:
    """Donated push_back — the host-sync-free hot path.

    Same semantics as :func:`push_back` plus:

    * ``gg`` is **donated**: XLA writes the scattered elements into the input
      buffers instead of copying every bucket level (the input array is dead
      after the call — rebind it).
    * returns a third value ``headroom``, a device-side int32 scalar
      ``capacity_per_block − max(new sizes)``.  Negative means the wave
      overflowed capacity and writes were dropped.  The host never has to
      read it in the steady state; :class:`CapacityPlanner` reads it only
      when its conservative bound says a growth might be needed — keeping
      host contacts O(log n) per growth phase (DESIGN.md §2).

    jit caches one executable per bucket structure (``nbuckets`` is pytree
    structure), so geometric growth compiles O(log n) executables total.
    """
    new, pos = _push_back_impl(gg, elems, mask, method)
    headroom = jnp.int32(new.capacity_per_block) - jnp.max(new.sizes)
    return new, pos, headroom


# --------------------------------------------------------------------------
# Element access — rw_g (global, binary search) and rw_b (per-block).
# --------------------------------------------------------------------------

def block_starts(gg: GGArray) -> jax.Array:
    """The paper's global prefix-sum index table."""
    return indexing.block_starts(gg.sizes)


def _gather_inblock(gg: GGArray, block: jax.Array, pos: jax.Array) -> jax.Array:
    """Gather elements at per-block positions — walks the bucket chain.

    This is the paper's 'multiple pointers to reach an element': an O(log n)
    select chain, the structural reason GGArray r/w trails a flat array.
    """
    starts = indexing.bucket_starts(gg.b0, gg.nbuckets)
    sizes = indexing.bucket_sizes(gg.b0, gg.nbuckets)
    out = jnp.zeros((*pos.shape, *gg.item_shape), dtype=gg.dtype)
    for b in range(gg.nbuckets):
        li = (pos - starts[b]).clip(0, sizes[b] - 1)
        in_level = (pos >= starts[b]) & (pos < starts[b] + sizes[b])
        vals = gg.buckets[b][block, li]
        cond = in_level.reshape(in_level.shape + (1,) * len(gg.item_shape))
        out = jnp.where(cond, vals, out)
    return out


@jax.jit
def read_global(gg: GGArray, idx: jax.Array) -> jax.Array:
    """rw_g: read by global index (block-major order) via binary search."""
    starts = block_starts(gg)
    block = indexing.find_block(starts, idx)
    return _gather_inblock(gg, block, idx - starts[block])


@jax.jit
def write_global(gg: GGArray, idx: jax.Array, vals: jax.Array) -> GGArray:
    """rw_g write: scatter by global index via binary search."""
    starts = block_starts(gg)
    block = indexing.find_block(starts, idx)
    pos = idx - starts[block]
    nbuckets, b0 = gg.nbuckets, gg.b0
    bstarts = indexing.bucket_starts(b0, nbuckets)
    bsizes = indexing.bucket_sizes(b0, nbuckets)
    buckets = []
    for b in range(nbuckets):
        li = pos - bstarts[b]
        in_level = (li >= 0) & (li < bsizes[b])
        li = jnp.where(in_level, li, bsizes[b])
        buckets.append(gg.buckets[b].at[block, li].set(vals, mode="drop"))
    return dataclasses.replace(gg, buckets=tuple(buckets))


@jax.jit
def gather_block(gg: GGArray, block: jax.Array, pos: jax.Array) -> jax.Array:
    """rw_b read: caller already knows the owning block (no search)."""
    return _gather_inblock(gg, block, pos)


def map_elements(gg: GGArray, fn: Callable[[jax.Array], jax.Array]) -> GGArray:
    """rw_b: apply ``fn`` to every *live* element, bucket-parallel.

    One fused elementwise pass per bucket level with a validity mask — the
    block-structured access mode (one GPU block per array block in the paper).
    """
    starts = indexing.bucket_starts(gg.b0, gg.nbuckets)
    sizes = indexing.bucket_sizes(gg.b0, gg.nbuckets)
    buckets = []
    for b in range(gg.nbuckets):
        posn = starts[b] + jnp.arange(sizes[b], dtype=jnp.int32)[None, :]
        live = posn < gg.sizes[:, None]
        live = live.reshape(live.shape + (1,) * len(gg.item_shape))
        buckets.append(jnp.where(live, fn(gg.buckets[b]), gg.buckets[b]))
    return dataclasses.replace(gg, buckets=tuple(buckets))


# --------------------------------------------------------------------------
# Flatten — the two-phase pattern's bridge to a contiguous array (§VI.D).
# --------------------------------------------------------------------------

@jax.jit
def flatten(gg: GGArray) -> tuple[jax.Array, jax.Array]:
    """Emit a contiguous (capacity-sized) array in block-major global order.

    Returns ``(flat, total)`` where ``flat[:total]`` are the live elements in
    global order.  Capacity-shaped (XLA static shapes); slots ≥ total are 0.
    """
    starts = block_starts(gg)
    cap = gg.capacity
    flat = jnp.zeros((cap, *gg.item_shape), dtype=gg.dtype)
    bstarts = indexing.bucket_starts(gg.b0, gg.nbuckets)
    bsizes = indexing.bucket_sizes(gg.b0, gg.nbuckets)
    for b in range(gg.nbuckets):
        posn = bstarts[b] + jnp.arange(bsizes[b], dtype=jnp.int32)[None, :]
        live = posn < gg.sizes[:, None]
        tgt = jnp.where(live, starts[:, None] + posn, cap)
        flat = flat.at[tgt].set(gg.buckets[b], mode="drop")
    return flat, jnp.sum(gg.sizes)


def from_flat(
    flat: jax.Array,
    n: int,
    nblocks: int,
    b0: int = 8,
) -> GGArray:
    """Distribute ``flat[:n]`` evenly into a fresh GGArray (phase transition)."""
    per_block = -(-n // nblocks)  # ceil
    nbuckets = indexing.min_buckets_for(b0, per_block)
    gg = init(nblocks, b0, flat.shape[1:], flat.dtype, nbuckets=max(nbuckets, 1))
    src = jnp.arange(nblocks * per_block, dtype=jnp.int32).reshape(nblocks, per_block)
    mask = src < n
    elems = flat[src.clip(0, flat.shape[0] - 1)]
    gg, _, _ = append(gg, elems, mask)  # fresh array: donation is free
    return gg


# --------------------------------------------------------------------------
# Introspection.
# --------------------------------------------------------------------------

def total_size(gg: GGArray) -> jax.Array:
    return jnp.sum(gg.sizes)


def memory_elems(gg: GGArray) -> int:
    """Allocated element slots (the §V memory-usage metric)."""
    return gg.capacity
