"""Single LFVector — the per-block unit of GGArray (paper Algs. 1–2).

A standalone one-block view used by the unit tests and the quickstart example
to mirror the paper's pseudocode directly.  ``GGArray`` is *not* built on top
of this class (it vectorizes over blocks natively); this exists so the
Algorithm 1/2 semantics are testable in isolation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import ggarray as gg_ops
from repro.core.ggarray import GGArray

__all__ = ["LFVector"]


@dataclasses.dataclass
class LFVector:
    """One LFVector: geometric buckets + a size counter (host-side wrapper)."""

    _gg: GGArray
    _planner: gg_ops.CapacityPlanner = dataclasses.field(
        default_factory=gg_ops.CapacityPlanner
    )

    @classmethod
    def create(
        cls,
        b0: int = 8,
        item_shape: Sequence[int] = (),
        dtype: Any = jnp.float32,
    ) -> "LFVector":
        return cls(gg_ops.init(1, b0, item_shape, dtype))

    # -- paper Alg. 1: push_back -----------------------------------------
    def push_back(self, elems: jax.Array, method: str = "scan") -> jax.Array:
        """Insert a batch of elements; grows (Alg. 2) if needed. Returns indices.

        Runs the amortized protocol: planner-reserved capacity + donated
        append, so steady-state pushes issue no device→host transfer.
        """
        elems = jnp.atleast_1d(elems)
        self._gg = self._planner.reserve(self._gg, elems.shape[0])
        self._gg, pos, headroom = gg_ops.append(self._gg, elems[None], method=method)
        self._planner.note_append(self._gg, headroom)
        return pos[0]

    # -- element access ----------------------------------------------------
    def __getitem__(self, idx) -> jax.Array:
        idx = jnp.asarray(idx)
        return gg_ops.gather_block(self._gg, jnp.zeros_like(idx), idx)

    def __setitem__(self, idx, val) -> None:
        idx = jnp.asarray(idx)
        self._gg = gg_ops.write_global(self._gg, idx, jnp.asarray(val))

    def __len__(self) -> int:
        return int(jax.device_get(self._gg.sizes[0]))

    # -- introspection ------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self._gg.capacity_per_block

    @property
    def nbuckets(self) -> int:
        return self._gg.nbuckets

    def to_array(self) -> jax.Array:
        flat, _ = gg_ops.flatten(self._gg)
        return flat[: len(self)]
