"""GGArray core — the paper's contribution as a composable JAX module."""
from repro.core.ggarray import (
    PUSH_BACK_METHODS,
    CapacityPlanner,
    GGArray,
    append,
    block_starts,
    ensure_capacity,
    flatten,
    from_flat,
    gather_block,
    grow,
    init,
    map_elements,
    memory_elems,
    needs_grow,
    push_back,
    read_global,
    reserve,
    total_size,
    write_global,
)
from repro.core.baselines import SemiStaticArray, StaticArray, static_init, static_push_back
from repro.core.insertion import INSERTION_METHODS, insertion_offsets
from repro.core.lfvector import LFVector

__all__ = [
    "GGArray", "init", "push_back", "append", "grow", "needs_grow",
    "ensure_capacity", "reserve", "CapacityPlanner", "PUSH_BACK_METHODS",
    "flatten", "from_flat", "read_global", "write_global", "gather_block",
    "map_elements", "total_size", "memory_elems", "block_starts",
    "StaticArray", "SemiStaticArray", "static_init", "static_push_back",
    "insertion_offsets", "INSERTION_METHODS", "LFVector",
]
