"""Parallel insertion-index algorithms (paper §III.B, Fig. 4 column 1).

Given a boolean insertion mask per block, every inserting thread must receive a
unique, dense offset ``>=`` the previous size — i.e. an **exclusive prefix sum
of the mask along the element axis**.  The paper evaluates three GPU
algorithms; each has a TPU-native analog here (DESIGN.md §2):

``atomic``
    CUDA ``atomicAdd`` serializes inserters on a counter.  TPUs have no global
    atomics; the faithful analog is a serialized ``fori_loop`` that walks the
    element axis carrying a per-block counter.  Kept — as in the paper — as the
    deliberately slow baseline.
``scan``
    Warp ``__shfl_up_sync`` prefix sum → VPU ``cumsum`` (XLA lowers to a
    logarithmic scan).  The Pallas tile-scan kernel (``kernels/scan_tile``) is
    the hand-tiled TPU version of the same algorithm.
``mxu``
    Tensor-core matmul scan (Dakkak et al. 2019) → MXU matmul scan re-blocked
    for 128×128 systolic tiles (``kernels/scan_mxu``).

All functions take ``mask: (nblocks, m) bool`` and return ``(offsets, counts)``
with ``offsets: (nblocks, m) int32`` exclusive per-block offsets (valid only
where ``mask``) and ``counts: (nblocks,) int32`` per-block insert totals.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["insertion_offsets", "INSERTION_METHODS"]


def _offsets_atomic(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Serialized counter — the ``atomicAdd`` analog (slowest, as in paper)."""
    nblocks, m = mask.shape
    mask_i = mask.astype(jnp.int32)

    def body(j, carry):
        counter, offsets = carry
        offsets = offsets.at[:, j].set(counter)
        return counter + mask_i[:, j], offsets

    counter0 = jnp.zeros((nblocks,), jnp.int32)
    offsets0 = jnp.zeros((nblocks, m), jnp.int32)
    counter, offsets = jax.lax.fori_loop(0, m, body, (counter0, offsets0))
    return offsets, counter


def _offsets_scan(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """VPU/XLA cumulative-sum scan — the warp-shuffle analog (fastest in paper)."""
    mask_i = mask.astype(jnp.int32)
    inclusive = jnp.cumsum(mask_i, axis=-1)
    return inclusive - mask_i, inclusive[:, -1]


def _offsets_mxu(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """MXU matmul scan — the tensor-core analog (Pallas kernel, interpret on CPU)."""
    from repro.kernels.scan_mxu import ops as scan_mxu_ops

    mask_i = mask.astype(jnp.int32)
    inclusive = scan_mxu_ops.row_scan(mask_i)
    return inclusive - mask_i, inclusive[:, -1]


def _offsets_tile(mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pallas VMEM tile scan — hand-tiled version of ``scan``."""
    from repro.kernels.scan_tile import ops as scan_tile_ops

    mask_i = mask.astype(jnp.int32)
    inclusive = scan_tile_ops.row_scan(mask_i)
    return inclusive - mask_i, inclusive[:, -1]


INSERTION_METHODS: dict[str, Callable[[jax.Array], tuple[jax.Array, jax.Array]]] = {
    "atomic": _offsets_atomic,
    "scan": _offsets_scan,
    "mxu": _offsets_mxu,
    "tile": _offsets_tile,
}


def insertion_offsets(mask: jax.Array, method: str = "scan") -> tuple[jax.Array, jax.Array]:
    """Exclusive per-block insertion offsets + per-block insert counts.

    ``mask`` may be any numeric dtype; it is normalized to bool (``!= 0``)
    first — every backend counts *lanes*, not values, so an int mask of 3s
    inserts one element per lane, not three.  Float masks are rejected
    (truthiness of a float lane is almost always a bug upstream).
    """
    if mask.ndim != 2:
        raise ValueError(f"mask must be (nblocks, m), got {mask.shape}")
    if jnp.issubdtype(mask.dtype, jnp.floating):
        raise TypeError(f"mask must be bool or integer, got {mask.dtype}")
    if mask.dtype != jnp.bool_:
        mask = mask != 0
    try:
        fn = INSERTION_METHODS[method]
    except KeyError:
        raise ValueError(
            f"unknown insertion method {method!r}; options: {sorted(INSERTION_METHODS)}"
        ) from None
    if mask.shape[1] == 0:  # empty wave: no offsets, zero counts
        nblocks = mask.shape[0]
        return (
            jnp.zeros((nblocks, 0), jnp.int32),
            jnp.zeros((nblocks,), jnp.int32),
        )
    return fn(mask)
