"""Theoretical memory usage (paper §V, Fig. 3).

Scenario: an application starts from ``n0`` elements and performs insertions
whose total count is ``n0 * F`` with ``F ~ LogNormal(mu=0, sigma)``.  A static
array must pre-allocate for the (1 - fail_rate) quantile of ``F`` to fail at
most ``fail_rate`` of the time; the semi-static array doubles to the next
power-of-two multiple; GGArray allocates geometric buckets and stays below
2× + B0 of the realized size.  All formulas are analytic where possible and
Monte-Carlo verified in the benchmark.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import indexing

__all__ = ["MemoryModel", "memory_curves"]


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    n0: int = 1_000_000
    nblocks: int = 512
    b0: int = 8
    fail_rate: float = 0.01

    # -- per-structure capacity for a *realized* final size s -------------
    def ggarray_capacity(self, s: float) -> float:
        """Uniform-level bucket capacity for total size ``s`` spread evenly."""
        per_block = max(int(math.ceil(s / self.nblocks)), 1)
        nb = indexing.min_buckets_for(self.b0, per_block)
        return self.nblocks * indexing.capacity(self.b0, max(nb, 1))

    def semistatic_capacity(self, s: float, start: float | None = None) -> float:
        """Doubling from ``start`` (default n0) to cover ``s``."""
        cap = float(start if start is not None else self.n0)
        while cap < s:
            cap *= 2
        return cap

    def static_capacity(self, sigma: float) -> float:
        """Pre-allocation for a (1-fail_rate) success probability (Fig. 3)."""
        z = _norm_ppf(1.0 - self.fail_rate)
        return self.n0 * math.exp(sigma * z)

    # -- expected capacities under F ~ LogNormal(0, sigma) ----------------
    def sample_final_sizes(self, sigma: float, rng: np.random.Generator, n: int) -> np.ndarray:
        return self.n0 * rng.lognormal(mean=0.0, sigma=sigma, size=n)

    def expected(self, sigma: float, samples: int = 4096, seed: int = 0) -> dict[str, float]:
        rng = np.random.default_rng(seed)
        s = self.sample_final_sizes(sigma, rng, samples)
        optimal = float(np.mean(s))
        gg = float(np.mean([self.ggarray_capacity(x) for x in s]))
        semi = float(np.mean([self.semistatic_capacity(x) for x in s]))
        return {
            "optimal": optimal,
            "ggarray": gg,
            "semistatic": semi,
            "static": self.static_capacity(sigma),
            "ggarray_over_optimal": gg / optimal,
            "static_over_optimal": self.static_capacity(sigma) / optimal,
        }


def _norm_ppf(p: float) -> float:
    """Acklam's inverse-normal approximation (no scipy in this container)."""
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
        )
    if p <= phigh:
        q = p - 0.5
        r = q * q
        return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / (
            ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1
        )
    q = math.sqrt(-2 * math.log(1 - p))
    return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
        (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
    )


def memory_curves(
    sigmas: np.ndarray | None = None, model: MemoryModel | None = None
) -> dict[str, np.ndarray]:
    """Fig. 3 data: memory/optimal ratios across sigma ∈ [0, 2]."""
    model = model or MemoryModel()
    sigmas = np.linspace(0.0, 2.0, 9) if sigmas is None else sigmas
    rows = [model.expected(float(s)) for s in sigmas]
    return {
        "sigma": np.asarray(sigmas),
        **{k: np.asarray([r[k] for r in rows]) for k in rows[0]},
    }
