"""Bucket geometry + global indexing for GGArray.

The LFVector layout (Dechev et al. 2006, as used by GGArray §IV): bucket ``b``
holds ``B0 * 2**b`` elements, so the first ``nb`` buckets cover positions
``[0, B0*(2**nb - 1))``.  Growth appends the next bucket — existing buckets are
never moved (the copy-free property the paper contrasts against doubling
reallocation).

Global indexing (paper §IV): a prefix-sum table over per-block sizes gives the
first global index owned by each block; binary search over it locates the block
that owns a global index (``rw_g``).  All functions here are shape-polymorphic
pure JAX and safe under ``jit``/``vmap``/``shard_map``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "bucket_sizes",
    "bucket_starts",
    "capacity",
    "bucket_of_position",
    "local_offset",
    "block_starts",
    "find_block",
    "min_buckets_for",
]


def bucket_sizes(b0: int, nbuckets: int) -> tuple[int, ...]:
    """Size of each bucket level: ``B0 * 2**b`` (paper Alg. 2)."""
    return tuple(b0 * (1 << b) for b in range(nbuckets))


def bucket_starts(b0: int, nbuckets: int) -> tuple[int, ...]:
    """First in-block position covered by each bucket: ``B0*(2**b - 1)``."""
    return tuple(b0 * ((1 << b) - 1) for b in range(nbuckets))


def capacity(b0: int, nbuckets: int) -> int:
    """Total per-block capacity with ``nbuckets`` levels: ``B0*(2**nb - 1)``."""
    return b0 * ((1 << nbuckets) - 1)


def min_buckets_for(b0: int, n: int) -> int:
    """Smallest number of bucket levels whose capacity holds ``n`` elements."""
    nb = 0
    while capacity(b0, nb) < n:
        nb += 1
    return nb


def bucket_of_position(pos: jax.Array, b0: int, nbuckets: int) -> jax.Array:
    """Bucket level that owns in-block position ``pos``.

    Uses exact integer comparisons against the (static, tiny) start table
    rather than float ``log2`` — ``nbuckets`` is O(log n) so this unrolls to a
    handful of vectorized compares.
    """
    starts = bucket_starts(b0, nbuckets)
    level = jnp.zeros(jnp.shape(pos), dtype=jnp.int32)
    for b in range(1, nbuckets):
        level = level + (pos >= starts[b]).astype(jnp.int32)
    return level


def local_offset(pos: jax.Array, level: jax.Array, b0: int, nbuckets: int) -> jax.Array:
    """Offset of in-block position ``pos`` inside its bucket ``level``."""
    starts = jnp.asarray(bucket_starts(b0, nbuckets), dtype=jnp.int32)
    return pos.astype(jnp.int32) - starts[level]


def block_starts(sizes: jax.Array) -> jax.Array:
    """Exclusive prefix sum of per-block sizes — the paper's global index table."""
    return jnp.cumsum(sizes) - sizes


def find_block(starts: jax.Array, global_idx: jax.Array) -> jax.Array:
    """Binary search (paper §IV): block owning ``global_idx`` given start table."""
    return (
        jnp.searchsorted(starts, global_idx, side="right").astype(jnp.int32) - 1
    ).clip(0)
