"""Comparison structures from the paper (§III.A): static and semi-static arrays.

``StaticArray``
    Flat pre-allocated buffer (cudaMalloc-at-start analog).  Insertions run on
    device with the same parallel insertion algorithms; no resize exists — the
    caller must pre-size for the worst case (the memory cost Fig. 3 quantifies).

``SemiStaticArray``
    Flat buffer resized from the host by doubling.  ``copy_on_grow=True`` is
    classic realloc (allocate 2×, copy everything).  The paper's ``memMap``
    variant uses the CUDA virtual-memory API to *remap* pages so growth skips
    the copy; XLA exposes no user-level VMM, so the benchmark harness models
    memMap by timing allocation only (``grow_alloc_only``) while the data copy
    still happens for correctness outside the timed region (EXPERIMENTS.md
    records this explicitly).  GGArray's buckets are the TPU-native way to get
    the same copy-free growth *without* pretending pages can be remapped.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.insertion import insertion_offsets

__all__ = ["StaticArray", "SemiStaticArray", "static_init", "static_push_back"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StaticArray:
    data: jax.Array  # (capacity, *item_shape)
    size: jax.Array  # () int32

    @property
    def capacity(self) -> int:
        return self.data.shape[0]


def static_init(
    capacity: int, item_shape: Sequence[int] = (), dtype: Any = jnp.float32
) -> StaticArray:
    return StaticArray(
        data=jnp.zeros((capacity, *item_shape), dtype=dtype),
        size=jnp.zeros((), jnp.int32),
    )


@partial(jax.jit, static_argnames=("method",))
def static_push_back(
    arr: StaticArray,
    elems: jax.Array,
    mask: jax.Array | None = None,
    method: str = "scan",
) -> tuple[StaticArray, jax.Array]:
    """Parallel insertion into a flat array (one global index space)."""
    if mask is None:
        mask = jnp.ones(elems.shape[:1], dtype=bool)
    offsets, count = insertion_offsets(mask[None], method=method)
    pos = arr.size + offsets[0]
    tgt = jnp.where(mask, pos, arr.capacity)
    data = arr.data.at[tgt].set(elems, mode="drop")
    new = StaticArray(data=data, size=arr.size + count[0])
    return new, jnp.where(mask, pos, -1)


@dataclasses.dataclass
class SemiStaticArray:
    """Host-resizable flat array (doubling), paper's semi-static/memMap."""

    arr: StaticArray
    copy_on_grow: bool = True  # False ≙ memMap accounting (see module docstring)

    @classmethod
    def create(
        cls,
        capacity: int,
        item_shape: Sequence[int] = (),
        dtype: Any = jnp.float32,
        copy_on_grow: bool = True,
    ) -> "SemiStaticArray":
        return cls(static_init(capacity, item_shape, dtype), copy_on_grow)

    @property
    def capacity(self) -> int:
        return self.arr.capacity

    @property
    def size(self) -> int:
        return int(jax.device_get(self.arr.size))

    # -- host-driven growth (the paper's host-synchronized resize) -------
    def grow_alloc_only(self) -> jax.Array:
        """Allocate the doubled buffer (the part memMap pays for)."""
        d = self.arr.data
        return jnp.zeros((d.shape[0] * 2, *d.shape[1:]), dtype=d.dtype)

    def grow(self) -> None:
        """Double capacity. realloc copies; memMap remaps (copy untimed)."""
        new = self.grow_alloc_only()
        new = jax.lax.dynamic_update_slice_in_dim(new, self.arr.data, 0, axis=0)
        self.arr = StaticArray(data=new, size=self.arr.size)

    def ensure_capacity(self, n_new: int) -> int:
        """Grow until ``n_new`` more fit. Returns number of doublings done."""
        grows = 0
        while self.size + n_new > self.capacity:
            self.grow()
            grows += 1
        return grows

    def push_back(
        self, elems: jax.Array, mask: jax.Array | None = None, method: str = "scan"
    ) -> jax.Array:
        n = elems.shape[0]
        self.ensure_capacity(n)
        self.arr, pos = static_push_back(self.arr, elems, mask, method=method)
        return pos
