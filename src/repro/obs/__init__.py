"""Unified telemetry layer: metrics registry, span tracing, serving timeline.

The observability subsystem the stats surfaces are rewired onto
(DESIGN.md §9): ``Engine``/``BatchEngine`` (TTFT, TPOT, queue wait, chunk
counts, admit/complete/starvation events), ``SlabArena``/``ExtentPool``
(grow events, copied bytes, utilization), ``TwoPhasePipeline`` (freeze/thaw
latency, elements frozen), ``CapacityPlanner``/``TenantPlanner`` (host
contacts, via ``gauge_fn`` callbacks).  The legacy ``EngineStats``/
``BatchStats``/``FreezeStats`` dataclasses survive as thin read-only views
over these registries.

Hard contract: recording a metric or a span is host-side Python only —
**zero device→host transfers on the append/decode hot path**.  Device
scalars go through ``Counter.add_lazy`` and materialize only at explicit
drain points (``snapshot()`` / metric reads), enforced by the transfer-guard
test in ``tests/serving/test_telemetry.py``.
"""
from repro.obs import device
from repro.obs.device import DeviceCounterPlane
from repro.obs.flightrec import FlightRecorder
from repro.obs.registry import (
    Counter,
    Gauge,
    GaugeFn,
    Histogram,
    MetricsRegistry,
    default_registry,
)
from repro.obs.timeline import ServingTimeline
from repro.obs.trace import Span, Tracer

__all__ = [
    "Counter",
    "DeviceCounterPlane",
    "FlightRecorder",
    "Gauge",
    "GaugeFn",
    "Histogram",
    "MetricsRegistry",
    "ServingTimeline",
    "Span",
    "Tracer",
    "default_registry",
    "device",
]
