"""Span tracing with JSON and Chrome/Perfetto trace export.

``Tracer`` records three host-side event kinds against one monotonic clock
(``time.perf_counter``, microsecond resolution in the export):

* **spans** — ``with tracer.span("prefill_chunk", request=rid):`` wall-clock
  intervals.  Spans nest via the context-manager stack, which is exactly the
  nesting Chrome's trace viewer reconstructs from ``ph: "X"`` duration
  events on one thread track.
* **instants** — ``tracer.event("admit", request=rid)`` point events
  (``ph: "i"``), the serving timeline's admit/evict/starvation markers.
* **counter samples** — ``tracer.sample("pool.utilization", 0.93)`` time
  series (``ph: "C"``), rendered as stacked graphs in the viewer — the
  per-step gauge track of the serving timeline.

Recording is append-to-a-list: no device contact, no synchronization, so
spans are safe around the decode hot loop (they time the *dispatch* path —
JAX is async; wrap the body in ``block_until_ready`` yourself if you want
device latency, and accept the sync that implies).

``jax_annotations=True`` additionally wraps each span body in
``jax.profiler.TraceAnnotation``, so the same span names appear inside a
``jax.profiler.trace`` capture (the XLA-level timeline) — off by default
because the annotation has its own overhead and most runs never profile.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any

__all__ = ["Span", "Tracer"]


@dataclasses.dataclass
class Span:
    name: str
    t0_us: float  # offset from tracer epoch
    dur_us: float
    depth: int
    attrs: dict


def _annotation_ctx(name: str):
    try:
        import jax.profiler

        return jax.profiler.TraceAnnotation(name)
    except (ImportError, AttributeError):  # pragma: no cover - jax is pinned
        return contextlib.nullcontext()


class Tracer:
    def __init__(self, *, jax_annotations: bool = False, clock=time.perf_counter):
        self._clock = clock
        self._epoch = clock()
        self.jax_annotations = jax_annotations
        self.spans: list[Span] = []
        self.events: list[dict] = []
        self.samples: list[dict] = []
        self._stack: list[str] = []

    def _now_us(self) -> float:
        return (self._clock() - self._epoch) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        t0 = self._now_us()
        self._stack.append(name)
        ctx = _annotation_ctx(name) if self.jax_annotations else contextlib.nullcontext()
        try:
            with ctx:
                yield self
        finally:
            depth = len(self._stack) - 1
            self._stack.pop()
            self.spans.append(
                Span(name=name, t0_us=t0, dur_us=self._now_us() - t0,
                     depth=depth, attrs=attrs)
            )

    def event(self, name: str, **attrs) -> None:
        self.events.append({"name": name, "ts_us": self._now_us(), "attrs": attrs})

    def sample(self, name: str, value: float) -> None:
        self.samples.append(
            {"name": name, "ts_us": self._now_us(), "value": float(value)}
        )

    # ---- export ----------------------------------------------------------
    def to_json(self) -> dict:
        """Timeline as plain data (spans sorted by start time)."""
        return {
            "clock": "perf_counter_us_since_tracer_start",
            "spans": [
                dataclasses.asdict(s)
                for s in sorted(self.spans, key=lambda s: s.t0_us)
            ],
            "events": list(self.events),
            "samples": list(self.samples),
        }

    def to_chrome(self) -> dict:
        """Chrome/Perfetto trace-event JSON (load in ``ui.perfetto.dev``)."""
        ev: list[dict] = []
        for s in sorted(self.spans, key=lambda s: s.t0_us):
            ev.append(
                {
                    "name": s.name, "ph": "X", "ts": s.t0_us, "dur": s.dur_us,
                    "pid": 0, "tid": 0, "args": s.attrs,
                }
            )
        for e in self.events:
            ev.append(
                {
                    "name": e["name"], "ph": "i", "ts": e["ts_us"], "s": "t",
                    "pid": 0, "tid": 0, "args": e["attrs"],
                }
            )
        for c in self.samples:
            ev.append(
                {
                    "name": c["name"], "ph": "C", "ts": c["ts_us"],
                    "pid": 0, "tid": 0, "args": {"value": c["value"]},
                }
            )
        # one global timestamp order: every (pid, tid) stream is monotonic,
        # which Perfetto's importer needs to thread the track correctly
        ev.sort(key=lambda e: e["ts"])
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def export_json(self, path: str, *, extra: dict | None = None) -> str:
        payload = dict(extra or {})
        payload["timeline"] = self.to_json()
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        return path

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
        return path
