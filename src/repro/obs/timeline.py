"""ServingTimeline — one registry + one tracer per observed component.

The bundle every instrumented surface owns (``BatchEngine.obs``,
``Engine.obs``): a :class:`~repro.obs.registry.MetricsRegistry` for the
aggregate view (counters/gauges/histograms, the ``*Stats`` legacy views read
from it) and a :class:`~repro.obs.trace.Tracer` for the per-step timeline
(spans, instants, per-step gauge samples → JSON + Chrome trace).

``gauge_sample`` is the bridge: it sets the registry gauge (so high-water
marks and the final snapshot agree) *and* appends a timeline counter sample
(so the per-step history is reconstructible) — one host float, recorded in
two places, which is what lets the acceptance test reconcile the timeline
against the legacy stats view exactly (DESIGN.md §9).

Everything here is host state; the zero-sync contract of ``obs`` holds:
no method issues a device→host transfer except ``snapshot()``/
``export_json()``, which are explicit drain points (lazy device counters
materialize there).
"""
from __future__ import annotations

import json

from repro.obs.flightrec import FlightRecorder
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer

__all__ = ["ServingTimeline"]


class ServingTimeline:
    def __init__(
        self,
        registry: MetricsRegistry | None = None,
        *,
        jax_annotations: bool = False,
        flight_capacity: int = 256,
    ):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = Tracer(jax_annotations=jax_annotations)
        # every event also lands in the flight recorder's bounded ring, so
        # a postmortem bundle has the recent timeline with zero extra call
        # sites at the recording surfaces (DESIGN.md §9.y)
        self.flight = FlightRecorder(capacity=flight_capacity)

    # ---- recording -------------------------------------------------------
    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    def event(self, name: str, **attrs) -> None:
        self.tracer.event(name, **attrs)
        self.flight.note(name, **attrs)

    def gauge_sample(self, name: str, value: float) -> None:
        """Set the registry gauge and log a timeline sample (one value)."""
        self.registry.gauge(name).set(value)
        self.tracer.sample(name, value)

    # ---- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Registry snapshot (the explicit lazy-counter drain point)."""
        return self.registry.snapshot()

    def export_json(self, path: str) -> str:
        """Metrics snapshot + full timeline as one JSON document."""
        payload = {"metrics": self.snapshot(), "timeline": self.tracer.to_json()}
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        return path

    def export_chrome(self, path: str) -> str:
        """Chrome/Perfetto trace of the timeline (spans/events/samples)."""
        return self.tracer.export_chrome(path)
