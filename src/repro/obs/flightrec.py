"""Flight recorder — bounded event ring + postmortem bundles (DESIGN.md §9.y).

An arena invariant violation used to be a bare ``AssertionError`` with the
interesting state (scheduler queue, page tables, refcounts, free bitmap)
already torn down by the time anyone looks.  The flight recorder keeps a
bounded ring of recent timeline events — ``ServingTimeline.event`` feeds it
automatically, so every admit/complete/grow/evict/cow the engine already
records is in the ring at zero extra call sites — and, on failure, freezes
everything into a JSON **postmortem bundle**:

* the event ring (most recent ``capacity`` events, in order),
* a full engine-state snapshot supplied by the failing component
  (scheduler queue + reservations, page tables, slab refcounts, prefix-trie
  shape, free-bitmap summary — see ``BatchEngine._flightrec_state``),
* the registry snapshot (THE lazy-counter drain point, so pending device
  scalars and the device counter plane are materialized into the bundle),
* the violation itself (exception type/message plus structured details like
  the offending slab ids).

Bundles are written to ``REPRO_FLIGHTREC_DIR`` when set (the pytest/CI hook
points it at an artifact dir) and always kept on ``last_bundle`` for
in-process inspection.  ``python -m repro.obs.dump bundle.json`` pretty-
prints one offline (``repro/obs/dump.py``).

Recording is host-only and O(1) per event; nothing here touches the device
until a bundle is actually built (failure path), so the zero-sync contract
of the hot path is untouched.
"""
from __future__ import annotations

import collections
import json
import os
import time

__all__ = ["FlightRecorder", "SCHEMA", "DIR_ENV"]

SCHEMA = "repro.flightrec/1"
DIR_ENV = "REPRO_FLIGHTREC_DIR"


def _jsonable(x):
    """Best-effort conversion of event/state values to JSON-safe types."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple, set, frozenset)):
        return [_jsonable(v) for v in x]
    tolist = getattr(x, "tolist", None)  # numpy scalars/arrays
    if callable(tolist):
        return _jsonable(tolist())
    item = getattr(x, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return repr(x)


class FlightRecorder:
    """Bounded ring of recent events + postmortem bundle builder."""

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self.events: collections.deque = collections.deque(maxlen=capacity)
        self.last_bundle: dict | None = None
        self.last_path: str | None = None
        self._seq = 0
        self._epoch = time.perf_counter()

    # ---- recording (hot path: O(1) host work) ----------------------------
    def note(self, name: str, **attrs) -> None:
        self._seq += 1
        ev = {
            "seq": self._seq,
            "t_us": (time.perf_counter() - self._epoch) * 1e6,
            "name": name,
        }
        if attrs:
            ev["attrs"] = {k: _jsonable(v) for k, v in attrs.items()}
        self.events.append(ev)

    def __len__(self) -> int:
        return len(self.events)

    # ---- failure path ----------------------------------------------------
    def bundle(
        self,
        *,
        reason: str,
        error: BaseException | None = None,
        state: dict | None = None,
        metrics: dict | None = None,
        device_counters: dict | None = None,
    ) -> dict:
        """Freeze the ring + supplied state into a postmortem bundle dict."""
        err = None
        if error is not None:
            err = {"type": type(error).__name__, "message": str(error)}
        b = {
            "schema": SCHEMA,
            "reason": reason,
            "error": err,
            "events_recorded": self._seq,
            "events": [dict(e) for e in self.events],
            "state": _jsonable(state or {}),
            "metrics": _jsonable(metrics),
            "device_counters": _jsonable(device_counters),
        }
        self.last_bundle = b
        return b

    def dump(
        self,
        *,
        reason: str,
        error: BaseException | None = None,
        state: dict | None = None,
        metrics: dict | None = None,
        device_counters: dict | None = None,
        directory: str | None = None,
    ) -> str | None:
        """Build a bundle and write it under ``directory`` (default: the
        ``REPRO_FLIGHTREC_DIR`` env var).  Returns the written path, or
        ``None`` when no directory is configured (the bundle is still kept
        on ``last_bundle``).  Never raises — the recorder must not mask the
        original failure."""
        b = self.bundle(
            reason=reason,
            error=error,
            state=state,
            metrics=metrics,
            device_counters=device_counters,
        )
        directory = directory or os.environ.get(DIR_ENV)
        if not directory:
            return None
        try:
            os.makedirs(directory, exist_ok=True)
            safe = "".join(c if c.isalnum() or c in "-_" else "_" for c in reason)
            path = os.path.join(
                directory, f"flightrec_{safe}_{os.getpid()}_{self._seq}.json"
            )
            with open(path, "w") as f:
                json.dump(b, f, indent=2)
                f.write("\n")
        except OSError:
            return None
        self.last_path = path
        return path
