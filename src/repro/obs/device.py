"""Device counter plane — in-kernel counters drained without host syncs.

The paper's pitch is growth *without host synchronization*, which makes the
structure's health (wave occupancy, masked-lane waste, tiles DMA'd per page
walk) invisible exactly where it matters: inside the kernels.  This module
is the device-side half of the observability layer (DESIGN.md §9.x): each
instrumented Pallas family writes a small int32 counter block as one extra
kernel output, the ops wrappers pack those blocks into a fixed-layout
float32 vector (:data:`SLOTS`), and the vector rides the caller's pytree —
through scan carries, across jit boundaries — as ordinary device data.

Nothing here reads a device value.  Draining goes through
:class:`DeviceCounterPlane`: ``add()`` appends a device vector (a list
append), ``flush()`` slices the device total into per-slot
``Counter.add_lazy`` pends — still zero transfers — and the numbers only
materialize when the registry snapshots or a counter is read, the same
explicit drain points the PR-8 layer already has.  The decode hot path
therefore stays at **zero** device→host transfers with instrumentation on
(transfer-guard + device_get-spy tested).

Collection inside traced code uses a :func:`tape`: ``kvcache``/ops record
vectors while the step function traces, and the step body (``serving/
steps.py``) sums the tape into an extra output when ``cfg.instrument`` is
set.  With ``instrument=False`` no tape exists, no vector is built, and
every trace is byte-identical to the uninstrumented program (compile-spy
tested).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

__all__ = [
    "SLOTS",
    "NSLOTS",
    "SLOT_INDEX",
    "CTR_ROWS",
    "CTR_LANES",
    "ctr_shape",
    "ctr_block_spec",
    "ctr_accum",
    "zeros",
    "pack",
    "from_block",
    "as_dict",
    "Tape",
    "tape",
    "record",
    "recording",
    "DeviceCounterPlane",
]

# One lane per counter, fixed layout: lane i of the in-kernel block row 0 is
# SLOTS[i].  Grouped by kernel family; the names double as registry counter
# names under the "device." prefix.
SLOTS: tuple[str, ...] = (
    # push_back: fused bucket append (kernels/push_back)
    "push_back.waves",          # kernel launches (one wave each)
    "push_back.lanes",          # wave lanes processed (rows × padded width)
    "push_back.active_lanes",   # Σ mask — lanes that carried an element
    "push_back.padded_lanes",   # lanes added by tile/MXU padding (pure waste)
    "push_back.level_writes",   # bucket-level slots written across all levels
    # paged gather: page-table walk (kernels/paged)
    "paged_gather.launches",
    "paged_gather.tiles",       # page tiles with a live slab id (DMA'd work)
    "paged_gather.masked_tiles",  # −1 / padded page entries walked (waste)
    # paged attend: flash-decode page walk (kernels/paged)
    "paged_attend.launches",
    "paged_attend.tiles",         # KV tiles entering the online softmax
    "paged_attend.tiles_skipped",  # page steps gated off (tail slabs, −1)
    "paged_attend.lanes",         # score lanes in visited tiles
    "paged_attend.masked_lanes",  # score lanes past kv_len in visited tiles
    # flatten: segmented gather (kernels/flatten)
    "flatten.launches",
    "flatten.rows_touched",     # block rows visited by the gather
    "flatten.span_rows",        # Σ (ends − starts) — the information bound
    # slab append: arena wave insert (kernels/paged.slab_append)
    "slab_append.waves",
    "slab_append.lanes",
    "slab_append.active_lanes",
)
NSLOTS = len(SLOTS)
SLOT_INDEX: dict[str, int] = {name: i for i, name in enumerate(SLOTS)}

# In-kernel counter block: (8, 128) int32 — the minimum int32 VMEM tile, so
# the extra output never perturbs the data operands' tiling.  Row 0 carries
# the counters (lane i = SLOTS[i]); rows 1..7 stay zero.
CTR_ROWS = 8
CTR_LANES = 128
assert NSLOTS <= CTR_LANES


def ctr_shape():
    """Out-shape of the in-kernel counter block."""
    return jax.ShapeDtypeStruct((CTR_ROWS, CTR_LANES), jnp.int32)


def ctr_block_spec():
    """BlockSpec pinning every grid step to the same (only) counter block —
    the grid-accumulator idiom: step 0 initializes, later steps add."""
    from jax.experimental import pallas as pl

    return pl.BlockSpec((CTR_ROWS, CTR_LANES), lambda *_: (0, 0))


def _contrib(shape, pairs):
    """Σ one-hot(lane=slot)·value over ``pairs`` → (CTR_ROWS, CTR_LANES)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    lanes = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    blk = jnp.zeros(shape, jnp.int32)
    for slot, value in pairs:
        hit = (rows == 0) & (lanes == SLOT_INDEX[slot])
        blk = blk + jnp.where(hit, jnp.asarray(value, jnp.int32), 0)
    return blk


def ctr_accum(ctr_ref, first, pairs):
    """Accumulate ``pairs`` of (slot name, int32 scalar) into the counter
    block ref.  ``first`` is this launch's first-grid-step predicate: that
    step overwrites (the output block is revisited, not zero-initialized),
    every later step adds.  Values must already be gated (use
    ``jnp.where(cond, v, 0)``, not ``pl.when``, so the accumulate itself is
    unconditional and the block stays consistent)."""
    from jax.experimental import pallas as pl

    blk = _contrib(ctr_ref.shape, pairs)

    @pl.when(first)
    def _init():
        ctr_ref[...] = blk

    @pl.when(jnp.logical_not(first))
    def _add():
        ctr_ref[...] = ctr_ref[...] + blk


# --------------------------------------------------------------------------
# host-side vector layout — (NSLOTS,) float32, one value per slot.
# --------------------------------------------------------------------------

def zeros() -> jax.Array:
    return jnp.zeros((NSLOTS,), jnp.float32)


def pack(**slots) -> jax.Array:
    """Build a counter vector from named slot values (device scalars or
    ints); unnamed slots are zero.  Dots in slot names are passed as
    ``pack(**{"push_back.waves": 1})``."""
    vec = zeros()
    for name, value in slots.items():
        vec = vec.at[SLOT_INDEX[name]].add(jnp.asarray(value, jnp.float32))
    return vec


def from_block(block: jax.Array) -> jax.Array:
    """In-kernel counter block → (NSLOTS,) vector (row 0, leading lanes)."""
    return block[0, :NSLOTS].astype(jnp.float32)


def as_dict(vec) -> dict[str, float]:
    """Materialize a counter vector → {slot: value}.  This READS the device
    value — call it only at drain points (benches, bundles, tests)."""
    host = jax.device_get(vec)
    return {name: float(host[i]) for i, name in enumerate(SLOTS)}


# --------------------------------------------------------------------------
# tape — collect vectors recorded inside traced code.
# --------------------------------------------------------------------------

class Tape:
    """An ordered list of counter vectors recorded under one :func:`tape`."""

    __slots__ = ("vecs",)

    def __init__(self):
        self.vecs: list = []

    def add(self, vec) -> None:
        self.vecs.append(vec)

    def total(self):
        """Device sum of everything recorded (zeros when nothing was)."""
        if not self.vecs:
            return zeros()
        if len(self.vecs) == 1:
            return self.vecs[0]
        return jnp.sum(jnp.stack(self.vecs), axis=0)


_ACTIVE: list[Tape] = []


@contextlib.contextmanager
def tape():
    """Open a collection scope: :func:`record` calls inside land on the
    yielded tape.  Scopes nest (innermost wins) — the step functions open
    one per scan-body iteration so recorded tracers never escape their
    trace level."""
    t = Tape()
    _ACTIVE.append(t)
    try:
        yield t
    finally:
        _ACTIVE.pop()


def record(vec) -> None:
    """Record a counter vector on the innermost active tape (no-op without
    one — ops can record unconditionally)."""
    if _ACTIVE:
        _ACTIVE[-1].add(vec)


def recording() -> bool:
    return bool(_ACTIVE)


# --------------------------------------------------------------------------
# plane — engine-side accumulator, drained through Counter.add_lazy.
# --------------------------------------------------------------------------

class DeviceCounterPlane:
    """Holds per-step counter vectors as device values; never syncs itself.

    ``add()`` is the hot-path call (a list append).  ``flush()`` sums the
    pending vectors on device and hands one scalar slice per slot to
    ``Counter.add_lazy`` — still zero transfers; the registry's existing
    drain points (snapshot / metric reads) do the single ``device_get``
    per counter.
    """

    PREFIX = "device."

    def __init__(self, registry):
        self.registry = registry
        self._pending: list = []

    @property
    def pending(self) -> int:
        return len(self._pending)

    def add(self, vec) -> None:
        self._pending.append(vec)

    def flush(self) -> None:
        """Move pending vectors into the registry as lazy counter adds
        (no device→host transfer happens here)."""
        if not self._pending:
            return
        tot = (
            self._pending[0]
            if len(self._pending) == 1
            else jnp.sum(jnp.stack(self._pending), axis=0)
        )
        self._pending = []
        for i, name in enumerate(SLOTS):
            self.registry.counter(
                self.PREFIX + name, help="device counter plane slot"
            ).add_lazy(tot[i])

    def counters(self) -> dict[str, float]:
        """Flush + read every slot → {slot: value}.  This is a drain point
        (one ``device_get`` per counter with pending adds)."""
        self.flush()
        out = {}
        for name in SLOTS:
            c = self.registry.get(self.PREFIX + name)
            out[name] = float(c.total()) if c is not None else 0.0
        return out
