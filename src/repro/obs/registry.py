"""Process-local metrics registry: counters, gauges, histograms with labels.

One registry per observed component (an engine, an arena, a pipeline — or the
process-wide :func:`default_registry`).  Metrics are host-side Python state:
recording is a dict update, never a device operation, so instrumentation is
safe on the append/decode hot path (the zero-sync contract, DESIGN.md §9).

The one deliberate exception is :meth:`Counter.add_lazy`: device scalars
(e.g. the live-count a freeze leaves behind) are *accumulated as device
values* and summed into the host total only when the metric is read or the
registry snapshots — so the transfer happens at an explicit drain point the
caller chose, never inside the recording call.  This is the registry-level
version of the ``FreezeStats.elements_frozen`` pattern (DESIGN.md §2).

Label values are part of the series key (``counter.inc(site="stop_drain")``);
cardinality is the caller's responsibility (label requests only in tests and
timelines, never unbounded user input).  Not thread-safe — the serving loop
is single-threaded host code.
"""
from __future__ import annotations

from typing import Any, Callable

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "GaugeFn",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
]

_Key = tuple  # sorted (label, value) pairs — the series key


def _key(labels: dict) -> _Key:
    return tuple(sorted(labels.items()))


def _series_name(name: str, key: _Key) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class _Metric:
    kind = "?"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help

    def snapshot_into(self, out: dict) -> None:
        raise NotImplementedError


class Counter(_Metric):
    """Monotonic count per label set; supports lazy device-scalar adds."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: dict[_Key, float] = {}
        self._lazy: dict[_Key, list] = {}  # pending device scalars

    def inc(self, n: float = 1, **labels) -> None:
        k = _key(labels)
        self._vals[k] = self._vals.get(k, 0) + n

    def add_lazy(self, scalar: Any, **labels) -> None:
        """Accumulate a device scalar without reading it.

        The value stays on device until :meth:`value`/:meth:`total`/
        ``snapshot`` drains it (one transfer for all pending scalars).
        """
        self._lazy.setdefault(_key(labels), []).append(scalar)

    def _drain(self) -> None:
        import jax
        import jax.numpy as jnp

        for k, pend in list(self._lazy.items()):
            if not pend:
                continue
            tot = pend[0] if len(pend) == 1 else jnp.sum(jnp.stack(pend))
            self._vals[k] = self._vals.get(k, 0) + int(jax.device_get(tot))
            self._lazy[k] = []

    def value(self, **labels) -> float:
        self._drain()
        return self._vals.get(_key(labels), 0)

    def total(self) -> float:
        """Sum across every label set (drains pending device scalars)."""
        self._drain()
        return sum(self._vals.values())

    def snapshot_into(self, out: dict) -> None:
        self._drain()
        for k, v in sorted(self._vals.items()):
            out[_series_name(self.name, k)] = v


class Gauge(_Metric):
    """Last-set value per label set, with a high-water mark."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: dict[_Key, float] = {}
        self._hwm: dict[_Key, float] = {}

    def set(self, v: float, **labels) -> None:
        k = _key(labels)
        self._vals[k] = v
        if v > self._hwm.get(k, float("-inf")):
            self._hwm[k] = v

    def value(self, **labels) -> float:
        return self._vals.get(_key(labels), 0)

    def hwm(self, **labels) -> float:
        """High-water mark over every ``set`` so far."""
        return self._hwm.get(_key(labels), 0)

    def snapshot_into(self, out: dict) -> None:
        for k, v in sorted(self._vals.items()):
            out[_series_name(self.name, k)] = {"value": v, "hwm": self._hwm[k]}


class GaugeFn(_Metric):
    """Gauge computed by a callback at snapshot time (zero recording cost).

    The hook for host counters owned elsewhere — e.g. a
    ``CapacityPlanner.host_syncs`` int — so existing accounting surfaces in
    the catalog without the owner importing ``obs``.
    """

    kind = "gauge"

    def __init__(self, name: str, fn: Callable[[], float], help: str = ""):
        super().__init__(name, help)
        self.fn = fn

    def value(self) -> float:
        return self.fn()

    def hwm(self) -> float:
        return self.fn()

    def snapshot_into(self, out: dict) -> None:
        v = self.fn()
        out[self.name] = {"value": v, "hwm": v}


def _summary(vals: list, count: int | None = None, total: float | None = None) -> dict:
    """Summary stats; ``count``/``total`` override the (possibly sampled)
    raw list with the exact running values a bounded reservoir keeps."""
    arr = np.asarray(vals, np.float64)
    n = int(arr.size) if count is None else int(count)
    s = float(arr.sum()) if total is None else float(total)
    return {
        "count": n,
        "sum": s,
        "mean": s / n if n else 0.0,
        "p50": float(np.quantile(arr, 0.50)),
        "p95": float(np.quantile(arr, 0.95)),
        "max": float(arr.max()),
    }


# Per-labelset sample cap: below it the histogram stores every observation
# (exact quantiles); past it, Vitter's algorithm R keeps a uniform reservoir
# so long serving runs hold O(1) memory per series instead of O(steps).
RESERVOIR_CAP = 4096


class Histogram(_Metric):
    """Sampled histogram per label set with exact count/sum.

    Memory per label set is bounded at :data:`RESERVOIR_CAP` samples: until
    the cap every observation is stored (quantiles are exact); past it the
    stored samples become a uniform reservoir (algorithm R, deterministic
    per-metric RNG) — quantiles turn into reservoir estimates while
    ``count``/``sum``/``mean`` stay exact running totals.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._vals: dict[_Key, list[float]] = {}  # bounded reservoirs
        self._count: dict[_Key, int] = {}  # exact observation counts
        self._sum: dict[_Key, float] = {}  # exact running sums
        import random
        import zlib

        # deterministic per-metric stream (hash() is process-salted)
        self._rng = random.Random(zlib.crc32(name.encode()))

    def observe(self, v: float, **labels) -> None:
        k = _key(labels)
        v = float(v)
        n = self._count.get(k, 0) + 1
        self._count[k] = n
        self._sum[k] = self._sum.get(k, 0.0) + v
        vals = self._vals.setdefault(k, [])
        if len(vals) < RESERVOIR_CAP:
            vals.append(v)
        else:  # algorithm R: keep each of the n seen with prob CAP/n
            j = self._rng.randrange(n)
            if j < RESERVOIR_CAP:
                vals[j] = v

    def values(self, **labels) -> list[float]:
        """Stored samples of one label set (every observation until
        :data:`RESERVOIR_CAP`, a uniform reservoir past it); with no
        labels, every stored sample merged."""
        if labels:
            return list(self._vals.get(_key(labels), []))
        return [v for vals in self._vals.values() for v in vals]

    def count(self, **labels) -> int:
        """Exact observation count (not bounded by the reservoir)."""
        if labels:
            return self._count.get(_key(labels), 0)
        return sum(self._count.values())

    def sum(self, **labels) -> float:
        """Exact running sum (not bounded by the reservoir)."""
        if labels:
            return self._sum.get(_key(labels), 0.0)
        return sum(self._sum.values())

    def quantile(self, q: float, **labels) -> float:
        """Quantile over the stored samples — exact while the label set has
        at most :data:`RESERVOIR_CAP` observations, a uniform-reservoir
        estimate beyond that."""
        vals = self.values(**labels)
        if not vals:
            raise ValueError(f"histogram {self.name}: no samples for {labels}")
        return float(np.quantile(np.asarray(vals, np.float64), q))

    def snapshot_into(self, out: dict) -> None:
        merged = self.values()
        if not merged:
            return
        summary = _summary(merged, self.count(), self.sum())
        if len(self._vals) > 1 or _key({}) not in self._vals:
            summary["series"] = {
                _series_name(self.name, k): _summary(
                    v, self._count.get(k, len(v)), self._sum.get(k)
                )
                for k, v in sorted(self._vals.items())
                if v
            }
        out[self.name] = summary


class MetricsRegistry:
    """Get-or-create metric namespace + one-call JSON-safe snapshot."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str) -> Any:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"not {cls.kind}"
            )
        elif help and not m.help:
            m.help = help
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def gauge_fn(self, name: str, fn: Callable[[], float], help: str = "") -> GaugeFn:
        m = self._metrics.get(name)
        if m is None:
            m = GaugeFn(name, fn, help)
            self._metrics[name] = m
        elif isinstance(m, GaugeFn):
            m.fn = fn
        else:
            raise TypeError(f"metric {name!r} already registered as {m.kind}")
        return m

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """→ {"counters": {...}, "gauges": {...}, "histograms": {...}}.

        This is a drain point: pending lazy device scalars are materialized
        here (and only here / on explicit metric reads).
        """
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        bucket = {"counter": "counters", "gauge": "gauges", "histogram": "histograms"}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            m.snapshot_into(out[bucket[m.kind]])
        return out


_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (component-scoped registries are separate)."""
    return _default
