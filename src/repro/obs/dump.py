"""Load + pretty-print flight-recorder postmortem bundles.

``python -m repro.obs.dump bundle.json`` renders a bundle written by
:class:`repro.obs.flightrec.FlightRecorder` — the violation, the structured
state snapshot (offending slabs, scheduler queue, refcount/free summaries),
the hottest device counters, and the tail of the event ring — so an arena
invariant violation from a CI run is diagnosable offline from the uploaded
artifact alone.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import flightrec

__all__ = ["load_bundle", "summarize", "main"]


def load_bundle(path: str) -> dict:
    """Read + validate a postmortem bundle (schema-checked round-trip)."""
    with open(path) as f:
        b = json.load(f)
    schema = b.get("schema")
    if schema != flightrec.SCHEMA:
        raise ValueError(
            f"{path}: schema {schema!r} is not {flightrec.SCHEMA!r}"
        )
    for key in ("reason", "events", "state"):
        if key not in b:
            raise ValueError(f"{path}: bundle is missing {key!r}")
    return b


def _fmt_counters(counters: dict, limit: int = 12) -> list[str]:
    nonzero = {k: v for k, v in counters.items() if v}
    top = sorted(nonzero.items(), key=lambda kv: -abs(kv[1]))[:limit]
    return [f"    {name:<28} {value:g}" for name, value in top]


def summarize(bundle: dict, *, tail: int = 20) -> str:
    """Human-readable rendering of one bundle."""
    lines = [f"flight recorder bundle — reason: {bundle['reason']}"]
    err = bundle.get("error")
    if err:
        lines.append(f"  error: {err['type']}: {err['message']}")
    state = bundle.get("state") or {}
    inv = state.get("invariant")
    if inv:
        lines.append("  invariant:")
        for k, v in inv.items():
            lines.append(f"    {k}: {v}")
    sched = state.get("scheduler")
    if sched:
        lines.append(
            "  scheduler: tick {tick}, {npending} pending, slots {slots}".format(
                tick=sched.get("tick"),
                npending=len(sched.get("pending", [])),
                slots=sched.get("phase"),
            )
        )
    alloc = state.get("allocator")
    if alloc:
        lines.append(
            "  allocator: {n_slabs} slabs, {free} free, refcount sum "
            "{ref_sum}".format(
                n_slabs=alloc.get("n_slabs"),
                free=alloc.get("free_slabs"),
                ref_sum=alloc.get("refcount_sum"),
            )
        )
    pages = state.get("page_tables")
    if pages:
        lines.append(f"  page tables: {len(pages)} live slots")
    prefix = state.get("prefix")
    if prefix:
        lines.append(f"  prefix cache: {prefix}")
    dev = bundle.get("device_counters") or {}
    rows = _fmt_counters(dev)
    if rows:
        lines.append("  device counters (nonzero):")
        lines.extend(rows)
    events = bundle.get("events") or []
    lines.append(
        f"  events: {len(events)} in ring "
        f"({bundle.get('events_recorded', len(events))} recorded)"
    )
    for ev in events[-tail:]:
        attrs = ev.get("attrs")
        suffix = f" {attrs}" if attrs else ""
        lines.append(f"    [{ev['seq']:>6}] {ev['name']}{suffix}")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bundle", help="path to a flightrec_*.json bundle")
    ap.add_argument(
        "--tail", type=int, default=20, help="event-ring tail length to show"
    )
    args = ap.parse_args(argv)
    try:
        bundle = load_bundle(args.bundle)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"repro.obs.dump: {e}", file=sys.stderr)
        return 1
    print(summarize(bundle, tail=args.tail))
    return 0


if __name__ == "__main__":
    sys.exit(main())
