"""seamless-m4t-large-v2 [audio] — enc-dec, 24L d_model=1024 16H d_ff=8192
vocab=256206.  [arXiv:2308.11596; hf]

Backbone only per the assignment: the speech frontend is a stub — the encoder
consumes precomputed frame embeddings from ``input_specs()``; the text decoder
cross-attends to the encoder memory.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,  # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    activation="relu",
    rope_theta=10_000.0,
)
