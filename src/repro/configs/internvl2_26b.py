"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT frontend is a stub (``input_specs()`` provides 256 patch
embeddings); the InternLM2-style decoder is the real backbone.
[arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    n_prefix_embeds=256,
    activation="swiglu",
    rope_theta=1_000_000.0,
)
