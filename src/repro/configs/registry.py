"""Architecture registry: ``--arch <id>`` → ModelConfig (+ reduced variants).

``get(name)`` returns the exact assigned config; ``reduced(name)`` shrinks the
same family shape (few layers / narrow width / tiny vocab / few experts) for
CPU smoke tests — the full configs are only ever exercised via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

_MODULES = {
    "qwen3-32b": "repro.configs.qwen3_32b",
    "qwen1.5-0.5b": "repro.configs.qwen15_0_5b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "qwen2.5-3b": "repro.configs.qwen25_3b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
}

ARCH_NAMES: tuple[str, ...] = tuple(_MODULES)


def get(name: str) -> ModelConfig:
    import importlib

    try:
        mod = importlib.import_module(_MODULES[name])
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; options: {ARCH_NAMES}") from None
    return mod.CONFIG


def reduced(name: str, **overrides) -> ModelConfig:
    """Same family, tiny dimensions — one forward/train step runs on CPU."""
    cfg = get(name)
    period = len(cfg.layout)
    changes: dict = dict(
        n_layers=2 * period,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 // cfg.group) if cfg.group > 1 else 4,
        d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_prefix_embeds=min(cfg.n_prefix_embeds, 4),
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        dtype="float32",
        param_dtype="float32",
        attention_chunk=32,
        cache_b0=8,
        remat=False,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=128,
            capacity_b0=4,
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk_size=8
        )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
