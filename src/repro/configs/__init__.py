from repro.configs.base import SHAPES, ModelConfig, MoEConfig, ShapeConfig, SSMConfig, sub_quadratic_ready
from repro.configs.registry import ARCH_NAMES, get, reduced

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
    "sub_quadratic_ready", "ARCH_NAMES", "get", "reduced",
]
