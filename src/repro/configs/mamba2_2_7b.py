"""mamba2-2.7b [ssm] — 64L d_model=2560, attention-free, ssm_state=128,
vocab=50280. SSD (state-space duality). [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # unused (attention-free); kept for config uniformity
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    layout=("mamba",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    tie_embeddings=True,
)
