"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attention 1:7 interleave. [arXiv:2403.19887; hf]

Period of 8 layers: slots 0-3 mamba, slot 4 attention (offset 4 per the Jamba
paper), slots 5-7 mamba; MoE on every second layer (offset 1).  Jamba's
Mamba-1 blocks are realized with the SSD layer (d_state=16) — see DESIGN.md.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    layout=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, moe_period=2, moe_offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    activation="swiglu",
    rope_theta=10_000.0,
)
