"""Model/run configuration dataclasses.

A ``ModelConfig`` describes one architecture from the assigned pool.  Layer
heterogeneity (Jamba's 1:7 Mamba:attention interleave, every-other-layer MoE)
is expressed as a repeating **period**: ``layout`` lists the layer kinds of one
period and the stack scans ``n_layers // len(layout)`` periods — keeping the
lowered HLO O(one period) regardless of depth (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

LayerKind = Literal["attn", "mamba"]
AttentionImpl = Literal["blockwise", "blockwise_tri", "xla", "pallas"]
CachePolicy = Literal["static", "semistatic", "ggarray", "two_phase", "paged"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Which layers in the period run MoE MLPs (indices into layout).
    moe_period: int = 1  # every `moe_period`-th layer is MoE
    moe_offset: int = 0
    # GGArray-style growable expert buffers: capacity snaps to geometric
    # bucket levels instead of dropping at a fixed factor (DESIGN.md §3).
    ggarray_capacity: bool = False
    capacity_b0: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None  # default d_model // n_heads
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    # layer heterogeneity: one period of layer kinds; dense = ("attn",)
    layout: tuple[LayerKind, ...] = ("attn",)
    # sub-configs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # encoder-decoder (seamless): encoder layers + cross-attention decoder
    n_enc_layers: int = 0
    # multimodal stub frontend: number of prefix embeddings provided by
    # input_specs() (ViT patches / audio frames), 0 = text-only
    n_prefix_embeds: int = 0
    # MLP activation
    activation: Literal["swiglu", "gelu", "relu"] = "swiglu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "bfloat16"
    # implementation switches
    attention_impl: AttentionImpl = "blockwise"
    attention_chunk: int = 1024  # KV chunk for blockwise attention
    cache_policy: CachePolicy = "ggarray"
    cache_b0: int = 2048  # first KV bucket length (GGArray B0 for the cache)
    cache_quant: bool = False  # int8 KV cache (per-token/head scales) — §Perf
    # paged policy (slab arena, DESIGN.md §4): tokens per slab (0 → cache_b0;
    # equality with cache_b0 is what makes the paged level walk bit-exact vs
    # the ggarray bucket walk) and the attend implementation behind it
    cache_slab: int = 0
    paged_attend_impl: Literal["levels", "pallas"] = "levels"
    # memory space for the indirection kernels (paged / push_back / flatten):
    # None = auto (hbm on TPU, vmem in interpret mode — kernels/common)
    kernel_memory_space: Literal["vmem", "hbm"] | None = None
    insertion_method: str = "scan"
    remat: bool = True
    # device counter plane (obs/device, DESIGN.md §9.x): when set, the cache
    # ops record in-kernel/jnp counters and the step functions return an
    # extra counter vector.  Off by default — the uninstrumented trace is
    # byte-identical to a config without the field (compile-spy tested).
    instrument: bool = False

    def __post_init__(self):
        if self.n_layers % len(self.layout):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period {len(self.layout)}"
            )
        if self.n_heads % self.n_kv_heads:
            raise ValueError(f"{self.name}: n_heads must divide by n_kv_heads")

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the table TP-shards cleanly (16 | 256);
        out-of-vocab logit columns are masked to -inf before any softmax."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layout)

    @property
    def slab_tokens(self) -> int:
        """Tokens per KV slab under the paged cache policy."""
        return self.cache_slab or self.cache_b0

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    def is_moe_layer(self, idx_in_period: int) -> bool:
        if self.moe is None:
            return False
        return idx_in_period % self.moe.moe_period == self.moe.moe_offset

    # -- parameter counting (for roofline MODEL_FLOPS) --------------------
    def param_counts(self) -> dict[str, float]:
        """Total and active parameter counts (active ≙ per-token compute)."""
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        if self.qkv_bias:
            attn += dh * (self.n_heads + 2 * self.n_kv_heads)
        dense_mlp = (
            3 * d * self.d_ff if self.activation == "swiglu" else 2 * d * self.d_ff
        )
        mamba = 0.0
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            g, n = self.ssm.n_groups, self.ssm.d_state
            nh = self.ssm.n_ssm_heads(d)
            in_proj = d * (2 * di + 2 * g * n + nh)
            mamba = in_proj + (di + 2 * g * n) * self.ssm.d_conv + di * d + di + 2 * nh

        total = 0.0
        active = 0.0
        for i, kind in enumerate(self.layout):
            if kind == "mamba":
                total += mamba
                active += mamba
                continue
            total += attn
            active += attn
            if self.is_moe_layer(i):
                e_mlp = 3 * d * self.moe.d_ff_expert
                total += self.moe.n_experts * e_mlp + d * self.moe.n_experts
                active += self.moe.top_k * e_mlp + d * self.moe.n_experts
            else:
                total += dense_mlp
                active += dense_mlp
        total *= self.n_periods
        active *= self.n_periods
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        enc = 0.0
        if self.n_enc_layers:
            enc = self.n_enc_layers * (attn + dense_mlp)
            # decoder cross-attention blocks
            total += self.n_layers * attn
            active += self.n_layers * attn
        total += embed + enc
        active += embed + enc
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def sub_quadratic_ready(cfg: ModelConfig) -> bool:
    """True if the arch can run long_500k (SSM/hybrid; not pure full attention)."""
    return any(kind == "mamba" for kind in cfg.layout)
