"""The paper's own experimental configuration (§VI).

Start size 1e6, duplicate 10× to 1.024e9; GGArray variants with 32 and 512
LFVectors; B0 sized so the initial size fits the first bucket chain.  The
benchmark harness scales ``start_size`` down for CPU wall-clock sanity while
keeping the duplication structure identical.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GGArrayDemoConfig:
    start_size: int = 1_000_000
    duplications: int = 10
    nblocks_variants: tuple[int, ...] = (32, 512)
    b0_per_block: int = 64
    rw_op_repeats: int = 30  # the paper's "+1, 30 times" read/write kernel


CONFIG = GGArrayDemoConfig()
