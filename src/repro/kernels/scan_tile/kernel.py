"""VPU tile prefix-sum kernel — the warp-shuffle scan (§III.B.2) on TPU.

The GPU version scans within a warp via ``__shfl_up_sync`` and stitches warps
with shared-memory partials + atomics.  On TPU the VPU computes a per-tile
``cumsum`` over a VMEM block, and — because TPU grid steps execute in order —
the inter-tile partial is a plain VMEM scratch carry, with no atomics and no
inter-block handshake (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import MXU_LANE

__all__ = ["row_scan_pallas"]

DEFAULT_ROW_TILE = 8
DEFAULT_COL_TILE = 512  # wider than the MXU kernel: VPU scans are lane-parallel


def _scan_kernel(x_ref, o_ref, carry_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    y = jnp.cumsum(x_ref[...], axis=-1)
    o_ref[...] = y + carry_ref[...]
    carry_ref[...] += y[:, -1:]


def row_scan_pallas(
    x: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    col_tile: int = DEFAULT_COL_TILE,
    interpret: bool = False,
) -> jax.Array:
    """Per-row inclusive prefix sum via VPU tile scans + sequential carry."""
    rows, cols = x.shape
    if rows % row_tile or cols % col_tile:
        raise ValueError(f"unpadded shape {x.shape}; pad to ({row_tile}, {col_tile})")
    return pl.pallas_call(
        _scan_kernel,
        grid=(rows // row_tile, cols // col_tile),
        in_specs=[pl.BlockSpec((row_tile, col_tile), lambda r, c: (r, c))],
        out_specs=pl.BlockSpec((row_tile, col_tile), lambda r, c: (r, c)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        scratch_shapes=[pltpu.VMEM((row_tile, 1), x.dtype)],
        interpret=interpret,
    )(x)
