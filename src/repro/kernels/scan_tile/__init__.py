from repro.kernels.scan_tile import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
