"""Pure-jnp oracle for the VPU tile scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["row_scan"]


def row_scan(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x, axis=-1, dtype=x.dtype)
