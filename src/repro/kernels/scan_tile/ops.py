"""jit'd public wrapper for the VPU tile scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import common
from repro.kernels.scan_tile import kernel as _kernel
from repro.kernels.scan_tile import ref as _ref

__all__ = ["row_scan"]


@partial(jax.jit, static_argnames=("interpret", "use_ref"))
def row_scan(
    x: jax.Array, *, interpret: bool | None = None, use_ref: bool = False
) -> jax.Array:
    if x.ndim != 2:
        raise ValueError(f"expected (rows, cols), got {x.shape}")
    if use_ref:
        return _ref.row_scan(x)
    rows, cols = x.shape
    col_tile = min(_kernel.DEFAULT_COL_TILE, max(common.MXU_LANE, cols))
    xp = common.pad_to(x, _kernel.DEFAULT_ROW_TILE, axis=0)
    xp = common.pad_to(xp, col_tile, axis=1)
    out = _kernel.row_scan_pallas(
        xp, col_tile=col_tile, interpret=common.should_interpret(interpret)
    )
    return out[:rows, :cols]
