"""Shared kernel utilities: interpret-mode policy and padding helpers.

All kernels target TPU (``pl.pallas_call`` + explicit ``BlockSpec`` VMEM
tiling).  On non-TPU backends (this container is CPU) they execute in
``interpret=True`` mode, which runs the kernel body as traced JAX ops — the
correctness oracle path used by the test suite.  ``REPRO_FORCE_INTERPRET=1``
forces interpret mode everywhere (CI sets it so kernel regressions surface
on CPU runners regardless of backend detection).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

__all__ = ["should_interpret", "pad_to", "MXU_LANE"]

MXU_LANE = 128  # MXU systolic dimension / VREG lane count


def should_interpret(interpret: bool | None) -> bool:
    """Resolve the interpret flag: env force > explicit > interpret off-TPU."""
    if os.environ.get("REPRO_FORCE_INTERPRET") == "1":
        return True
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def pad_to(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (VMEM tile alignment)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)
