"""Shared kernel utilities: interpret policy, memory-space grid layer, padding.

All kernels target TPU (``pl.pallas_call`` + explicit ``BlockSpec`` tiling).
On non-TPU backends (this container is CPU) they execute in ``interpret=True``
mode, which runs the kernel body as traced JAX ops — the correctness oracle
path used by the test suite.  ``REPRO_FORCE_INTERPRET=1`` forces interpret
mode everywhere (CI sets it so kernel regressions surface on CPU runners
regardless of backend detection).

Memory spaces (DESIGN.md §4 "Memory-space tiers")
-------------------------------------------------
The three indirection kernel families (``kernels/paged``,
``kernels/push_back``, ``kernels/flatten``) each exist in two tilings behind
one :class:`GridPlan`:

``"vmem"``
    Every operand is auto-pipelined into VMEM by its ``BlockSpec``; the
    indirection tables (page tables, size vectors, prefix sums) ride along as
    ordinary tiled operands and the *data* operands (slab pool, bucket
    levels, compacted plane) are resident per grid step.  Cheap to launch and
    exactly what interpret mode wants — but per-step residency scales with
    the whole pool, which caps the problem size on a real chip.

``"hbm"``
    The data stays HBM-resident.  The indirection tables become
    **scalar-prefetch operands** (``pltpu.PrefetchScalarGridSpec``) — they are
    tiny (Tarjan & Zwick: O(√n)–O(log n) entries), live in SMEM, and are
    available *before* the kernel body runs, so a ``BlockSpec.index_map`` can
    read them to DMA exactly one slab / level / block-row tile per grid step.
    Kernels that need data-dependent tile *counts* (flatten's ragged block
    spans, push_back's touched levels) instead take ``pltpu.ANY``-space refs
    and issue explicit ``make_async_copy`` DMAs gated by prefetched touch
    tables.

Both spaces run the same index math and are bit-exact against the jnp
oracles; ``resolve_memory_space`` picks ``vmem`` under interpret mode and
``hbm`` on a real TPU unless overridden (arg > ``REPRO_MEMORY_SPACE`` env >
backend default).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "should_interpret",
    "pad_to",
    "MXU_LANE",
    "MEMORY_SPACES",
    "resolve_memory_space",
    "DISPATCH_METHODS",
    "MXU_DISPATCH_WAVE",
    "resolve_dispatch",
    "extent_row",
    "GridPlan",
]

MXU_LANE = 128  # MXU systolic dimension / VREG lane count

MEMORY_SPACES = ("vmem", "hbm")

# Wave width at which the insert permutation moves from the exact int32
# one-hot reduction (VPU, O(m²) compares) to the MXU dispatch matmul.
# Measured, not a-priori: the threshold lives in kernels/tuning.py (single
# source of truth shared with the benchmark sweeps).
from repro.kernels.tuning import MXU_DISPATCH_WAVE  # noqa: E402

DISPATCH_METHODS = ("auto", "onehot", "mxu")


def should_interpret(interpret: bool | None) -> bool:
    """Resolve the interpret flag: env force > explicit > interpret off-TPU."""
    if os.environ.get("REPRO_FORCE_INTERPRET") == "1":
        return True
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def resolve_memory_space(
    memory_space: str | None, interpret: bool | None = None
) -> str:
    """Resolve the kernel memory space: arg > env > backend default.

    The default is ``"hbm"`` on a real TPU (pools/levels cannot be VMEM
    resident at serving scale) and ``"vmem"`` in interpret mode (everything
    is host memory anyway and the simpler tiling traces faster).  Setting
    ``REPRO_MEMORY_SPACE=vmem|hbm`` overrides the default everywhere — the
    hook CI uses to run the hbm tilings on CPU runners.
    """
    env = os.environ.get("REPRO_MEMORY_SPACE")
    space = memory_space if memory_space is not None else env
    if space is None:
        space = "vmem" if should_interpret(interpret) else "hbm"
    if space not in MEMORY_SPACES:
        raise ValueError(f"memory_space {space!r} not in {MEMORY_SPACES}")
    return space


def resolve_dispatch(dispatch: str, m: int, dtype: Any) -> str:
    """Resolve the insert-permutation backend for an ``m``-wide wave.

    ``"auto"`` routes waves of at least :data:`MXU_DISPATCH_WAVE` lanes
    through the MXU dispatch matmul — but only for payloads the f32 matmul
    reproduces bit-for-bit (f32/bf16/f16, int8/int16); wide ints and f64
    can exceed the f32 mantissa the MXU accumulates in and stay on the
    exact one-hot reduction.  Explicit ``"onehot"``/``"mxu"`` are honored
    as given.
    """
    if dispatch not in DISPATCH_METHODS:
        raise ValueError(f"dispatch {dispatch!r} not in {DISPATCH_METHODS}")
    if dispatch != "auto":
        return dispatch
    dt = jnp.dtype(dtype)
    exact = (jnp.issubdtype(dt, jnp.floating) and dt.itemsize <= 4) or (
        jnp.issubdtype(dt, jnp.integer) and dt.itemsize <= 2
    )
    return "mxu" if m >= MXU_DISPATCH_WAVE and exact else "onehot"


def extent_row(ext, off, e: int, size: int):
    """Two-level page-table resolution for a ``BlockSpec.index_map``.

    ``ext``/``off`` are this step's scalar-prefetched two-level table entries
    (``pool/extents.resolve_pages``); the index map of extent ``e``'s operand
    returns ``off`` when the step's slab lives in extent ``e`` and a parked
    in-bounds row otherwise — every extent DMAs a tile each step, but the
    body consumes only the one ``ext`` selects, so off-extent tiles are
    provably inert (the multi-extent analog of the page −1 clip).
    """
    return jnp.where(ext == e, jnp.clip(off, 0, size - 1), 0)


def pad_to(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (VMEM tile alignment)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


@dataclasses.dataclass(frozen=True)
class GridPlan:
    """One kernel grid, two memory spaces — the shared scalar-prefetch layer.

    A kernel family builds one ``GridPlan`` per memory space and calls
    :meth:`pallas_call`; the plan owns the mechanics that differ between the
    spaces so the kernel modules only describe *what* each operand is:

    * operand order is uniform — ``body(*tables, *tensors, *outs, *scratch)``
      in both spaces, with the ``num_tables`` leading operands being the
      int32 indirection tables;
    * on the ``hbm`` path the tables become ``PrefetchScalarGridSpec`` scalar
      operands (SMEM, readable from every ``index_map``), and
      ``table_specs`` is ignored;
    * on the ``vmem`` path the tables are ordinary operands tiled by
      ``table_specs``;
    * ``aliases`` maps *tensor*-operand positions to outputs; the plan
      offsets them by the table count for the flat numbering
      ``input_output_aliases`` wants (scalar-prefetch operands included).

    ``in_specs`` entries may be ``pl.BlockSpec(memory_space=pltpu.ANY)`` for
    operands the body DMAs manually (flatten's compact plane, push_back's
    bucket levels).

    ``instrument=True`` appends the device counter plane's block
    (``obs/device``: (8, 128) int32, every grid step mapped to the same
    block — the grid-accumulator idiom) as one extra output in **both**
    memory spaces: the body receives its ref after the declared outputs and
    before scratch, and writes it with ``device.ctr_accum``.  Off by
    default, and when off this dataclass field doesn't reach the
    ``pallas_call`` — the uninstrumented plan builds the exact same program
    as before the counter plane existed.
    """

    memory_space: str
    grid: tuple[int, ...]
    num_tables: int
    table_specs: Sequence[Any]
    in_specs: Sequence[Any]
    out_specs: Any
    scratch_shapes: Sequence[Any] = ()
    aliases: Mapping[int, int] = dataclasses.field(default_factory=dict)
    instrument: bool = False

    def __post_init__(self):
        if self.memory_space not in MEMORY_SPACES:
            raise ValueError(
                f"memory_space {self.memory_space!r} not in {MEMORY_SPACES}"
            )

    def _with_counters(self, out_specs, out_shape):
        """Append the counter block's spec + shape (instrumented plans)."""
        from repro.obs import device

        if not isinstance(out_specs, (list, tuple)):
            out_specs = [out_specs]
        if not isinstance(out_shape, (list, tuple)):
            out_shape = [out_shape]
        return (
            list(out_specs) + [device.ctr_block_spec()],
            list(out_shape) + [device.ctr_shape()],
        )

    def pallas_call(self, body, out_shape, *, interpret: bool = False):
        """→ the configured ``pl.pallas_call`` (call it with tables first)."""
        aliases = {self.num_tables + i: o for i, o in self.aliases.items()}
        out_specs = self.out_specs
        if self.instrument:
            out_specs, out_shape = self._with_counters(out_specs, out_shape)
        if self.memory_space == "hbm":
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=self.num_tables,
                grid=self.grid,
                in_specs=list(self.in_specs),
                out_specs=out_specs,
                scratch_shapes=list(self.scratch_shapes),
            )
            return pl.pallas_call(
                body,
                grid_spec=grid_spec,
                out_shape=out_shape,
                input_output_aliases=aliases,
                interpret=interpret,
            )
        kwargs: dict[str, Any] = {}
        if self.scratch_shapes:
            kwargs["scratch_shapes"] = list(self.scratch_shapes)
        return pl.pallas_call(
            body,
            grid=self.grid,
            in_specs=list(self.table_specs) + list(self.in_specs),
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
            input_output_aliases=aliases,
            **kwargs,
        )
