"""Shared kernel utilities: interpret-mode policy and padding helpers.

All kernels target TPU (``pl.pallas_call`` + explicit ``BlockSpec`` VMEM
tiling).  On non-TPU backends (this container is CPU) they execute in
``interpret=True`` mode, which runs the kernel body as traced JAX ops — the
correctness oracle path used by the test suite.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["should_interpret", "pad_to", "MXU_LANE"]

MXU_LANE = 128  # MXU systolic dimension / VREG lane count


def should_interpret(interpret: bool | None) -> bool:
    """Resolve the interpret flag: explicit wins, else interpret off-TPU."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def pad_to(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple (VMEM tile alignment)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)
