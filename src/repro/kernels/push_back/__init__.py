from repro.kernels.push_back import kernel, ops, ref  # noqa: F401
