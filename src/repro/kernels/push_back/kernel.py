"""Fused push-back kernel — offsets + multi-level scatter in one tiled pass.

The jnp append path is two dispatches: an exclusive prefix sum of the mask
(``core.insertion``) and then one scatter per bucket level.  This kernel fuses
the whole write phase: one grid step per block tile computes the per-block
offsets on the VPU (``cumsum``), resolves the dense insert permutation with an
exact int32 one-hot reduction (the ``dispatch_mxu`` idiom — no float
accumulation, so results are bit-identical to the jnp oracle), and writes
every bucket level in the same pass.

The scatter is expressed as a *gather* per level — output slot ``start_b + j``
takes wave element ``sel[start_b + j − size_row]`` when that offset is live —
because TPU Pallas has no dynamic scatter primitive; a shifted-window gather
over the (tiny) wave is the vectorizable formulation.  Bucket levels are
passed through ``input_output_aliases`` so untouched slots are never copied:
together with ``donate_argnums`` at the jit boundary this is what makes the
donated append O(wave) writes instead of O(capacity) copies.

Items are carried as one trailing feature axis ``D`` (non-scalar payloads are
flattened by ``ops``): every ref is ``(rows, width, D)`` with the permutation
computed on the 2-D ``(rows, m)`` mask and broadcast over ``D`` — this is the
3-D variant the KV-cache decode path needs ((heads, dim) items; was a jnp
fallback before).

The kernel takes ``ngroups`` independent payload *groups* sharing one mask
and size vector (each group has its own bucket tuple, feature width, and
dtype): the offsets and the one-hot permutation — the expensive part of a
tiny wave — are computed **once** and reused for every group's scatter.
This is what lets the quantized KV-cache decode write k/v/ks/vs in a single
launch instead of four.

VMEM note: like the flatten kernel, every bucket level's block-tile rows stay
resident per grid step (total = per-block capacity · tile rows), plus an
(m × m) one-hot for the permutation.  A production variant would keep levels
in HBM and DMA only those the wave's position interval [min sizes, max pos)
can touch; the index math is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import indexing

__all__ = ["push_back_pallas"]

DEFAULT_BLOCK_TILE = 8


def _push_back_kernel(mask_ref, sizes_ref, *refs, starts, bsizes, ngroups):
    nlev = len(bsizes)
    elems_refs = refs[:ngroups]
    level_in = refs[ngroups : ngroups + ngroups * nlev]  # group-major
    level_out = refs[ngroups + ngroups * nlev : ngroups + 2 * ngroups * nlev]
    pos_ref = refs[-2]
    nsz_ref = refs[-1]

    mask = mask_ref[...]  # (rows, m) int32 0/1
    sizes = sizes_ref[...]  # (rows, 1) int32
    rows, m = mask.shape

    inc = jnp.cumsum(mask, axis=1)
    off = inc - mask  # exclusive prefix sum (the insertion offsets)
    count = inc[:, -1:]  # (rows, 1)
    pos = sizes + off  # absolute in-block positions

    # Dense insert permutation: sel[r, o] = the unique masked lane k with
    # off[r, k] == o.  Exact int32 one-hot reduction — value bits never touch
    # arithmetic, so the gather below is bit-identical to the jnp scatter.
    # Computed ONCE, reused by every payload group's scatter.
    iota_o = jax.lax.broadcasted_iota(jnp.int32, (rows, m, m), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (rows, m, m), 2)
    onehot = (off[:, None, :] == iota_o) & (mask[:, None, :] > 0)
    sel = jnp.sum(jnp.where(onehot, iota_k, 0), axis=2)  # (rows, m)

    for g in range(ngroups):
        elems = elems_refs[g][...]  # (rows, m, D_g)
        gathered = jnp.take_along_axis(elems, sel[:, :, None], axis=1)
        for b in range(nlev):
            j = jax.lax.broadcasted_iota(jnp.int32, (rows, bsizes[b]), 1)
            o = starts[b] + j - sizes  # wave offset landing at this slot
            valid = (o >= 0) & (o < count)
            oc = jnp.clip(o, 0, m - 1)
            vals = jnp.take_along_axis(gathered, oc[:, :, None], axis=1)
            level_out[g * nlev + b][...] = jnp.where(
                valid[:, :, None], vals, level_in[g * nlev + b][...]
            )

    pos_ref[...] = jnp.where(mask > 0, pos, -1)
    nsz_ref[...] = sizes + count


def push_back_pallas(
    bucket_groups: tuple[tuple[jax.Array, ...], ...],  # per group, level b: (nblocks, B0·2^b, D_g)
    sizes: jax.Array,  # (nblocks, 1) int32
    b0: int,
    elem_groups: tuple[jax.Array, ...],  # per group: (nblocks, m, D_g)
    mask: jax.Array,  # (nblocks, m) int32 0/1
    *,
    block_tile: int = DEFAULT_BLOCK_TILE,
    interpret: bool = False,
) -> tuple[tuple[tuple[jax.Array, ...], ...], jax.Array, jax.Array]:
    """→ (new level groups, positions (−1 where masked), new sizes (nblocks, 1))."""
    ngroups = len(elem_groups)
    nblocks, m, _ = elem_groups[0].shape
    if nblocks % block_tile:
        raise ValueError(f"nblocks {nblocks} must divide by tile {block_tile}")
    nlev = len(bucket_groups[0])
    starts = indexing.bucket_starts(b0, nlev)
    bsizes = indexing.bucket_sizes(b0, nlev)
    kernel = functools.partial(
        _push_back_kernel, starts=starts, bsizes=bsizes, ngroups=ngroups
    )
    row_spec = lambda width: pl.BlockSpec((block_tile, width), lambda i: (i, 0))
    item_spec = lambda width, d: pl.BlockSpec(
        (block_tile, width, d), lambda i: (i, 0, 0)
    )
    dims = [e.shape[2] for e in elem_groups]
    level_specs = [
        item_spec(sz, d) for d in dims for sz in bsizes
    ]
    outs = pl.pallas_call(
        kernel,
        grid=(nblocks // block_tile,),
        in_specs=[row_spec(m), row_spec(1)]
        + [item_spec(m, d) for d in dims]
        + level_specs,
        out_specs=level_specs + [row_spec(m), row_spec(1)],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, sz, d), grp[0].dtype)
            for grp, d in zip(bucket_groups, dims)
            for sz in bsizes
        ]
        + [
            jax.ShapeDtypeStruct((nblocks, m), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
        ],
        # level inputs alias their outputs: untouched slots are never copied.
        input_output_aliases={
            2 + ngroups + i: i for i in range(ngroups * nlev)
        },
        interpret=interpret,
    )(mask, sizes, *elem_groups, *(lvl for grp in bucket_groups for lvl in grp))
    nl = ngroups * nlev
    groups = tuple(
        tuple(outs[g * nlev : (g + 1) * nlev]) for g in range(ngroups)
    )
    return groups, outs[nl], outs[nl + 1]
