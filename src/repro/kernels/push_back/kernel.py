"""Fused push-back kernel — offsets + multi-level scatter in one tiled pass.

The jnp append path is two dispatches: an exclusive prefix sum of the mask
(``core.insertion``) and then one scatter per bucket level.  This kernel fuses
the whole write phase: one grid step per block tile computes the per-block
offsets on the VPU (``cumsum``), resolves the dense insert permutation with an
exact int32 one-hot reduction (the ``dispatch_mxu`` idiom — no float
accumulation, so results are bit-identical to the jnp oracle), and writes
every bucket level in the same pass.

The scatter is expressed as a *gather* per level — output slot ``start_b + j``
takes wave element ``sel[start_b + j − size_row]`` when that offset is live —
because TPU Pallas has no dynamic scatter primitive; a shifted-window gather
over the (tiny) wave is the vectorizable formulation.  Bucket levels are
passed through ``input_output_aliases`` so untouched slots are never copied:
together with ``donate_argnums`` at the jit boundary this is what makes the
donated append O(wave) writes instead of O(capacity) copies.

VMEM note: like the flatten kernel, every bucket level's block-tile rows stay
resident per grid step (total = per-block capacity · tile rows), plus an
(m × m) one-hot for the permutation.  A production variant would keep levels
in HBM and DMA only those the wave's position interval [min sizes, max pos)
can touch; the index math is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import indexing

__all__ = ["push_back_pallas"]

DEFAULT_BLOCK_TILE = 8


def _push_back_kernel(mask_ref, elems_ref, sizes_ref, *refs, starts, bsizes):
    nlev = len(bsizes)
    level_in = refs[:nlev]
    level_out = refs[nlev : 2 * nlev]
    pos_ref = refs[2 * nlev]
    nsz_ref = refs[2 * nlev + 1]

    mask = mask_ref[...]  # (rows, m) int32 0/1
    elems = elems_ref[...]  # (rows, m)
    sizes = sizes_ref[...]  # (rows, 1) int32
    rows, m = mask.shape

    inc = jnp.cumsum(mask, axis=1)
    off = inc - mask  # exclusive prefix sum (the insertion offsets)
    count = inc[:, -1:]  # (rows, 1)
    pos = sizes + off  # absolute in-block positions

    # Dense insert permutation: sel[r, o] = the unique masked lane k with
    # off[r, k] == o.  Exact int32 one-hot reduction — value bits never touch
    # arithmetic, so the gather below is bit-identical to the jnp scatter.
    iota_o = jax.lax.broadcasted_iota(jnp.int32, (rows, m, m), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (rows, m, m), 2)
    onehot = (off[:, None, :] == iota_o) & (mask[:, None, :] > 0)
    sel = jnp.sum(jnp.where(onehot, iota_k, 0), axis=2)  # (rows, m)
    gathered = jnp.take_along_axis(elems, sel, axis=1)  # wave in offset order

    for b in range(nlev):
        j = jax.lax.broadcasted_iota(jnp.int32, (rows, bsizes[b]), 1)
        o = starts[b] + j - sizes  # wave offset landing at this slot
        valid = (o >= 0) & (o < count)
        oc = jnp.clip(o, 0, m - 1)
        vals = jnp.take_along_axis(gathered, oc, axis=1)
        level_out[b][...] = jnp.where(valid, vals, level_in[b][...])

    pos_ref[...] = jnp.where(mask > 0, pos, -1)
    nsz_ref[...] = sizes + count


def push_back_pallas(
    buckets: tuple[jax.Array, ...],  # level b: (nblocks, B0·2^b)
    sizes: jax.Array,  # (nblocks, 1) int32
    b0: int,
    elems: jax.Array,  # (nblocks, m)
    mask: jax.Array,  # (nblocks, m) int32 0/1
    *,
    block_tile: int = DEFAULT_BLOCK_TILE,
    interpret: bool = False,
) -> tuple[tuple[jax.Array, ...], jax.Array, jax.Array]:
    """→ (new levels, positions (−1 where masked), new sizes (nblocks, 1))."""
    nblocks, m = elems.shape
    if nblocks % block_tile:
        raise ValueError(f"nblocks {nblocks} must divide by tile {block_tile}")
    nlev = len(buckets)
    starts = indexing.bucket_starts(b0, nlev)
    bsizes = indexing.bucket_sizes(b0, nlev)
    kernel = functools.partial(_push_back_kernel, starts=starts, bsizes=bsizes)
    row_spec = lambda width: pl.BlockSpec((block_tile, width), lambda i: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid=(nblocks // block_tile,),
        in_specs=[row_spec(m), row_spec(m), row_spec(1)]
        + [row_spec(sz) for sz in bsizes],
        out_specs=[row_spec(sz) for sz in bsizes] + [row_spec(m), row_spec(1)],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, sz), buckets[0].dtype) for sz in bsizes
        ]
        + [
            jax.ShapeDtypeStruct((nblocks, m), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
        ],
        # level inputs alias their outputs: untouched slots are never copied.
        input_output_aliases={3 + b: b for b in range(nlev)},
        interpret=interpret,
    )(mask, elems, sizes, *buckets)
    return tuple(outs[:nlev]), outs[nlev], outs[nlev + 1]
