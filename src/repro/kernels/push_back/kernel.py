"""Fused push-back kernel — offsets + multi-level scatter in one tiled pass.

The jnp append path is two dispatches: an exclusive prefix sum of the mask
(``core.insertion``) and then one scatter per bucket level.  This kernel fuses
the whole write phase: one grid step per block tile computes the per-block
offsets on the VPU (``cumsum``), resolves the dense insert permutation
(:func:`apply_insert_permutation` — exact int32 one-hot reduction, or the
``kernels/dispatch_mxu`` matmul for waves at least ``common.MXU_DISPATCH_WAVE``
lanes wide), and writes every bucket level in the same pass.

The scatter is expressed as a *gather* per level — output slot ``start_b + j``
takes wave element ``sel[start_b + j − size_row]`` when that offset is live —
because TPU Pallas has no dynamic scatter primitive; a shifted-window gather
over the (tiny) wave is the vectorizable formulation.  Bucket levels are
passed through ``input_output_aliases`` so untouched slots are never copied:
together with ``donate_argnums`` at the jit boundary this is what makes the
donated append O(wave) writes instead of O(capacity) copies.

Items are carried as one trailing feature axis ``D`` (non-scalar payloads are
flattened by ``ops``): every ref is ``(rows, width, D)`` with the permutation
computed on the 2-D ``(rows, m)`` mask and broadcast over ``D`` — this is the
3-D variant the KV-cache decode path needs ((heads, dim) items; was a jnp
fallback before).

The kernel takes ``ngroups`` independent payload *groups* sharing one mask
and size vector (each group has its own bucket tuple, feature width, and
dtype): the offsets and the one-hot permutation — the expensive part of a
tiny wave — are computed **once** and reused for every group's scatter.
This is what lets the quantized KV-cache decode write k/v/ks/vs in a single
launch instead of four.

Memory spaces (``common.GridPlan``, DESIGN.md §4.7): the ``vmem`` tiling
keeps every level's block-tile rows resident per grid step (total =
per-block capacity · tile rows).  The ``hbm`` tiling leaves the levels in
HBM (``pltpu.ANY``, aliased in place): a scalar-prefetched *touch table* —
level ``b`` is touched by a tile iff some row's write interval
``[size, size+count)`` meets ``[start_b, start_b+width_b)`` — gates explicit
DMAs that stream exactly the touched level tiles through **two**
largest-level-sized scratch slots, double-buffered: level ``b+1``'s inbound
copy is started before level ``b``'s is awaited, so the next level's DMA
overlaps the current level's scatter + write-back.  Per-step VMEM is two
level tiles plus the wave, never the whole chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import indexing
from repro.kernels import common
from repro.kernels.dispatch_mxu import kernel as dispatch_kernel
from repro.obs import device

__all__ = ["push_back_pallas", "apply_insert_permutation"]

DEFAULT_BLOCK_TILE = 8


def _ctr_pairs(mask, sizes, count, starts, bsizes):
    """Device-counter contributions of one grid step (DESIGN.md §9.x).

    ``level_writes`` is the true scatter volume: per row, the write interval
    ``[size, size+count)`` clipped to each level's ``[start, start+width)``
    — levels the interval misses contribute zero, so the sum equals the
    bucket slots actually written (both memory spaces, touched or not).
    """
    rows, m = mask.shape
    writes = jnp.zeros((), jnp.int32)
    for b in range(len(bsizes)):
        lo = jnp.maximum(sizes[:, 0], starts[b])
        hi = jnp.minimum(sizes[:, 0] + count[:, 0], starts[b] + bsizes[b])
        writes = writes + jnp.sum(jnp.maximum(hi - lo, 0))
    first = pl.program_id(0) == 0
    return first, [
        ("push_back.waves", jnp.where(first, 1, 0)),  # 1 per launch
        ("push_back.lanes", rows * m),
        ("push_back.active_lanes", jnp.sum(mask)),
        ("push_back.level_writes", writes),
    ]


def apply_insert_permutation(
    off: jax.Array,  # (rows, m) exclusive prefix sums of the mask
    mask: jax.Array,  # (rows, m) int32 0/1
    elems: jax.Array,  # (rows, m, D)
    dispatch: str,
) -> jax.Array:
    """Dense insert permutation: out[r, o] = elems[r, k] for the unique masked
    lane ``k`` with ``off[r, k] == o``.

    ``dispatch="onehot"``: exact int32 one-hot reduction + gather — value
    bits never touch arithmetic, bit-identical to the jnp scatter for every
    dtype.  ``dispatch="mxu"``: the one-hot becomes a dispatch matmul
    (``kernels/dispatch_mxu.permute_rows``) — the MXU path for wide waves,
    bit-exact for f32-representable payloads.  Slots past the row's lane
    count differ between the two (lane 0's value vs 0) but are dead under
    every caller's ``o < count`` write guard.
    """
    rows, m = mask.shape
    iota_o = jax.lax.broadcasted_iota(jnp.int32, (rows, m, m), 1)
    onehot = (off[:, None, :] == iota_o) & (mask[:, None, :] > 0)
    if dispatch == "mxu":
        return dispatch_kernel.permute_rows(onehot, elems)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (rows, m, m), 2)
    sel = jnp.sum(jnp.where(onehot, iota_k, 0), axis=2)  # (rows, m)
    return jnp.take_along_axis(elems, sel[:, :, None], axis=1)


def _level_window(gathered, sizes, count, level_tile, start, width, m):
    """One level's shifted-window gather — shared by both memory spaces."""
    rows = sizes.shape[0]
    j = jax.lax.broadcasted_iota(jnp.int32, (rows, width), 1)
    o = start + j - sizes  # wave offset landing at this slot
    valid = (o >= 0) & (o < count)
    oc = jnp.clip(o, 0, m - 1)
    vals = jnp.take_along_axis(gathered, oc[:, :, None], axis=1)
    return jnp.where(valid[:, :, None], vals, level_tile)


def _push_back_vmem(
    mask_ref, sizes_ref, *refs, starts, bsizes, ngroups, dispatches,
    instrument=False,
):
    nlev = len(bsizes)
    elems_refs = refs[:ngroups]
    level_in = refs[ngroups : ngroups + ngroups * nlev]  # group-major
    level_out = refs[ngroups + ngroups * nlev : ngroups + 2 * ngroups * nlev]
    nout = ngroups + 2 * ngroups * nlev
    pos_ref = refs[nout]
    nsz_ref = refs[nout + 1]

    mask = mask_ref[...]  # (rows, m) int32 0/1
    sizes = sizes_ref[...]  # (rows, 1) int32
    rows, m = mask.shape

    inc = jnp.cumsum(mask, axis=1)
    off = inc - mask  # exclusive prefix sum (the insertion offsets)
    count = inc[:, -1:]  # (rows, 1)
    pos = sizes + off  # absolute in-block positions

    for g in range(ngroups):
        # permutation resolved ONCE per group, reused by every level's scatter
        gathered = apply_insert_permutation(
            off, mask, elems_refs[g][...], dispatches[g]
        )
        for b in range(nlev):
            level_out[g * nlev + b][...] = _level_window(
                gathered, sizes, count, level_in[g * nlev + b][...],
                starts[b], bsizes[b], m,
            )

    pos_ref[...] = jnp.where(mask > 0, pos, -1)
    nsz_ref[...] = sizes + count
    if instrument:
        first, pairs = _ctr_pairs(mask, sizes, count, starts, bsizes)
        device.ctr_accum(refs[nout + 2], first, pairs)


def _push_back_hbm(
    touch_ref, mask_ref, sizes_ref, *refs, starts, bsizes, ngroups, dispatches,
    instrument=False,
):
    nlev = len(bsizes)
    elems_refs = refs[:ngroups]
    # level inputs are aliased to the outputs — one HBM buffer; use the outs
    level_out = refs[ngroups + ngroups * nlev : ngroups + 2 * ngroups * nlev]
    nout = ngroups + 2 * ngroups * nlev
    pos_ref = refs[nout]
    nsz_ref = refs[nout + 1]
    scratch = refs[-ngroups - 2 : -2]  # per group: (2, rows, max_width, d)
    sem_in, sem_out = refs[-2], refs[-1]  # (ngroups, 2) DMA semaphores

    i = pl.program_id(0)
    mask = mask_ref[...]
    sizes = sizes_ref[...]
    rows, m = mask.shape

    inc = jnp.cumsum(mask, axis=1)
    off = inc - mask
    count = inc[:, -1:]
    pos = sizes + off

    gathered = [
        apply_insert_permutation(off, mask, elems_refs[g][...], dispatches[g])
        for g in range(ngroups)
    ]

    # Levels are double-buffered through two scratch slots (slot = b % 2):
    # level b+1's DMA-in is started *before* waiting on level b's, so the
    # inbound stream of the next touched level overlaps the current level's
    # scatter + write-back.  Semaphores are per (group, slot) so in-flight
    # copies of adjacent levels never alias a wait.
    def _copies(b, inbound):
        width = bsizes[b]
        slot = b % 2
        out = []
        for g in range(ngroups):
            rows_hbm = level_out[g * nlev + b].at[pl.ds(i * rows, rows)]
            tile = scratch[g].at[slot, :, pl.ds(0, width)]
            sem = (sem_in if inbound else sem_out).at[g, slot]
            src, dst = (rows_hbm, tile) if inbound else (tile, rows_hbm)
            out.append(pltpu.make_async_copy(src, dst, sem))
        return out

    def start_in(b):
        @pl.when(touch_ref[i, b] > 0)
        def _(b=b):
            for cp in _copies(b, inbound=True):
                cp.start()

    def finish_level(b):
        """Wait level ``b``'s tiles in, scatter, start the write-back."""

        @pl.when(touch_ref[i, b] > 0)
        def _(b=b):
            slot, width = b % 2, bsizes[b]
            for cp in _copies(b, inbound=True):
                cp.wait()
            for g in range(ngroups):
                scratch[g][slot, :, :width] = _level_window(
                    gathered[g], sizes, count, scratch[g][slot, :, :width],
                    starts[b], width, m,
                )
            for cp in _copies(b, inbound=False):
                cp.start()

    def drain_out(b):
        @pl.when(touch_ref[i, b] > 0)
        def _(b=b):
            for cp in _copies(b, inbound=False):
                cp.wait()

    for b in range(nlev):
        if b >= 2:
            drain_out(b - 2)  # slot b%2 must be clear before reuse
        start_in(b)
        if b >= 1:
            finish_level(b - 1)
    finish_level(nlev - 1)
    if nlev >= 2:
        drain_out(nlev - 2)
    drain_out(nlev - 1)

    pos_ref[...] = jnp.where(mask > 0, pos, -1)
    nsz_ref[...] = sizes + count
    if instrument:
        first, pairs = _ctr_pairs(mask, sizes, count, starts, bsizes)
        device.ctr_accum(refs[nout + 2], first, pairs)


def push_back_pallas(
    bucket_groups: tuple[tuple[jax.Array, ...], ...],  # per group, level b: (nblocks, B0·2^b, D_g)
    sizes: jax.Array,  # (nblocks, 1) int32
    b0: int,
    elem_groups: tuple[jax.Array, ...],  # per group: (nblocks, m, D_g)
    mask: jax.Array,  # (nblocks, m) int32 0/1
    *,
    block_tile: int = DEFAULT_BLOCK_TILE,
    memory_space: str = "vmem",
    dispatches: tuple[str, ...] | None = None,
    touch: jax.Array | None = None,  # (ntiles, nlev) int32 — hbm level gating
    instrument: bool = False,
    interpret: bool = False,
) -> tuple:
    """→ (new level groups, positions (−1 where masked), new sizes (nblocks, 1)).

    With ``instrument=True`` the tuple gains a trailing (8, 128) int32
    counter block (``obs/device`` layout) accumulated in-kernel.
    """
    ngroups = len(elem_groups)
    nblocks, m, _ = elem_groups[0].shape
    if nblocks % block_tile:
        raise ValueError(f"nblocks {nblocks} must divide by tile {block_tile}")
    nlev = len(bucket_groups[0])
    starts = indexing.bucket_starts(b0, nlev)
    bsizes = indexing.bucket_sizes(b0, nlev)
    if dispatches is None:
        dispatches = ("onehot",) * ngroups
    dims = [e.shape[2] for e in elem_groups]
    row_spec = lambda width: pl.BlockSpec((block_tile, width), lambda i: (i, 0))
    item_spec = lambda width, d: pl.BlockSpec(
        (block_tile, width, d), lambda i: (i, 0, 0)
    )
    level_shapes = [
        jax.ShapeDtypeStruct((nblocks, sz, d), grp[0].dtype)
        for grp, d in zip(bucket_groups, dims)
        for sz in bsizes
    ]
    out_shape = level_shapes + [
        jax.ShapeDtypeStruct((nblocks, m), jnp.int32),
        jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
    ]
    nl = ngroups * nlev
    # level inputs alias their outputs: untouched slots are never copied.
    aliases = {2 + ngroups + i: i for i in range(nl)}
    if memory_space == "hbm":
        if touch is None:
            raise ValueError("hbm push_back needs the level-touch table")
        any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        plan = common.GridPlan(
            memory_space="hbm",
            grid=(nblocks // block_tile,),
            num_tables=1,
            table_specs=(),
            in_specs=[
                pl.BlockSpec((block_tile, m), lambda i, touch: (i, 0)),
                pl.BlockSpec((block_tile, 1), lambda i, touch: (i, 0)),
            ]
            + [
                pl.BlockSpec((block_tile, m, d), lambda i, touch: (i, 0, 0))
                for d in dims
            ]
            + [any_spec] * nl,
            out_specs=[any_spec] * nl
            + [
                pl.BlockSpec((block_tile, m), lambda i, touch: (i, 0)),
                pl.BlockSpec((block_tile, 1), lambda i, touch: (i, 0)),
            ],
            scratch_shapes=[
                # two slots per group — level b+1 streams into slot (b+1)%2
                # while level b is scattered/written back from slot b%2
                pltpu.VMEM((2, block_tile, bsizes[-1], d), grp[0].dtype)
                for grp, d in zip(bucket_groups, dims)
            ]
            + [
                pltpu.SemaphoreType.DMA((ngroups, 2)),
                pltpu.SemaphoreType.DMA((ngroups, 2)),
            ],
            aliases=aliases,
            instrument=instrument,
        )
        kernel = functools.partial(
            _push_back_hbm,
            starts=starts, bsizes=bsizes, ngroups=ngroups, dispatches=dispatches,
            instrument=instrument,
        )
        outs = plan.pallas_call(kernel, out_shape, interpret=interpret)(
            touch, mask, sizes, *elem_groups,
            *(lvl for grp in bucket_groups for lvl in grp),
        )
    else:
        level_specs = [item_spec(sz, d) for d in dims for sz in bsizes]
        plan = common.GridPlan(
            memory_space="vmem",
            grid=(nblocks // block_tile,),
            num_tables=0,
            table_specs=(),
            in_specs=[row_spec(m), row_spec(1)]
            + [item_spec(m, d) for d in dims]
            + level_specs,
            out_specs=level_specs + [row_spec(m), row_spec(1)],
            aliases=aliases,
            instrument=instrument,
        )
        kernel = functools.partial(
            _push_back_vmem,
            starts=starts, bsizes=bsizes, ngroups=ngroups, dispatches=dispatches,
            instrument=instrument,
        )
        outs = plan.pallas_call(kernel, out_shape, interpret=interpret)(
            mask, sizes, *elem_groups,
            *(lvl for grp in bucket_groups for lvl in grp),
        )
    groups = tuple(
        tuple(outs[g * nlev : (g + 1) * nlev]) for g in range(ngroups)
    )
    if instrument:
        return groups, outs[nl], outs[nl + 1], outs[nl + 2]
    return groups, outs[nl], outs[nl + 1]
