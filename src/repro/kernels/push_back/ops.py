"""jit'd fused push-back: padding/dispatch around the Pallas kernel.

``push_back_fused`` is the ``method="fused"`` backend of
``core.ggarray.push_back``/``append``: per-block prefix-sum offsets and the
scatter into every bucket level fused into one tiled pass.  The jnp
scan-then-scatter path (also reachable as ``use_ref=True``) is the
correctness oracle — results are bit-identical across the round-trip test
matrix (``tests/kernels/test_push_back.py``) in **both** memory spaces.

Non-scalar items are supported by flattening ``item_shape`` into one trailing
feature axis around the 3-D kernel.  ``push_back_fused_multi`` scatters
several payload *groups* (own buckets / feature width / dtype each) that
share one mask and size vector in a single launch, computing the offsets and
the insert permutation once — the KV-cache decode path writes k/v (and the
int8 quant scales) this way (``serving/kvcache.py::append``).

``memory_space`` selects the kernel tiling (``common.resolve_memory_space``:
explicit > ``REPRO_MEMORY_SPACE`` > hbm on TPU / vmem in interpret mode).
The hbm tiling additionally takes a *level-touch table* computed here — per
block tile and level, whether any row's write interval ``[size, size+count)``
meets the level — which is what lets the kernel DMA only the touched level
tiles out of HBM.  ``dispatch`` selects the insert-permutation backend per
payload group (``common.resolve_dispatch``: ``"auto"`` routes waves at least
``MXU_DISPATCH_WAVE`` lanes wide through the MXU dispatch matmul).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import indexing
from repro.kernels import common
from repro.kernels.push_back import kernel as _kernel
from repro.kernels.push_back import ref as _ref
from repro.obs import device

__all__ = ["push_back_fused", "push_back_fused_multi"]


def _oracle_counters(mask, sizes, b0, nlev, nblocks, m):
    """jnp device counters matching the in-kernel block's accounting: the
    same padded-wave geometry the fused kernel runs, so the use_ref path
    reports identical numbers (cross-checked in tests)."""
    tile = _kernel.DEFAULT_BLOCK_TILE
    rows_pad = nblocks + (-nblocks) % tile
    m_pad = m + (-m) % common.MXU_LANE
    starts = jnp.asarray(indexing.bucket_starts(b0, nlev), jnp.int32)
    widths = jnp.asarray(indexing.bucket_sizes(b0, nlev), jnp.int32)
    mask_i = mask.astype(jnp.int32)
    count = jnp.sum(mask_i, axis=1)
    lo = jnp.maximum(sizes.astype(jnp.int32)[:, None], starts[None, :])
    hi = jnp.minimum(
        (sizes.astype(jnp.int32) + count)[:, None], (starts + widths)[None, :]
    )
    writes = jnp.sum(jnp.maximum(hi - lo, 0))
    return device.pack(**{
        "push_back.waves": 1,
        "push_back.lanes": rows_pad * m_pad,
        "push_back.active_lanes": jnp.sum(mask_i),
        "push_back.padded_lanes": rows_pad * m_pad - nblocks * m,
        "push_back.level_writes": writes,
    })


def _level_touch(
    sizes: jax.Array, mask_i: jax.Array, b0: int, nlev: int, block_tile: int
) -> jax.Array:
    """→ (ntiles, nlev) int32: does any row in the tile write into level b?"""
    starts = jnp.asarray(indexing.bucket_starts(b0, nlev), jnp.int32)
    ends = starts + jnp.asarray(indexing.bucket_sizes(b0, nlev), jnp.int32)
    lo = sizes.astype(jnp.int32)  # (nblocks,)
    hi = lo + jnp.sum(mask_i, axis=1, dtype=jnp.int32)
    row = (hi[:, None] > starts[None, :]) & (lo[:, None] < ends[None, :])
    return (
        row.reshape(-1, block_tile, nlev).any(axis=1).astype(jnp.int32)
    )


@partial(
    jax.jit,
    static_argnames=(
        "b0", "interpret", "use_ref", "memory_space", "dispatch", "instrument",
    ),
)
def push_back_fused_multi(
    bucket_groups: tuple[tuple[jax.Array, ...], ...],
    sizes: jax.Array,  # (nblocks,) int32
    b0: int,
    elem_groups: tuple[jax.Array, ...],  # per group: (nblocks, m, *item_g)
    mask: jax.Array,  # (nblocks, m) bool or 0/1 integers
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
    dispatch: str = "auto",
    instrument: bool = False,
) -> tuple:
    """→ (new bucket groups, new sizes (nblocks,), positions (−1 masked)).

    ``instrument=True`` appends a device counter vector (``obs/device``
    layout): in-kernel counts on the fused path (plus the statically known
    padding waste), a matching jnp oracle on ``use_ref``/degenerate paths.
    """
    if mask.dtype != jnp.bool_:
        mask = mask != 0
    nblocks, m = elem_groups[0].shape[:2]
    nlev = len(bucket_groups[0])
    if m == 0:
        pos0 = jnp.zeros((nblocks, 0), jnp.int32)
        if instrument:
            return bucket_groups, sizes, pos0, device.zeros()
        return bucket_groups, sizes, pos0
    if use_ref:  # per-group oracle: positions/sizes are mask-only, identical
        groups, new_sizes, pos = [], None, None
        for buckets, elems in zip(bucket_groups, elem_groups):
            levels, new_sizes, pos = _ref.push_back(buckets, sizes, b0, elems, mask)
            groups.append(levels)
        if instrument:
            vec = _oracle_counters(mask, sizes, b0, nlev, nblocks, m)
            return tuple(groups), new_sizes, pos, vec
        return tuple(groups), new_sizes, pos

    space = common.resolve_memory_space(memory_space, interpret)
    item_shapes = [e.shape[2:] for e in elem_groups]
    dispatches = tuple(
        common.resolve_dispatch(dispatch, m, e.dtype) for e in elem_groups
    )

    def flat(x, item):
        d = 1
        for dim in item:
            d *= dim
        return x.reshape(*x.shape[: x.ndim - len(item)], d)

    tile = _kernel.DEFAULT_BLOCK_TILE
    row_pad = (-nblocks) % tile
    buckets3 = [
        tuple(flat(b, item) for b in grp)
        for grp, item in zip(bucket_groups, item_shapes)
    ]
    elems3 = [flat(e, item) for e, item in zip(elem_groups, item_shapes)]
    if row_pad:  # padded rows: mask all-False, sizes 0 — provably inert
        buckets3 = [
            tuple(common.pad_to(b, tile, axis=0) for b in grp) for grp in buckets3
        ]
        elems3 = [common.pad_to(e, tile, axis=0) for e in elems3]
        mask = common.pad_to(mask, tile, axis=0)
        sizes = common.pad_to(sizes, tile, axis=0)
    elems3 = [common.pad_to(e, common.MXU_LANE, axis=1) for e in elems3]
    mask = common.pad_to(mask, common.MXU_LANE, axis=1)

    touch = (
        _level_touch(sizes, mask.astype(jnp.int32), b0, nlev, tile)
        if space == "hbm"
        else None
    )
    outs = _kernel.push_back_pallas(
        tuple(buckets3),
        sizes.reshape(-1, 1).astype(jnp.int32),
        b0,
        tuple(elems3),
        mask.astype(jnp.int32),
        memory_space=space,
        dispatches=dispatches,
        touch=touch,
        instrument=instrument,
        interpret=common.should_interpret(interpret),
    )
    groups, pos, new_sizes = outs[:3]
    out_groups = tuple(
        tuple(
            lvl[:nblocks].reshape(nblocks, lvl.shape[1], *item)
            for lvl in grp
        )
        for grp, item in zip(groups, item_shapes)
    )
    if instrument:
        # tile/MXU padding waste is statically known here, not in-kernel
        pad_waste = mask.shape[0] * mask.shape[1] - nblocks * m
        vec = device.from_block(outs[3]) + device.pack(
            **{"push_back.padded_lanes": pad_waste}
        )
        return out_groups, new_sizes[:nblocks, 0], pos[:nblocks, :m], vec
    return out_groups, new_sizes[:nblocks, 0], pos[:nblocks, :m]


@partial(
    jax.jit,
    static_argnames=(
        "b0", "interpret", "use_ref", "memory_space", "dispatch", "instrument",
    ),
)
def push_back_fused(
    buckets: tuple[jax.Array, ...],
    sizes: jax.Array,  # (nblocks,) int32
    b0: int,
    elems: jax.Array,  # (nblocks, m, *item_shape)
    mask: jax.Array,  # (nblocks, m) bool or 0/1 integers
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
    dispatch: str = "auto",
    instrument: bool = False,
) -> tuple:
    """→ (new bucket levels, new sizes (nblocks,), positions (−1 masked));
    with ``instrument=True`` a trailing device counter vector rides along."""
    outs = push_back_fused_multi(
        (buckets,), sizes, b0, (elems,), mask,
        interpret=interpret, use_ref=use_ref,
        memory_space=memory_space, dispatch=dispatch, instrument=instrument,
    )
    if instrument:
        groups, new_sizes, pos, vec = outs
        return groups[0], new_sizes, pos, vec
    groups, new_sizes, pos = outs
    return groups[0], new_sizes, pos
