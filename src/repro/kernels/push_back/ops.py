"""jit'd fused push-back: padding/dispatch around the Pallas kernel.

``push_back_fused`` is the ``method="fused"`` backend of
``core.ggarray.push_back``/``append``: per-block prefix-sum offsets and the
scatter into every bucket level fused into one tiled pass.  The jnp
scan-then-scatter path (also reachable as ``use_ref=True``) is the
correctness oracle — results are bit-identical across the round-trip test
matrix (``tests/kernels/test_push_back.py``).

Scalar items only (like the flatten kernels' 2-D coverage); callers fall back
to the jnp path for non-scalar ``item_shape``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.push_back import kernel as _kernel
from repro.kernels.push_back import ref as _ref

__all__ = ["push_back_fused"]


@partial(jax.jit, static_argnames=("b0", "interpret", "use_ref"))
def push_back_fused(
    buckets: tuple[jax.Array, ...],
    sizes: jax.Array,  # (nblocks,) int32
    b0: int,
    elems: jax.Array,  # (nblocks, m)
    mask: jax.Array,  # (nblocks, m) bool or 0/1 integers
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> tuple[tuple[jax.Array, ...], jax.Array, jax.Array]:
    """→ (new bucket levels, new sizes (nblocks,), positions (−1 masked))."""
    if mask.dtype != jnp.bool_:
        mask = mask != 0
    nblocks, m = elems.shape
    if m == 0:
        return buckets, sizes, jnp.zeros((nblocks, 0), jnp.int32)
    if use_ref:
        return _ref.push_back(buckets, sizes, b0, elems, mask)

    tile = _kernel.DEFAULT_BLOCK_TILE
    row_pad = (-nblocks) % tile
    if row_pad:  # padded rows: mask all-False, sizes 0 — provably inert
        buckets = tuple(common.pad_to(b, tile, axis=0) for b in buckets)
        elems = common.pad_to(elems, tile, axis=0)
        mask = common.pad_to(mask, tile, axis=0)
        sizes = common.pad_to(sizes, tile, axis=0)
    elems = common.pad_to(elems, common.MXU_LANE, axis=1)
    mask = common.pad_to(mask, common.MXU_LANE, axis=1)

    levels, pos, new_sizes = _kernel.push_back_pallas(
        buckets,
        sizes.reshape(-1, 1).astype(jnp.int32),
        b0,
        elems,
        mask.astype(jnp.int32),
        interpret=common.should_interpret(interpret),
    )
    return (
        tuple(lvl[:nblocks] for lvl in levels),
        new_sizes[:nblocks, 0],
        pos[:nblocks, :m],
    )
