"""Pure-jnp oracle for the fused push-back kernel.

Mirrors ``core.ggarray``'s scan-then-scatter path (``insertion_offsets``
followed by ``_scatter_positions``) on raw bucket tuples, so the kernel can
be checked bit-exactly without constructing a ``GGArray``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import indexing

__all__ = ["push_back"]


def push_back(
    buckets: tuple[jax.Array, ...],  # level b: (nblocks, B0·2^b, *item)
    sizes: jax.Array,  # (nblocks,) int32
    b0: int,
    elems: jax.Array,  # (nblocks, m, *item)
    mask: jax.Array,  # (nblocks, m) bool
) -> tuple[tuple[jax.Array, ...], jax.Array, jax.Array]:
    """→ (new bucket levels, new sizes, positions (−1 where masked out))."""
    mask_i = mask.astype(jnp.int32)
    inclusive = jnp.cumsum(mask_i, axis=-1)
    offsets = inclusive - mask_i
    counts = inclusive[:, -1]
    pos = sizes[:, None] + offsets

    nbuckets = len(buckets)
    starts = indexing.bucket_starts(b0, nbuckets)
    bsizes = indexing.bucket_sizes(b0, nbuckets)
    rows = jnp.arange(pos.shape[0], dtype=jnp.int32)[:, None]
    out = []
    for b in range(nbuckets):
        li = pos - starts[b]
        in_level = mask & (li >= 0) & (li < bsizes[b])
        li = jnp.where(in_level, li, bsizes[b])
        out.append(buckets[b].at[rows, li].set(elems, mode="drop"))
    return tuple(out), sizes + counts, jnp.where(mask, pos, -1)
