"""MXU matmul prefix-sum kernel — the tensor-core scan (§III.B.3) on TPU.

Dakkak et al. (2019) phrase scan as matrix multiplication against triangular
one-matrices on 16×16 tensor-core fragments.  The TPU MXU is a 128×128
systolic array, so the construction re-blocks to 128-wide lanes:

for each (row_tile, col_tile) VMEM block ``X`` of shape (R, 128):

    Y = X · U            # U upper-triangular ones → per-row inclusive scan
    out = Y + carry      # carry = running row totals of previous col tiles
    carry += Y[:, -1:]   # tile totals ride the sequential TPU grid

TPU grid steps execute **in order**, so the inter-tile carry lives in a VMEM
scratch accumulator — no decoupled-lookback machinery (the GPU version's
inter-block coordination) is needed.  This is the hardware adaptation recorded
in DESIGN.md §2.

The matmul runs in f32: per-tile partial sums are ≤ 128·max|x| (exact in f32
for the insertion-mask use case where x ∈ {0,1}); the unbounded running carry
is accumulated in the *output dtype* (int32 for masks) to stay exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import MXU_LANE

__all__ = ["row_scan_pallas"]

DEFAULT_ROW_TILE = 8  # f32 VREG sublane count


def _scan_kernel(x_ref, o_ref, carry_ref, *, acc_dtype):
    """One (R, 128) tile: matmul scan + sequential-grid carry."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(jnp.float32)
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (MXU_LANE, MXU_LANE), 0)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (MXU_LANE, MXU_LANE), 1)
    upper = (iota_r <= iota_c).astype(jnp.float32)
    y = jnp.dot(x, upper, preferred_element_type=jnp.float32).astype(acc_dtype)
    o_ref[...] = y + carry_ref[...]
    carry_ref[...] += y[:, -1:]


def row_scan_pallas(
    x: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool = False,
) -> jax.Array:
    """Per-row inclusive prefix sum of ``x: (rows, cols)`` via MXU matmuls.

    ``rows`` must be a multiple of ``row_tile`` and ``cols`` of 128 (the
    ``ops.row_scan`` wrapper pads).  Output dtype == input dtype.
    """
    rows, cols = x.shape
    if rows % row_tile or cols % MXU_LANE:
        raise ValueError(f"unpadded shape {x.shape}; pad to ({row_tile}, {MXU_LANE})")
    acc_dtype = x.dtype
    kernel = functools.partial(_scan_kernel, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=(rows // row_tile, cols // MXU_LANE),
        in_specs=[pl.BlockSpec((row_tile, MXU_LANE), lambda r, c: (r, c))],
        out_specs=pl.BlockSpec((row_tile, MXU_LANE), lambda r, c: (r, c)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), acc_dtype),
        scratch_shapes=[pltpu.VMEM((row_tile, 1), acc_dtype)],
        interpret=interpret,
    )(x)
