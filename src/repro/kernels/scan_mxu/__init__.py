from repro.kernels.scan_mxu import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
