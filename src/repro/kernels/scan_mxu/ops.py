"""jit'd public wrapper for the MXU scan kernel (pads, dispatches, unpads)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.scan_mxu import kernel as _kernel
from repro.kernels.scan_mxu import ref as _ref

__all__ = ["row_scan"]


@partial(jax.jit, static_argnames=("interpret", "use_ref"))
def row_scan(
    x: jax.Array, *, interpret: bool | None = None, use_ref: bool = False
) -> jax.Array:
    """Inclusive per-row prefix sum of ``x: (rows, cols)``.

    Pads rows to the sublane tile and cols to 128 lanes, runs the Pallas MXU
    kernel (interpret mode off-TPU), slices the result back.
    """
    if x.ndim != 2:
        raise ValueError(f"expected (rows, cols), got {x.shape}")
    if use_ref:
        return _ref.row_scan(x)
    rows, cols = x.shape
    xp = common.pad_to(x, _kernel.DEFAULT_ROW_TILE, axis=0)
    xp = common.pad_to(xp, common.MXU_LANE, axis=1)
    out = _kernel.row_scan_pallas(xp, interpret=common.should_interpret(interpret))
    return out[:rows, :cols]
