"""Pure-jnp oracles for the MXU scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["row_scan", "row_scan_matmul"]


def row_scan(x: jax.Array) -> jax.Array:
    """Per-row inclusive prefix sum (ground truth for kernels/scan_mxu)."""
    return jnp.cumsum(x, axis=-1, dtype=x.dtype)


def row_scan_matmul(x: jax.Array, tile: int = 128) -> jax.Array:
    """The Dakkak matmul-scan *algorithm* in plain XLA ops.

    Same tiling/carry structure as the Pallas kernel — used as a second
    oracle and as the benchmarkable algorithm path on non-TPU backends
    (interpret-mode kernel timings are meaningless on CPU).
    """
    rows, cols = x.shape
    pad = (-cols) % tile
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    n_tiles = xp.shape[1] // tile
    upper = jnp.triu(jnp.ones((tile, tile), jnp.float32))
    xt = jnp.moveaxis(xp.reshape(rows, n_tiles, tile), 1, 0).astype(jnp.float32)

    def body(carry, xtile):  # xtile: (rows, tile)
        y = (xtile @ upper).astype(x.dtype) + carry
        return y[:, -1:], y

    _, out = jax.lax.scan(body, jnp.zeros((rows, 1), x.dtype), xt)
    out = jnp.moveaxis(out, 0, 1).reshape(rows, n_tiles * tile)
    return out[:, :cols]
