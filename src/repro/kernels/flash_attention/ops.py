"""jit'd wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import common
from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref

__all__ = ["flash_attention"]


@partial(jax.jit, static_argnames=("group", "causal", "interpret", "use_ref", "bq", "bk"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    group: int = 1,
    causal: bool = True,
    interpret: bool | None = None,
    use_ref: bool = False,
    bq: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """Softmax attention over (BH, S, D) tensors; GQA via ``group``."""
    if use_ref:
        return _ref.attention(q, k, v, group=group, causal=causal)
    Sq, Skv = q.shape[1], k.shape[1]
    bq = min(_kernel.DEFAULT_BQ, Sq) if bq is None else bq
    bk = min(_kernel.DEFAULT_BK, Skv) if bk is None else bk
    return _kernel.flash_attention_pallas(
        q, k, v,
        group=group, causal=causal, bq=bq, bk=bk,
        interpret=common.should_interpret(interpret),
    )
