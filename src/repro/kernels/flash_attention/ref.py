"""Pure-jnp oracle: exact softmax attention with GQA + causal masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention"]


def attention(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH_kv, Skv, D)
    v: jax.Array,
    *,
    group: int = 1,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jax.Array:
    BH, Sq, D = q.shape
    sm_scale = D ** -0.5 if sm_scale is None else sm_scale
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * sm_scale
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)).astype(q.dtype)
