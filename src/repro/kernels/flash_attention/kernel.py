"""Flash attention (prefill) — Pallas TPU kernel with online softmax.

Grid ``(batch·q_heads, Sq/BQ, Skv/BK)``; the trailing KV axis is sequential on
TPU, so the running max/denominator/accumulator live in VMEM scratch across KV
steps.  GQA is handled in the BlockSpec index maps (query head ``h`` reads KV
head ``h // group``) — no K/V repetition in HBM.  Causal masking skips fully
masked KV blocks via ``pl.when`` (upper-triangular blocks cost no MXU work).

This is the TPU hot path; the framework's dry-run/compile path uses the
pure-JAX blockwise implementation in models/attention.py (same math, same
oracle in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

DEFAULT_BQ = 256
DEFAULT_BK = 256
MASK_VALUE = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, sm_scale, causal, bq, bk, n_kv_blocks):
    qb, kb = pl.program_id(1), pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: KV block strictly above the diagonal touches nothing.
    needed = (not causal) or (kb * bk <= qb * bq + bq - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0].astype(jnp.float32)  # (BK, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        if causal:
            qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, MASK_VALUE)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)  # guard fully-masked rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH_kv, Skv, D)
    v: jax.Array,  # (BH_kv, Skv, D)
    *,
    group: int = 1,  # q heads per kv head (GQA)
    causal: bool = True,
    sm_scale: float | None = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    if Sq % bq or Skv % bk:
        raise ValueError(f"unpadded seq: Sq={Sq} Skv={Skv}; pad to ({bq},{bk})")
    sm_scale = D ** -0.5 if sm_scale is None else sm_scale
    n_kv_blocks = Skv // bk
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        causal=causal,
        bq=bq,
        bk=bk,
        n_kv_blocks=n_kv_blocks,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // bq, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qb, kb: (h, qb, 0)),
            pl.BlockSpec((1, bk, D), lambda h, qb, kb, g=group: (h // g, kb, 0)),
            pl.BlockSpec((1, bk, D), lambda h, qb, kb, g=group: (h // g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qb, kb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
