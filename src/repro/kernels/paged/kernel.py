"""Paged Pallas kernels — read/write a slab pool through page tables.

Three kernels back the arena subsystem (``repro.pool``, DESIGN.md §4), each
built on the shared :class:`repro.kernels.common.GridPlan` memory-space layer
(two tilings per kernel, one index math — DESIGN.md §4.7):

``paged_gather_pallas``
    Materialize each logical array's contiguous view by walking its page
    table — the indirection-table read the arena's flatten path uses.  vmem:
    one grid step per row tile against the resident pool.  hbm: grid
    ``(narrays, pages)`` with the page table scalar-prefetched; the pool
    ``index_map`` reads ``pages[n, p]`` so each grid step DMAs exactly the
    one slab tile it emits.

``paged_attend_pallas``
    Flash-decode attention against paged K/V pools: grid ``(batch, kv_heads,
    pages)`` with the online-softmax state in VMEM scratch (the
    ``kernels/decode_attention`` structure), the per-step KV tile selected by
    the page table.  Pages past the live length — GGArray tail slabs — are
    skipped entirely.  hbm: lengths and pages are scalar-prefetched and the
    K/V ``index_map`` DMAs one ``(slab_tokens, head_dim)`` tile per step
    instead of holding the pools resident.

``slab_append_pallas``
    The push_back prefix-sum machinery (exclusive mask scan + insert
    permutation, see ``kernels/push_back``) retargeted at the pool: each grid
    step resolves its slab's wave elements through the slab's *owner* row,
    and the pool aliases its output so untouched slabs are never copied.
    hbm: one slab per grid step, with the owner/base/size tables
    scalar-prefetched — the owner table drives the wave-row ``index_map``, so
    only the owning array's wave lane block is DMA'd alongside the slab tile.
    Waves at least ``common.MXU_DISPATCH_WAVE`` lanes wide apply the insert
    permutation as an MXU dispatch matmul (``kernels/dispatch_mxu``) instead
    of the exact int32 one-hot reduction — bit-exact for f32-representable
    payloads (``common.resolve_dispatch``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.paged.ref import MASK_VALUE
from repro.kernels.push_back.kernel import apply_insert_permutation
from repro.obs import device

__all__ = [
    "paged_gather_pallas",
    "paged_gather_pallas_extents",
    "paged_attend_pallas",
    "paged_attend_pallas_extents",
    "slab_append_pallas",
    "DEFAULT_ROW_TILE",
]

DEFAULT_ROW_TILE = 8


def _attend_ctr(ctr_ref, slab_live, kv_len, p, slab_tokens):
    """Accumulate one attend grid step's device counters (§9.x).

    ``visit`` mirrors the body's compute gate exactly — live slab id AND
    page start inside the KV length; ``masked_lanes`` counts score lanes in
    *visited* tiles that the causal-length mask then discards (the tail
    waste of token-granularity slabs).
    """
    visit = jnp.where(slab_live & (p * slab_tokens < kv_len), 1, 0)
    masked = visit * (
        slab_tokens - jnp.clip(kv_len - p * slab_tokens, 0, slab_tokens)
    )
    first = (pl.program_id(0) == 0) & (pl.program_id(1) == 0) & (p == 0)
    device.ctr_accum(ctr_ref, first, [
        ("paged_attend.launches", jnp.where(first, 1, 0)),
        ("paged_attend.tiles", visit),
        ("paged_attend.tiles_skipped", 1 - visit),
        ("paged_attend.lanes", visit * slab_tokens),
        ("paged_attend.masked_lanes", masked),
    ])


# --------------------------------------------------------------------------
# gather — logical contiguous view through the page table.
# --------------------------------------------------------------------------

def _gather_vmem(pages_ref, pool_ref, *refs, instrument=False):
    out_ref = refs[0]
    pages = pages_ref[...]  # (rows, P) int32
    pool = pool_ref[...]  # (S, T, D)
    rows, P = pages.shape
    S, T, D = pool.shape
    idx = jnp.clip(pages, 0, S - 1).reshape(rows * P)
    g = jnp.take(pool, idx, axis=0).reshape(rows, P, T, D)
    valid = (pages >= 0)[:, :, None, None]
    out_ref[...] = jnp.where(valid, g, 0).reshape(rows, P * T, D)
    if instrument:
        first = pl.program_id(0) == 0
        live = jnp.sum((pages >= 0).astype(jnp.int32))
        device.ctr_accum(refs[1], first, [
            ("paged_gather.launches", jnp.where(first, 1, 0)),
            ("paged_gather.tiles", live),
            ("paged_gather.masked_tiles", rows * P - live),
        ])


def _gather_hbm(pages_ref, pool_ref, *refs, instrument=False):
    out_ref = refs[0]
    n, p = pl.program_id(0), pl.program_id(1)
    slab = pages_ref[n, p]  # this step's one DMA'd tile is pool[slab]
    out_ref[...] = jnp.where(slab >= 0, pool_ref[...], 0)
    if instrument:
        first = (n == 0) & (p == 0)
        live = jnp.where(slab >= 0, 1, 0)
        device.ctr_accum(refs[1], first, [
            ("paged_gather.launches", jnp.where(first, 1, 0)),
            ("paged_gather.tiles", live),
            ("paged_gather.masked_tiles", 1 - live),
        ])


def paged_gather_pallas(
    pool: jax.Array,  # (S, T, D)
    pages: jax.Array,  # (N, P) int32
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    memory_space: str = "vmem",
    instrument: bool = False,
    interpret: bool = False,
):
    """→ (N, P·T, D) contiguous logical views (zeros under page −1).

    Any row count works: the vmem tiling pads ``N`` up to ``row_tile`` with
    page-table rows of −1 (provably inert — every lane reads as zero) and
    slices the result; the hbm tiling grids over rows directly.  With
    ``instrument=True`` → (out, counter block).
    """
    N, P = pages.shape
    S, T, D = pool.shape
    if memory_space == "hbm":
        plan = common.GridPlan(
            memory_space="hbm",
            grid=(N, P),
            num_tables=1,
            table_specs=(),
            in_specs=[
                pl.BlockSpec(
                    (1, T, D),
                    lambda n, p, pages: (jnp.clip(pages[n, p], 0, S - 1), 0, 0),
                )
            ],
            out_specs=pl.BlockSpec((1, T, D), lambda n, p, pages: (n, p, 0)),
            instrument=instrument,
        )
        outs = plan.pallas_call(
            functools.partial(_gather_hbm, instrument=instrument),
            jax.ShapeDtypeStruct((N, P * T, D), pool.dtype),
            interpret=interpret,
        )(pages, pool)
        if instrument:
            return outs[0], outs[1]
        return outs
    pages_p = common.pad_to(pages, row_tile, axis=0, value=-1)
    Np = pages_p.shape[0]
    plan = common.GridPlan(
        memory_space="vmem",
        grid=(Np // row_tile,),
        num_tables=1,
        table_specs=[pl.BlockSpec((row_tile, P), lambda i: (i, 0))],
        in_specs=[pl.BlockSpec((S, T, D), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((row_tile, P * T, D), lambda i: (i, 0, 0)),
        instrument=instrument,
    )
    outs = plan.pallas_call(
        functools.partial(_gather_vmem, instrument=instrument),
        jax.ShapeDtypeStruct((Np, P * T, D), pool.dtype),
        interpret=interpret,
    )(pages_p, pool)
    if instrument:
        return outs[0][:N], outs[1]
    return outs[:N]


# --------------------------------------------------------------------------
# gather, segmented pool — the same walk through the two-level table.
# --------------------------------------------------------------------------

def _gather_vmem_extents(ext_ref, off_ref, *refs):
    *pools, out_ref = refs
    ext = ext_ref[...]  # (rows, P) int32 extent ids, −1 unclaimed
    off = off_ref[...]  # (rows, P) int32 offsets-in-extent
    rows, P = ext.shape
    T, D = pools[0].shape[1:]
    acc = jnp.zeros((rows, P, T, D), out_ref.dtype)
    for e, pool_ref in enumerate(pools):
        pool = pool_ref[...]  # (S_e, T, D)
        idx = jnp.clip(off, 0, pool.shape[0] - 1).reshape(rows * P)
        g = jnp.take(pool, idx, axis=0).reshape(rows, P, T, D)
        acc = jnp.where((ext == e)[:, :, None, None], g, acc)
    out_ref[...] = acc.reshape(rows, P * T, D)


def _gather_hbm_extents(ext_ref, off_ref, *refs):
    *pools, out_ref = refs
    n, p = pl.program_id(0), pl.program_id(1)
    e = ext_ref[n, p]  # the body consumes only the tile this id selects
    out = jnp.zeros(out_ref.shape, out_ref.dtype)
    for i, pool_ref in enumerate(pools):
        out = jnp.where(e == i, pool_ref[...], out)
    out_ref[...] = out


def _extent_tile_spec(e: int, size: int, block: tuple[int, ...]):
    """hbm BlockSpec for extent ``e``: the index_map resolves this grid
    step's (ext, off) pair via ``common.extent_row`` — one slab tile per
    extent per step, only the selected one consumed."""
    return pl.BlockSpec(
        block,
        lambda n, p, ext, off: (
            common.extent_row(ext[n, p], off[n, p], e, size),
            0,
            0,
        ),
    )


def paged_gather_pallas_extents(
    extents: tuple[jax.Array, ...],  # each (S_e, T, D)
    ext_tbl: jax.Array,  # (N, P) int32 — extent id per page, −1 unclaimed
    off_tbl: jax.Array,  # (N, P) int32 — offset-in-extent per page
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    memory_space: str = "vmem",
    interpret: bool = False,
) -> jax.Array:
    """Multi-extent ``paged_gather_pallas``: same contiguous views, with the
    page table pre-resolved through the two-level (extent, offset) table so
    growth never had to copy the pool (``pool/extents``)."""
    N, P = ext_tbl.shape
    T, D = extents[0].shape[1:]
    E = len(extents)
    if memory_space == "hbm":
        plan = common.GridPlan(
            memory_space="hbm",
            grid=(N, P),
            num_tables=2,
            table_specs=(),
            in_specs=[
                _extent_tile_spec(e, ext.shape[0], (1, T, D))
                for e, ext in enumerate(extents)
            ],
            out_specs=pl.BlockSpec((1, T, D), lambda n, p, ext, off: (n, p, 0)),
        )
        return plan.pallas_call(
            _gather_hbm_extents,
            jax.ShapeDtypeStruct((N, P * T, D), extents[0].dtype),
            interpret=interpret,
        )(ext_tbl, off_tbl, *extents)
    ext_p = common.pad_to(ext_tbl, row_tile, axis=0, value=-1)
    off_p = common.pad_to(off_tbl, row_tile, axis=0, value=-1)
    Np = ext_p.shape[0]
    plan = common.GridPlan(
        memory_space="vmem",
        grid=(Np // row_tile,),
        num_tables=2,
        table_specs=[
            pl.BlockSpec((row_tile, P), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, P), lambda i: (i, 0)),
        ],
        in_specs=[
            pl.BlockSpec(ext.shape, lambda i: (0, 0, 0)) for ext in extents
        ],
        out_specs=pl.BlockSpec((row_tile, P * T, D), lambda i: (i, 0, 0)),
    )
    out = plan.pallas_call(
        _gather_vmem_extents,
        jax.ShapeDtypeStruct((Np, P * T, D), extents[0].dtype),
        interpret=interpret,
    )(ext_p, off_p, *extents)
    return out[:N]


# --------------------------------------------------------------------------
# attend — flash-decode through the page table.
# --------------------------------------------------------------------------

def _attend_step(q, k, v, kv_len, p, slab_tokens, m_ref, l_ref, acc_ref):
    """One page's online-softmax update — shared by both memory spaces."""
    s = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
    kpos = p * slab_tokens + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < kv_len, s, MASK_VALUE)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    pw = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(pw, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pw, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _attend_vmem(
    len_ref, pages_ref, q_ref, k_ref, v_ref, o_ref, *rest,
    slab_tokens, n_pages, instrument=False,
):
    if instrument:
        ctr_ref, m_ref, l_ref, acc_ref = rest
    else:
        m_ref, l_ref, acc_ref = rest
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0, 0]
    slab = pages_ref[0, p]

    @pl.when((slab >= 0) & (p * slab_tokens < kv_len))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, pl.ds(jnp.maximum(slab, 0), 1)][0]  # (T, D)
        v = v_ref[0, pl.ds(jnp.maximum(slab, 0), 1)][0]
        _attend_step(q, k, v, kv_len, p, slab_tokens, m_ref, l_ref, acc_ref)

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)

    if instrument:
        _attend_ctr(ctr_ref, slab >= 0, kv_len, p, slab_tokens)


def _attend_hbm(
    len_ref, pages_ref, q_ref, k_ref, v_ref, o_ref, *rest,
    slab_tokens, n_pages, instrument=False,
):
    if instrument:
        ctr_ref, m_ref, l_ref, acc_ref = rest
    else:
        m_ref, l_ref, acc_ref = rest
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    slab = pages_ref[b, p]

    @pl.when((slab >= 0) & (p * slab_tokens < kv_len))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        # this step's DMA'd tiles: k/v_pool[head, pages[b, p]]
        _attend_step(
            q, k_ref[0, 0], v_ref[0, 0], kv_len, p, slab_tokens,
            m_ref, l_ref, acc_ref,
        )

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)

    if instrument:
        _attend_ctr(ctr_ref, slab >= 0, kv_len, p, slab_tokens)


def paged_attend_pallas(
    q: jax.Array,  # (B, KH, G, D) f32, pre-scaled
    k_pool: jax.Array,  # (KH, S, T, D) head-major pool
    v_pool: jax.Array,  # (KH, S, T, D)
    pages: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32
    *,
    memory_space: str = "vmem",
    instrument: bool = False,
    interpret: bool = False,
):
    B, KH, G, D = q.shape
    _, S, T, _ = k_pool.shape
    P = pages.shape[1]
    pages = pages.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    scratch = [
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, D), jnp.float32),
    ]
    out_shape = jax.ShapeDtypeStruct((B, KH, G, D), jnp.float32)
    if memory_space == "hbm":
        kv_spec = pl.BlockSpec(
            (1, 1, T, D),
            lambda b, h, p, lens, pages: (h, jnp.clip(pages[b, p], 0, S - 1), 0, 0),
        )
        plan = common.GridPlan(
            memory_space="hbm",
            grid=(B, KH, P),
            num_tables=2,
            table_specs=(),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, p, lens, pages: (b, h, 0, 0)),
                kv_spec,
                kv_spec,
            ],
            out_specs=pl.BlockSpec(
                (1, 1, G, D), lambda b, h, p, lens, pages: (b, h, 0, 0)
            ),
            scratch_shapes=scratch,
            instrument=instrument,
        )
        kernel = functools.partial(
            _attend_hbm, slab_tokens=T, n_pages=P, instrument=instrument
        )
        outs = plan.pallas_call(kernel, out_shape, interpret=interpret)(
            lengths, pages, q, k_pool, v_pool
        )
        return (outs[0], outs[1]) if instrument else outs
    plan = common.GridPlan(
        memory_space="vmem",
        grid=(B, KH, P),
        num_tables=2,
        table_specs=[
            pl.BlockSpec((1, 1), lambda b, h, p: (b, 0)),
            pl.BlockSpec((1, P), lambda b, h, p: (b, 0)),
        ],
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p: (b, h, 0, 0)),
            pl.BlockSpec((1, S, T, D), lambda b, h, p: (h, 0, 0, 0)),
            pl.BlockSpec((1, S, T, D), lambda b, h, p: (h, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p: (b, h, 0, 0)),
        scratch_shapes=scratch,
        instrument=instrument,
    )
    kernel = functools.partial(
        _attend_vmem, slab_tokens=T, n_pages=P, instrument=instrument
    )
    outs = plan.pallas_call(kernel, out_shape, interpret=interpret)(
        lengths.reshape(B, 1), pages, q, k_pool, v_pool
    )
    return (outs[0], outs[1]) if instrument else outs


def _attend_vmem_extents(
    len_ref, ext_ref, off_ref, q_ref, *refs, slab_tokens, n_pages, n_ext,
):
    ks, vs = refs[:n_ext], refs[n_ext : 2 * n_ext]
    o_ref = refs[2 * n_ext]
    m_ref, l_ref, acc_ref = refs[2 * n_ext + 1 :]
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0, 0]
    ext = ext_ref[0, p]
    off = off_ref[0, p]

    @pl.when((ext >= 0) & (p * slab_tokens < kv_len))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        T, D = ks[0].shape[2:]
        k = jnp.zeros((T, D), ks[0].dtype)
        v = jnp.zeros((T, D), vs[0].dtype)
        for e in range(n_ext):
            row = common.extent_row(ext, off, e, ks[e].shape[1])
            k = jnp.where(ext == e, ks[e][0, pl.ds(row, 1)][0], k)
            v = jnp.where(ext == e, vs[e][0, pl.ds(row, 1)][0], v)
        _attend_step(q, k, v, kv_len, p, slab_tokens, m_ref, l_ref, acc_ref)

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _attend_hbm_extents(
    len_ref, ext_ref, off_ref, q_ref, *refs, slab_tokens, n_pages, n_ext,
):
    ks, vs = refs[:n_ext], refs[n_ext : 2 * n_ext]
    o_ref = refs[2 * n_ext]
    m_ref, l_ref, acc_ref = refs[2 * n_ext + 1 :]
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    ext = ext_ref[b, p]

    @pl.when((ext >= 0) & (p * slab_tokens < kv_len))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        # each extent DMA'd one (T, D) tile; consume the one ``ext`` selects
        k = jnp.zeros(ks[0][0, 0].shape, ks[0].dtype)
        v = jnp.zeros(vs[0][0, 0].shape, vs[0].dtype)
        for e in range(n_ext):
            k = jnp.where(ext == e, ks[e][0, 0], k)
            v = jnp.where(ext == e, vs[e][0, 0], v)
        _attend_step(q, k, v, kv_len, p, slab_tokens, m_ref, l_ref, acc_ref)

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attend_pallas_extents(
    q: jax.Array,  # (B, KH, G, D) f32, pre-scaled
    k_extents: tuple[jax.Array, ...],  # each (KH, S_e, T, D) head-major
    v_extents: tuple[jax.Array, ...],
    ext_tbl: jax.Array,  # (B, P) int32 — extent id per page, −1 unclaimed
    off_tbl: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32
    *,
    memory_space: str = "vmem",
    interpret: bool = False,
) -> jax.Array:
    """Multi-extent ``paged_attend_pallas``: the K/V index_maps resolve the
    page walk through the two-level (extent, offset) table."""
    B, KH, G, D = q.shape
    T = k_extents[0].shape[2]
    P = ext_tbl.shape[1]
    E = len(k_extents)
    ext_tbl = ext_tbl.astype(jnp.int32)
    off_tbl = off_tbl.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    scratch = [
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, D), jnp.float32),
    ]
    out_shape = jax.ShapeDtypeStruct((B, KH, G, D), jnp.float32)
    if memory_space == "hbm":
        def kv_spec(e: int, size: int):
            return pl.BlockSpec(
                (1, 1, T, D),
                lambda b, h, p, lens, ext, off: (
                    h,
                    common.extent_row(ext[b, p], off[b, p], e, size),
                    0,
                    0,
                ),
            )

        plan = common.GridPlan(
            memory_space="hbm",
            grid=(B, KH, P),
            num_tables=3,
            table_specs=(),
            in_specs=[
                pl.BlockSpec(
                    (1, 1, G, D), lambda b, h, p, lens, ext, off: (b, h, 0, 0)
                ),
                *[kv_spec(e, k.shape[1]) for e, k in enumerate(k_extents)],
                *[kv_spec(e, v.shape[1]) for e, v in enumerate(v_extents)],
            ],
            out_specs=pl.BlockSpec(
                (1, 1, G, D), lambda b, h, p, lens, ext, off: (b, h, 0, 0)
            ),
            scratch_shapes=scratch,
        )
        kernel = functools.partial(
            _attend_hbm_extents, slab_tokens=T, n_pages=P, n_ext=E
        )
        return plan.pallas_call(kernel, out_shape, interpret=interpret)(
            lengths, ext_tbl, off_tbl, q, *k_extents, *v_extents
        )
    plan = common.GridPlan(
        memory_space="vmem",
        grid=(B, KH, P),
        num_tables=3,
        table_specs=[
            pl.BlockSpec((1, 1), lambda b, h, p: (b, 0)),
            pl.BlockSpec((1, P), lambda b, h, p: (b, 0)),
            pl.BlockSpec((1, P), lambda b, h, p: (b, 0)),
        ],
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p: (b, h, 0, 0)),
            *[
                pl.BlockSpec((1, k.shape[1], T, D), lambda b, h, p: (h, 0, 0, 0))
                for k in k_extents
            ],
            *[
                pl.BlockSpec((1, v.shape[1], T, D), lambda b, h, p: (h, 0, 0, 0))
                for v in v_extents
            ],
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p: (b, h, 0, 0)),
        scratch_shapes=scratch,
    )
    kernel = functools.partial(
        _attend_vmem_extents, slab_tokens=T, n_pages=P, n_ext=E
    )
    return plan.pallas_call(kernel, out_shape, interpret=interpret)(
        lengths.reshape(B, 1), ext_tbl, off_tbl, q, *k_extents, *v_extents
    )


# --------------------------------------------------------------------------
# slab append — multi-array wave insert, scattered through slab ownership.
# --------------------------------------------------------------------------

def _slab_scatter(gathered, owner, base, size, count, pool_in, m):
    """Write wave elements into one slab tile row set — shared index math.

    ``gathered (rows, m, D)``, ``owner/base/size/count`` broadcastable over
    the tile's slab rows; returns the updated ``(tile, T, D)`` tile.
    """
    tile, T = pool_in.shape[:2]
    j = jax.lax.broadcasted_iota(jnp.int32, (tile, T), 1)
    o = base + j - size
    valid = (owner[:, None] >= 0) & (o >= 0) & (o < count)
    vals = jnp.take_along_axis(gathered, jnp.clip(o, 0, m - 1)[:, :, None], axis=1)
    return jnp.where(valid[:, :, None], vals, pool_in)


def _slab_append_vmem(
    owners_ref, bases_ref, sizes_ref, mask_ref, elems_ref, pool_in_ref,
    pool_out_ref, *, dispatch,
):
    mask = mask_ref[...]  # (N, m) int32 0/1
    elems = elems_ref[...]  # (N, m, D)
    sizes = sizes_ref[...]  # (N, 1) int32
    N, m = mask.shape

    # push_back machinery: exclusive scan + insert permutation
    inc = jnp.cumsum(mask, axis=1)
    off = inc - mask
    count = inc[:, -1:]  # (N, 1)
    gathered = apply_insert_permutation(off, mask, elems, dispatch)  # (N, m, D)

    owners = owners_ref[...][:, 0]  # (tile,) — owner array per slab, −1 free
    bases = bases_ref[...]  # (tile, 1) logical position of slot 0
    own = jnp.clip(owners, 0, N - 1)
    pool_out_ref[...] = _slab_scatter(
        jnp.take(gathered, own, axis=0),
        owners,
        bases,
        jnp.take(sizes[:, 0], own)[:, None],
        jnp.take(count[:, 0], own)[:, None],
        pool_in_ref[...],
        m,
    )


def _slab_append_hbm(
    owners_ref, bases_ref, sizes_ref, mask_ref, elems_ref, pool_in_ref,
    pool_out_ref, *, narrays, dispatch,
):
    s = pl.program_id(0)
    owner = owners_ref[s]
    own = jnp.clip(owner, 0, narrays - 1)
    mask = mask_ref[...]  # (1, m) — the owner's wave row (this step's DMA)
    elems = elems_ref[...]  # (1, m, D)
    _, m = mask.shape
    inc = jnp.cumsum(mask, axis=1)
    off = inc - mask
    count = inc[:, -1:]  # (1, 1)
    gathered = apply_insert_permutation(off, mask, elems, dispatch)  # (1, m, D)
    pool_out_ref[...] = _slab_scatter(
        gathered,
        owner.reshape(1),
        bases_ref[s].reshape(1, 1),
        sizes_ref[own].reshape(1, 1),
        count,
        pool_in_ref[...],
        m,
    )


def slab_append_pallas(
    pool: jax.Array,  # (S, T, D)
    owners: jax.Array,  # (S,) int32
    bases: jax.Array,  # (S,) int32
    sizes: jax.Array,  # (N,) int32
    elems: jax.Array,  # (N, m, D)
    mask: jax.Array,  # (N, m) int32 0/1
    *,
    slab_tile: int = DEFAULT_ROW_TILE,
    memory_space: str = "vmem",
    dispatch: str = "onehot",
    interpret: bool = False,
) -> jax.Array:
    """→ new pool (S, T, D); untouched slabs alias through unscathed."""
    S, T, D = pool.shape
    N, m = mask.shape
    owners = owners.reshape(S).astype(jnp.int32)
    bases = bases.reshape(S).astype(jnp.int32)
    sizes = sizes.reshape(N).astype(jnp.int32)
    out_shape = jax.ShapeDtypeStruct((S, T, D), pool.dtype)
    if memory_space == "hbm":
        # one slab per grid step; the scalar-prefetched owner table selects
        # which array's wave lane block rides along in the DMA.
        row_of = lambda s, owners, bases, sizes: jnp.clip(owners[s], 0, N - 1)
        plan = common.GridPlan(
            memory_space="hbm",
            grid=(S,),
            num_tables=3,
            table_specs=(),
            in_specs=[
                pl.BlockSpec(
                    (1, m), lambda s, ow, ba, si: (row_of(s, ow, ba, si), 0)
                ),
                pl.BlockSpec(
                    (1, m, D), lambda s, ow, ba, si: (row_of(s, ow, ba, si), 0, 0)
                ),
                pl.BlockSpec((1, T, D), lambda s, ow, ba, si: (s, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, T, D), lambda s, ow, ba, si: (s, 0, 0)),
            aliases={2: 0},  # pool in-place: O(wave) writes
        )
        kernel = functools.partial(_slab_append_hbm, narrays=N, dispatch=dispatch)
        return plan.pallas_call(kernel, out_shape, interpret=interpret)(
            owners, bases, sizes, mask, elems, pool
        )
    if S % slab_tile:
        raise ValueError(f"n_slabs {S} must divide by tile {slab_tile}")
    row = lambda width: pl.BlockSpec((slab_tile, width), lambda i: (i, 0))
    plan = common.GridPlan(
        memory_space="vmem",
        grid=(S // slab_tile,),
        num_tables=3,
        table_specs=[row(1), row(1), pl.BlockSpec((N, 1), lambda i: (0, 0))],
        in_specs=[
            pl.BlockSpec((N, m), lambda i: (0, 0)),
            pl.BlockSpec((N, m, D), lambda i: (0, 0, 0)),
            pl.BlockSpec((slab_tile, T, D), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((slab_tile, T, D), lambda i: (i, 0, 0)),
        aliases={2: 0},  # pool in-place: O(wave) writes
    )
    kernel = functools.partial(_slab_append_vmem, dispatch=dispatch)
    return plan.pallas_call(kernel, out_shape, interpret=interpret)(
        owners.reshape(S, 1), bases.reshape(S, 1), sizes.reshape(N, 1),
        mask, elems, pool
    )
