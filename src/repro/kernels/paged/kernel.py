"""Paged Pallas kernels — read/write a slab pool through page tables.

Three kernels back the arena subsystem (``repro.pool``, DESIGN.md §4):

``paged_gather_pallas``
    Materialize each logical array's contiguous view by walking its page
    table — the indirection-table read the arena's flatten path uses.

``paged_attend_pallas``
    Flash-decode attention against paged K/V pools: grid ``(batch, kv_heads,
    pages)`` with the online-softmax state in VMEM scratch (the
    ``kernels/decode_attention`` structure), the per-step KV tile selected by
    the page table.  Pages past the live length — GGArray tail slabs — are
    skipped entirely.

``slab_append_pallas``
    The push_back prefix-sum machinery (exclusive mask scan + exact int32
    one-hot permutation, see ``kernels/push_back``) retargeted at the pool:
    one grid step per slab tile resolves each slot's wave element through the
    slab's *owner* row, and the pool aliases its output so untouched slabs
    are never copied.

VMEM note: like the flatten/push_back kernels, pool operands are resident
per grid step (fine in interpret mode / at test scale).  A production
variant keeps pools in HBM and DMAs one slab per grid step with the page
table as a ``PrefetchScalarGridSpec`` scalar operand driving the index_map —
the index math is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged.ref import MASK_VALUE

__all__ = [
    "paged_gather_pallas",
    "paged_attend_pallas",
    "slab_append_pallas",
    "DEFAULT_ROW_TILE",
]

DEFAULT_ROW_TILE = 8


# --------------------------------------------------------------------------
# gather — logical contiguous view through the page table.
# --------------------------------------------------------------------------

def _gather_kernel(pages_ref, pool_ref, out_ref):
    pages = pages_ref[...]  # (rows, P) int32
    pool = pool_ref[...]  # (S, T, D)
    rows, P = pages.shape
    S, T, D = pool.shape
    idx = jnp.clip(pages, 0, S - 1).reshape(rows * P)
    g = jnp.take(pool, idx, axis=0).reshape(rows, P, T, D)
    valid = (pages >= 0)[:, :, None, None]
    out_ref[...] = jnp.where(valid, g, 0).reshape(rows, P * T, D)


def paged_gather_pallas(
    pool: jax.Array,  # (S, T, D)
    pages: jax.Array,  # (N, P) int32
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool = False,
) -> jax.Array:
    """→ (N, P·T, D) contiguous logical views (zeros under page −1)."""
    N, P = pages.shape
    S, T, D = pool.shape
    if N % row_tile:
        raise ValueError(f"narrays {N} must divide by tile {row_tile}")
    return pl.pallas_call(
        _gather_kernel,
        grid=(N // row_tile,),
        in_specs=[
            pl.BlockSpec((row_tile, P), lambda i: (i, 0)),
            pl.BlockSpec((S, T, D), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, P * T, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, P * T, D), pool.dtype),
        interpret=interpret,
    )(pages, pool)


# --------------------------------------------------------------------------
# attend — flash-decode through the page table.
# --------------------------------------------------------------------------

def _attend_kernel(
    len_ref, pages_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, slab_tokens, n_pages,
):
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0, 0]
    slab = pages_ref[0, p]

    @pl.when((slab >= 0) & (p * slab_tokens < kv_len))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, pl.ds(jnp.maximum(slab, 0), 1)][0]  # (T, D)
        v = v_ref[0, pl.ds(jnp.maximum(slab, 0), 1)][0]
        s = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
        kpos = p * slab_tokens + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, MASK_VALUE)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pw = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(pw, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            pw, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attend_pallas(
    q: jax.Array,  # (B, KH, G, D) f32, pre-scaled
    k_pool: jax.Array,  # (KH, S, T, D) head-major pool
    v_pool: jax.Array,  # (KH, S, T, D)
    pages: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32
    *,
    interpret: bool = False,
) -> jax.Array:
    B, KH, G, D = q.shape
    _, S, T, _ = k_pool.shape
    P = pages.shape[1]
    kernel = functools.partial(_attend_kernel, slab_tokens=T, n_pages=P)
    return pl.pallas_call(
        kernel,
        grid=(B, KH, P),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, p: (b, 0)),
            pl.BlockSpec((1, P), lambda b, h, p: (b, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, p: (b, h, 0, 0)),
            pl.BlockSpec((1, S, T, D), lambda b, h, p: (h, 0, 0, 0)),
            pl.BlockSpec((1, S, T, D), lambda b, h, p: (h, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.reshape(B, 1).astype(jnp.int32), pages, q, k_pool, v_pool)


# --------------------------------------------------------------------------
# slab append — multi-array wave insert, scattered through slab ownership.
# --------------------------------------------------------------------------

def _slab_append_kernel(
    mask_ref, elems_ref, sizes_ref, owners_ref, bases_ref, pool_in_ref, pool_out_ref
):
    mask = mask_ref[...]  # (N, m) int32 0/1
    elems = elems_ref[...]  # (N, m, D)
    sizes = sizes_ref[...]  # (N, 1) int32
    N, m = mask.shape

    # push_back machinery: exclusive scan + exact one-hot insert permutation
    inc = jnp.cumsum(mask, axis=1)
    off = inc - mask
    count = inc[:, -1:]  # (N, 1)
    iota_o = jax.lax.broadcasted_iota(jnp.int32, (N, m, m), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (N, m, m), 2)
    onehot = (off[:, None, :] == iota_o) & (mask[:, None, :] > 0)
    sel = jnp.sum(jnp.where(onehot, iota_k, 0), axis=2)
    gathered = jnp.take_along_axis(elems, sel[:, :, None], axis=1)  # (N, m, D)

    owners = owners_ref[...][:, 0]  # (tile,) — owner array per slab, −1 free
    bases = bases_ref[...]  # (tile, 1) logical position of slot 0
    own = jnp.clip(owners, 0, N - 1)
    tile, T = pool_in_ref.shape[:2]
    j = jax.lax.broadcasted_iota(jnp.int32, (tile, T), 1)
    o = bases + j - jnp.take(sizes[:, 0], own)[:, None]
    valid = (owners[:, None] >= 0) & (o >= 0) & (o < jnp.take(count[:, 0], own)[:, None])
    vals = jnp.take_along_axis(
        jnp.take(gathered, own, axis=0), jnp.clip(o, 0, m - 1)[:, :, None], axis=1
    )
    pool_out_ref[...] = jnp.where(valid[:, :, None], vals, pool_in_ref[...])


def slab_append_pallas(
    pool: jax.Array,  # (S, T, D)
    owners: jax.Array,  # (S, 1) int32
    bases: jax.Array,  # (S, 1) int32
    sizes: jax.Array,  # (N, 1) int32
    elems: jax.Array,  # (N, m, D)
    mask: jax.Array,  # (N, m) int32 0/1
    *,
    slab_tile: int = DEFAULT_ROW_TILE,
    interpret: bool = False,
) -> jax.Array:
    """→ new pool (S, T, D); untouched slabs alias through unscathed."""
    S, T, D = pool.shape
    N, m = mask.shape
    if S % slab_tile:
        raise ValueError(f"n_slabs {S} must divide by tile {slab_tile}")
    row = lambda width: pl.BlockSpec((slab_tile, width), lambda i: (i, 0))
    return pl.pallas_call(
        _slab_append_kernel,
        grid=(S // slab_tile,),
        in_specs=[
            pl.BlockSpec((N, m), lambda i: (0, 0)),
            pl.BlockSpec((N, m, D), lambda i: (0, 0, 0)),
            pl.BlockSpec((N, 1), lambda i: (0, 0)),
            row(1),
            row(1),
            pl.BlockSpec((slab_tile, T, D), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((slab_tile, T, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, T, D), pool.dtype),
        input_output_aliases={5: 0},  # pool in-place: O(wave) writes
        interpret=interpret,
    )(mask, elems, sizes, owners, bases, pool)
