"""Pure-jnp oracles for the paged kernels.

Each mirrors its Pallas kernel's accumulation structure op-for-op (same
segment widths, same reduction axes, same masked-update formulation), so
interpret-mode kernel runs are **bit-identical** to these references — the
contract the test matrix asserts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gather_pages", "attend_paged", "slab_append", "MASK_VALUE"]

MASK_VALUE = -1e30  # matches models.attention.MASK_VALUE (serving softmax mask)


def gather_pages(pool: jax.Array, pages: jax.Array) -> jax.Array:
    """pool (S, T, D), pages (N, P) int32 → (N, P·T, D); page < 0 → zeros."""
    S, T, D = pool.shape
    N, P = pages.shape
    out = pool[jnp.clip(pages, 0, max(S - 1, 0))]  # (N, P, T, D)
    valid = (pages >= 0)[:, :, None, None]
    return jnp.where(valid, out, 0).reshape(N, P * T, D)


def attend_paged(
    q: jax.Array,  # (B, KH, G, D) f32, pre-scaled
    k_pool: jax.Array,  # (KH, S, T, D) — head-major pool layout
    v_pool: jax.Array,  # (KH, S, T, D)
    pages: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32 live tokens per sequence
) -> jax.Array:
    """One-token attention through the page table, page-at-a-time.

    Online-softmax merge in page order — the flash-decode structure the
    Pallas kernel runs per grid step.  A page past the live length (or an
    unclaimed ``-1`` entry) leaves the state untouched, exactly like the
    kernel's ``pl.when`` skip.
    """
    B, KH, G, D = q.shape
    S, T = k_pool.shape[1:3]
    P = pages.shape[1]
    m = jnp.full((B, KH, G), MASK_VALUE, jnp.float32)
    l = jnp.zeros((B, KH, G), jnp.float32)
    acc = jnp.zeros((B, KH, G, D), jnp.float32)
    lengths = lengths.astype(jnp.int32)
    for p in range(P):
        slab = pages[:, p]  # (B,)
        k = jnp.take(k_pool, jnp.maximum(slab, 0), axis=1)  # (KH, B, T, D)
        v = jnp.take(v_pool, jnp.maximum(slab, 0), axis=1)
        s = jnp.einsum("bkgd,kbtd->bkgt", q, k.astype(jnp.float32))
        kpos = p * T + jnp.arange(T, dtype=jnp.int32)
        live = kpos[None, :] < lengths[:, None]  # (B, T)
        s = jnp.where(live[:, None, None, :], s, MASK_VALUE)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        pw = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(pw, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgt,kbtd->bkgd", pw, v.astype(jnp.float32)
        )
        # page skipped entirely (kernel's pl.when) when dead for a sequence
        use = ((slab >= 0) & (p * T < lengths))[:, None, None]
        m = jnp.where(use, m_new, m)
        l = jnp.where(use, l_new, l)
        acc = jnp.where(use[..., None], acc_new, acc)
    return acc / jnp.maximum(l, 1e-30)[..., None]


def slab_append(
    pool: jax.Array,  # (S, T, D)
    owners: jax.Array,  # (S,) int32 — owning array per slab, −1 = free
    bases: jax.Array,  # (S,) int32 — logical position of the slab's slot 0
    sizes: jax.Array,  # (N,) int32 — live elements per array
    elems: jax.Array,  # (N, m, D)
    mask: jax.Array,  # (N, m) bool
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """→ (new pool, new sizes, positions (N, m) (−1 where masked)).

    The push_back prefix-sum machinery on an ownership-indirected pool:
    per-array exclusive-scan offsets order the wave, and each slab slot
    ``bases[s] + j`` takes wave element ``offset = bases[s] + j − sizes[o]``
    of its owner ``o`` — the same scatter-as-gather formulation as
    ``kernels/push_back``, with one extra owner indirection per slab row.
    """
    mask_i = mask.astype(jnp.int32)
    inc = jnp.cumsum(mask_i, axis=1)
    off = inc - mask_i
    counts = inc[:, -1]  # (N,)
    pos = sizes[:, None] + off

    N, m = mask.shape
    iota_o = jax.lax.broadcasted_iota(jnp.int32, (N, m, m), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (N, m, m), 2)
    onehot = (off[:, None, :] == iota_o) & (mask_i[:, None, :] > 0)
    sel = jnp.sum(jnp.where(onehot, iota_k, 0), axis=2)
    gathered = jnp.take_along_axis(elems, sel[:, :, None], axis=1)  # (N, m, D)

    own = jnp.clip(owners, 0, N - 1)
    S, T = pool.shape[:2]
    j = jax.lax.broadcasted_iota(jnp.int32, (S, T), 1)
    o = bases[:, None] + j - sizes[own][:, None]  # wave offset at this slot
    valid = (owners[:, None] >= 0) & (o >= 0) & (o < counts[own][:, None])
    vals = jnp.take_along_axis(
        gathered[own], jnp.clip(o, 0, m - 1)[:, :, None], axis=1
    )  # (S, T, D)
    new_pool = jnp.where(valid[:, :, None], vals, pool)
    return new_pool, sizes + counts, jnp.where(mask, pos, -1)
