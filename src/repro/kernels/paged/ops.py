"""jit'd paged ops: padding/dispatch around the paged Pallas kernels.

All three ops flatten ``item_shape`` into one trailing feature axis around
the 3-D/4-D kernels (the ``kernels/push_back`` convention) and pad row/slab
counts to the kernel tile with provably inert rows (page −1 / owner −1).
``use_ref=True`` runs the jnp oracle — bit-identical in interpret mode.

``memory_space`` selects the kernel tiling (``common.resolve_memory_space``:
explicit > ``REPRO_MEMORY_SPACE`` > hbm on TPU / vmem in interpret mode);
``slab_append``'s ``dispatch`` selects the insert-permutation backend
(``common.resolve_dispatch`` — MXU matmul for wide waves).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.paged import kernel as _kernel
from repro.kernels.paged import ref as _ref

__all__ = ["paged_gather", "paged_attend", "slab_append", "slab_append_donated"]


def _flat_item(x: jax.Array, lead: int) -> tuple[jax.Array, tuple[int, ...]]:
    """Collapse everything past ``lead`` leading dims into one feature axis."""
    item = x.shape[lead:]
    d = 1
    for dim in item:
        d *= dim
    return x.reshape(*x.shape[:lead], d), item


@partial(jax.jit, static_argnames=("interpret", "use_ref", "memory_space"))
def paged_gather(
    pool: jax.Array,  # (S, T, *item)
    pages: jax.Array,  # (N, P) int32
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
) -> jax.Array:
    """→ (N, P·T, *item) contiguous logical views (zeros under page −1)."""
    N, P = pages.shape
    pool3, item = _flat_item(pool, 2)
    if use_ref:
        out = _ref.gather_pages(pool3, pages)
    else:
        out = _kernel.paged_gather_pallas(
            pool3,
            pages,
            memory_space=common.resolve_memory_space(memory_space, interpret),
            interpret=common.should_interpret(interpret),
        )
    return out.reshape(N, P * pool.shape[1], *item)


@partial(jax.jit, static_argnames=("interpret", "use_ref", "memory_space"))
def paged_attend(
    q: jax.Array,  # (B, KH, G, D) f32, pre-scaled
    k_pool: jax.Array,  # (S, T, KH, D) — token-major pool (cache layout)
    v_pool: jax.Array,  # (S, T, KH, D)
    pages: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
) -> jax.Array:
    """→ (B, KH, G, D) f32 attention output through the page table.

    Pools arrive in the cache's token-major ``(slab, slot, head, dim)``
    layout and are transposed head-major for the kernel's per-head blocking
    (a production pool would be laid out head-major to begin with).
    """
    kh = k_pool.transpose(2, 0, 1, 3)  # (KH, S, T, D)
    vh = v_pool.transpose(2, 0, 1, 3)
    if use_ref:
        return _ref.attend_paged(q, kh, vh, pages, lengths)
    return _kernel.paged_attend_pallas(
        q, kh, vh, pages, lengths,
        memory_space=common.resolve_memory_space(memory_space, interpret),
        interpret=common.should_interpret(interpret),
    )


def _slab_append(
    pool: jax.Array,  # (S, T, *item)
    owners: jax.Array,  # (S,) int32 — owning array per slab, −1 free
    bases: jax.Array,  # (S,) int32 — logical position of each slab's slot 0
    sizes: jax.Array,  # (N,) int32
    elems: jax.Array,  # (N, m, *item)
    mask: jax.Array,  # (N, m) bool or 0/1 int
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
    dispatch: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """→ (new pool, new sizes (N,), positions (N, m) (−1 where masked))."""
    if mask.dtype != jnp.bool_:
        mask = mask != 0
    S, T = pool.shape[:2]
    N, m = mask.shape
    if m == 0:
        return pool, sizes, jnp.zeros((N, 0), jnp.int32)
    pool3, item = _flat_item(pool, 2)
    elems3, _ = _flat_item(elems, 2)
    if use_ref:
        new_pool, new_sizes, pos = _ref.slab_append(
            pool3, owners, bases, sizes.astype(jnp.int32), elems3, mask
        )
        return new_pool.reshape(pool.shape), new_sizes, pos
    # positions/counts are pure mask arithmetic — recomputed in-kernel for
    # the scatter, emitted here for the caller (same exclusive scan)
    mask_i = mask.astype(jnp.int32)
    inc = jnp.cumsum(mask_i, axis=1)
    counts = inc[:, -1]
    pos = sizes[:, None].astype(jnp.int32) + inc - mask_i
    space = common.resolve_memory_space(memory_space, interpret)
    disp = common.resolve_dispatch(dispatch, m, elems.dtype)
    tile = _kernel.DEFAULT_ROW_TILE
    if space == "hbm":
        pool_p, owners_p, bases_p = pool3, owners, bases
    else:  # padded slabs: owner −1 — provably inert
        pool_p = common.pad_to(pool3, tile, axis=0)
        owners_p = common.pad_to(owners.reshape(S), tile, axis=0, value=-1)
        bases_p = common.pad_to(bases.reshape(S), tile, axis=0)
    elems_p = common.pad_to(elems3, common.MXU_LANE, axis=1)
    mask_p = common.pad_to(mask_i, common.MXU_LANE, axis=1)
    new_pool = _kernel.slab_append_pallas(
        pool_p,
        owners_p,
        bases_p,
        sizes.astype(jnp.int32),
        elems_p,
        mask_p,
        memory_space=space,
        dispatch=disp,
        interpret=common.should_interpret(interpret),
    )[:S]
    return (
        new_pool.reshape(pool.shape),
        sizes + counts,
        jnp.where(mask, pos, -1),
    )


_SLAB_STATICS = ("interpret", "use_ref", "memory_space", "dispatch")
slab_append = partial(jax.jit, static_argnames=_SLAB_STATICS)(_slab_append)
# The arena's hot path: the pool is donated, so together with the kernel's
# input_output_aliases an append is O(wave) writes, not O(pool) copies.
slab_append_donated = jax.jit(
    _slab_append, static_argnames=_SLAB_STATICS, donate_argnums=(0,)
)
