"""jit'd paged ops: padding/dispatch around the paged Pallas kernels.

All three ops flatten ``item_shape`` into one trailing feature axis around
the 3-D/4-D kernels (the ``kernels/push_back`` convention) and pad row/slab
counts to the kernel tile with provably inert rows (page −1 / owner −1).
``use_ref=True`` runs the jnp oracle — bit-identical in interpret mode.

``memory_space`` selects the kernel tiling (``common.resolve_memory_space``:
explicit > ``REPRO_MEMORY_SPACE`` > hbm on TPU / vmem in interpret mode);
``slab_append``'s ``dispatch`` selects the insert-permutation backend
(``common.resolve_dispatch`` — MXU matmul for wide waves).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.paged import kernel as _kernel
from repro.kernels.paged import ref as _ref
from repro.obs import device
from repro.pool import extents as _extents

__all__ = ["paged_gather", "paged_attend", "slab_append", "slab_append_donated"]


def _gather_ctr(table: jax.Array, space: str, row_tile: int) -> jax.Array:
    """jnp gather counters matching the in-kernel accounting: the vmem
    tiling pads rows with −1 pages, and those walked-but-dead entries are
    genuine masked-tile waste, so they count."""
    N, P = table.shape
    rows = N if space == "hbm" else N + (-N) % row_tile
    live = jnp.sum((table >= 0).astype(jnp.int32))
    return device.pack(**{
        "paged_gather.launches": 1,
        "paged_gather.tiles": live,
        "paged_gather.masked_tiles": rows * P - live,
    })


def _attend_ctr(table: jax.Array, lengths: jax.Array, T: int, KH: int) -> jax.Array:
    """jnp attend counters over a (B, P) liveness table — the per-(b, p)
    walk the kernel grids over, times the KH head steps."""
    B, P = table.shape
    p_idx = jnp.arange(P, dtype=jnp.int32)[None, :]
    kv = lengths.astype(jnp.int32)[:, None]
    visit = ((table >= 0) & (p_idx * T < kv)).astype(jnp.int32)  # (B, P)
    masked = visit * (T - jnp.clip(kv - p_idx * T, 0, T))
    tiles = jnp.sum(visit)
    return device.pack(**{
        "paged_attend.launches": 1,
        "paged_attend.tiles": KH * tiles,
        "paged_attend.tiles_skipped": KH * (B * P - tiles),
        "paged_attend.lanes": KH * tiles * T,
        "paged_attend.masked_lanes": KH * jnp.sum(masked),
    })


def _flat_item(x: jax.Array, lead: int) -> tuple[jax.Array, tuple[int, ...]]:
    """Collapse everything past ``lead`` leading dims into one feature axis."""
    item = x.shape[lead:]
    d = 1
    for dim in item:
        d *= dim
    return x.reshape(*x.shape[:lead], d), item


def _as_extents(pool) -> tuple[jax.Array, ...]:
    """Normalize a pool argument: flat array → 1-extent tuple; drop empty
    extents (they hold no slab ids, so the global numbering is unchanged)."""
    exts = tuple(pool) if isinstance(pool, (tuple, list)) else (pool,)
    live = tuple(e for e in exts if e.shape[0] > 0)
    return live or exts[:1]


@partial(
    jax.jit,
    static_argnames=("interpret", "use_ref", "memory_space", "instrument"),
)
def paged_gather(
    pool,  # (S, T, *item) or tuple of extents (S_e, T, *item)
    pages: jax.Array,  # (N, P) int32 — global slab ids
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
    instrument: bool = False,
) -> Any:
    """→ (N, P·T, *item) contiguous logical views (zeros under page −1).

    A tuple/list pool is a segmented :class:`~repro.pool.extents.ExtentPool`
    layout: the global page table is resolved through the two-level
    (extent, offset) table host-side and the kernel walks per-extent operands
    (the oracle is the same flat gather over the concatenated extents).
    ``instrument=True`` → (out, device counter vector): in-kernel on the
    single-extent fused path, the matching jnp oracle elsewhere.
    """
    exts = _as_extents(pool)
    T = exts[0].shape[1]
    N, P = pages.shape
    space = common.resolve_memory_space(memory_space, interpret)
    if use_ref:
        pool3, item = _flat_item(_extents.flat_data(exts), 2)
        out = _ref.gather_pages(pool3, pages).reshape(N, P * T, *item)
        if instrument:
            return out, _gather_ctr(pages, space, _kernel.DEFAULT_ROW_TILE)
        return out
    run = common.should_interpret(interpret)
    if len(exts) == 1:
        pool3, item = _flat_item(exts[0], 2)
        outs = _kernel.paged_gather_pallas(
            pool3, pages, memory_space=space,
            instrument=instrument, interpret=run,
        )
        if instrument:
            return outs[0].reshape(N, P * T, *item), device.from_block(outs[1])
        return outs.reshape(N, P * T, *item)
    flat = [_flat_item(e, 2) for e in exts]
    item = flat[0][1]
    ext_tbl, off_tbl = _extents.resolve_pages(
        pages, tuple(e.shape[0] for e in exts)
    )
    out = _kernel.paged_gather_pallas_extents(
        tuple(p for p, _ in flat),
        ext_tbl,
        off_tbl,
        memory_space=space,
        interpret=run,
    ).reshape(N, P * T, *item)
    if instrument:
        return out, _gather_ctr(ext_tbl, space, _kernel.DEFAULT_ROW_TILE)
    return out


@partial(
    jax.jit,
    static_argnames=("interpret", "use_ref", "memory_space", "instrument"),
)
def paged_attend(
    q: jax.Array,  # (B, KH, G, D) f32, pre-scaled
    k_pool,  # (S, T, KH, D) token-major pool, or tuple of extents
    v_pool,  # (S, T, KH, D) or tuple of extents
    pages: jax.Array,  # (B, P) int32 — global slab ids
    lengths: jax.Array,  # (B,) int32
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
    instrument: bool = False,
) -> Any:
    """→ (B, KH, G, D) f32 attention output through the page table.

    Pools arrive in the cache's token-major ``(slab, slot, head, dim)``
    layout and are transposed head-major for the kernel's per-head blocking
    (a production pool would be laid out head-major to begin with).  Tuple
    pools are segmented extents; the walk resolves global slab ids through
    the two-level (extent, offset) table.  ``instrument=True`` → (out,
    device counter vector): in-kernel on the single-extent path, the
    matching jnp oracle elsewhere.
    """
    k_exts = _as_extents(k_pool)
    v_exts = _as_extents(v_pool)
    kh = tuple(k.transpose(2, 0, 1, 3) for k in k_exts)  # each (KH, S_e, T, D)
    vh = tuple(v.transpose(2, 0, 1, 3) for v in v_exts)
    KH, T = kh[0].shape[0], kh[0].shape[2]
    if use_ref:
        k1 = kh[0] if len(kh) == 1 else jnp.concatenate(kh, axis=1)
        v1 = vh[0] if len(vh) == 1 else jnp.concatenate(vh, axis=1)
        out = _ref.attend_paged(q, k1, v1, pages, lengths)
        if instrument:
            return out, _attend_ctr(pages, lengths, T, KH)
        return out
    space = common.resolve_memory_space(memory_space, interpret)
    run = common.should_interpret(interpret)
    if len(kh) == 1:
        outs = _kernel.paged_attend_pallas(
            q, kh[0], vh[0], pages, lengths,
            memory_space=space, instrument=instrument, interpret=run,
        )
        if instrument:
            return outs[0], device.from_block(outs[1])
        return outs
    ext_tbl, off_tbl = _extents.resolve_pages(
        pages, tuple(k.shape[1] for k in kh)
    )
    out = _kernel.paged_attend_pallas_extents(
        q, kh, vh, ext_tbl, off_tbl, lengths,
        memory_space=space, interpret=run,
    )
    if instrument:
        return out, _attend_ctr(ext_tbl, lengths, T, KH)
    return out


def _slab_append(
    pool,  # (S, T, *item) or tuple of extents (S_e, T, *item)
    owners: jax.Array,  # (S,) int32 — owning array per slab, −1 free
    bases: jax.Array,  # (S,) int32 — logical position of each slab's slot 0
    sizes: jax.Array,  # (N,) int32
    elems: jax.Array,  # (N, m, *item)
    mask: jax.Array,  # (N, m) bool or 0/1 int
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
    dispatch: str = "auto",
    instrument: bool = False,
) -> tuple:
    """→ (new pool, new sizes (N,), positions (N, m) (−1 where masked)).

    A tuple pool comes back as a tuple with the *same structure*: the kernel
    launches once per extent against that extent's slice of the owner/base
    tables (slab ids are contiguous per extent), each launch aliasing its
    extent in place — growth never copied the pool, and neither does the
    append.  ``instrument=True`` appends a device counter vector (jnp wave
    accounting — same numbers on every path/space).
    """
    if mask.dtype != jnp.bool_:
        mask = mask != 0
    is_multi = isinstance(pool, (tuple, list))
    exts = tuple(pool) if is_multi else (pool,)
    T = exts[0].shape[1]
    N, m = mask.shape

    def ctr():
        # the kernel pads wave lanes to MXU_LANE in both memory spaces
        m_pad = m + (-m) % common.MXU_LANE
        return device.pack(**{
            "slab_append.waves": 1,
            "slab_append.lanes": N * m_pad,
            "slab_append.active_lanes": jnp.sum(mask.astype(jnp.int32)),
        })

    if m == 0:
        pos0 = jnp.zeros((N, 0), jnp.int32)
        if instrument:
            return pool, sizes, pos0, device.zeros()
        return pool, sizes, pos0
    ext_item = [_flat_item(e, 2) for e in exts]
    item = ext_item[0][1]
    elems3, _ = _flat_item(elems, 2)
    if use_ref:
        pool3 = _extents.flat_data([p for p, _ in ext_item])
        new_pool, new_sizes, pos = _ref.slab_append(
            pool3, owners, bases, sizes.astype(jnp.int32), elems3, mask
        )
        if not is_multi:
            new_pool = new_pool.reshape(pool.shape)
        else:
            out, lo = [], 0
            for e in exts:
                hi = lo + e.shape[0]
                out.append(new_pool[lo:hi].reshape(e.shape))
                lo = hi
            new_pool = tuple(out)
        if instrument:
            return new_pool, new_sizes, pos, ctr()
        return new_pool, new_sizes, pos
    # positions/counts are pure mask arithmetic — recomputed in-kernel for
    # the scatter, emitted here for the caller (same exclusive scan)
    mask_i = mask.astype(jnp.int32)
    inc = jnp.cumsum(mask_i, axis=1)
    counts = inc[:, -1]
    pos = sizes[:, None].astype(jnp.int32) + inc - mask_i
    space = common.resolve_memory_space(memory_space, interpret)
    disp = common.resolve_dispatch(dispatch, m, elems.dtype)
    run = common.should_interpret(interpret)
    tile = _kernel.DEFAULT_ROW_TILE
    elems_p = common.pad_to(elems3, common.MXU_LANE, axis=1)
    mask_p = common.pad_to(mask_i, common.MXU_LANE, axis=1)
    sizes32 = sizes.astype(jnp.int32)

    def one_extent(ext3: jax.Array, lo: int) -> jax.Array:
        S_e = ext3.shape[0]
        own_e = jax.lax.dynamic_slice_in_dim(owners.reshape(-1), lo, S_e)
        base_e = jax.lax.dynamic_slice_in_dim(bases.reshape(-1), lo, S_e)
        if space == "hbm":
            pool_p, owners_p, bases_p = ext3, own_e, base_e
        else:  # padded slabs: owner −1 — provably inert
            pool_p = common.pad_to(ext3, tile, axis=0)
            owners_p = common.pad_to(own_e, tile, axis=0, value=-1)
            bases_p = common.pad_to(base_e, tile, axis=0)
        return _kernel.slab_append_pallas(
            pool_p,
            owners_p,
            bases_p,
            sizes32,
            elems_p,
            mask_p,
            memory_space=space,
            dispatch=disp,
            interpret=run,
        )[:S_e]

    new_exts, lo = [], 0
    for e3, _ in ext_item:
        S_e = e3.shape[0]
        new_exts.append(e3 if S_e == 0 else one_extent(e3, lo))
        lo += S_e
    new_sizes = sizes + counts
    pos = jnp.where(mask, pos, -1)
    if not is_multi:
        new_pool = new_exts[0].reshape(pool.shape)
    else:
        new_pool = tuple(ne.reshape(e.shape) for ne, e in zip(new_exts, exts))
    if instrument:
        return new_pool, new_sizes, pos, ctr()
    return new_pool, new_sizes, pos


_SLAB_STATICS = ("interpret", "use_ref", "memory_space", "dispatch", "instrument")
slab_append = partial(jax.jit, static_argnames=_SLAB_STATICS)(_slab_append)
# The arena's hot path: the pool is donated, so together with the kernel's
# input_output_aliases an append is O(wave) writes, not O(pool) copies.
slab_append_donated = jax.jit(
    _slab_append, static_argnames=_SLAB_STATICS, donate_argnums=(0,)
)
