"""Paged kernels — gather / attend / append through a slab indirection table.

The arena subsystem (``repro.pool``) stores many logical growable arrays in
one device pool of fixed-size slabs; these kernels are the device-side read
and write paths that follow the per-array page tables instead of owned
buffers (DESIGN.md §4).
"""
from repro.kernels.paged import ops, ref

__all__ = ["ops", "ref"]
