"""Flash-decode kernel — one-token attention against a long KV cache.

Decode attention is memory-bound (the whole KV cache streams through once per
token), so the kernel's job is to keep the MXU row dimension non-degenerate
and never re-read KV.  GQA makes that natural on TPU: the ``group`` query
heads that share a KV head are packed into the matmul row dimension, giving
``(group, D) × (D, BK)`` score tiles instead of vector–matrix products.

Grid ``(batch, kv_heads, S/BK)``; the trailing axis is sequential, carrying
the online-softmax state in VMEM scratch.  The live cache length arrives as a
``(batch, 1)`` array (read per block) so one compiled kernel serves any fill
level of the GGArray KV cache bucket it is pointed at.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

DEFAULT_BK = 512
MASK_VALUE = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, sm_scale, bk, n_kv_blocks):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0, 0]
    # Skip KV blocks entirely past the live length (GGArray tail buckets).
    @pl.when(kb * bk < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (BK, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, MASK_VALUE)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,  # (B, KH, G, D) — query heads grouped under their KV head
    k: jax.Array,  # (B, KH, S, D)
    v: jax.Array,  # (B, KH, S, D)
    lengths: jax.Array,  # (B, 1) int32 live cache lengths
    *,
    sm_scale: float | None = None,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    B, KH, G, D = q.shape
    S = k.shape[2]
    if S % bk:
        raise ValueError(f"unpadded KV length {S}; pad to {bk}")
    sm_scale = D ** -0.5 if sm_scale is None else sm_scale
    n_kv_blocks = S // bk
    kernel = functools.partial(
        _decode_kernel, sm_scale=sm_scale, bk=bk, n_kv_blocks=n_kv_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=(B, KH, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, kb: (b, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, kb: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, kb: (b, h, kb, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, kb: (b, h, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, kb: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
