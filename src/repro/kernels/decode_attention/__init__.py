from repro.kernels.decode_attention import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
