"""jit'd wrapper for the flash-decode kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.decode_attention import kernel as _kernel
from repro.kernels.decode_attention import ref as _ref

__all__ = ["decode_attention"]


@partial(jax.jit, static_argnames=("interpret", "use_ref", "bk"))
def decode_attention(
    q: jax.Array,  # (B, H, D) flat query heads
    k: jax.Array,  # (B, KH, S, D)
    v: jax.Array,
    lengths: jax.Array,  # (B,)
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    bk: int | None = None,
) -> jax.Array:
    """One-token attention vs a (possibly partially filled) KV cache."""
    B, H, D = q.shape
    KH, S = k.shape[1], k.shape[2]
    group = H // KH
    qg = q.reshape(B, KH, group, D)
    lengths = lengths.reshape(B, 1).astype(jnp.int32)
    if use_ref:
        return _ref.decode_attention(qg, k, v, lengths).reshape(B, H, D)
    bk = min(_kernel.DEFAULT_BK, S) if bk is None else bk
    kp = common.pad_to(k, bk, axis=2)
    vp = common.pad_to(v, bk, axis=2)
    out = _kernel.decode_attention_pallas(
        qg, kp, vp, lengths, bk=bk, interpret=common.should_interpret(interpret)
    )
    return out.reshape(B, H, D)
