"""Pure-jnp oracle for flash-decode: masked softmax attention, one query."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention"]


def decode_attention(
    q: jax.Array,  # (B, KH, G, D)
    k: jax.Array,  # (B, KH, S, D)
    v: jax.Array,
    lengths: jax.Array,  # (B,) or (B, 1)
    *,
    sm_scale: float | None = None,
) -> jax.Array:
    D = q.shape[-1]
    S = k.shape[2]
    sm_scale = D ** -0.5 if sm_scale is None else sm_scale
    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    live = jnp.arange(S)[None, :] < lengths.reshape(-1, 1)  # (B, S)
    s = jnp.where(live[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)
