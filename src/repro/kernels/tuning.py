"""Measured kernel crossover thresholds — one source of truth.

Every ``"auto"`` resolver (``common.resolve_dispatch``, the push-back method
resolution in ``core/ggarray`` and ``serving/kvcache``) and every benchmark
sweep that brackets a crossover (``benchmarks/bench_kernels.py``,
``benchmarks/bench_append.py``) imports the constants from here, so a re-tune
is a one-line edit that kernels and benchmarks see simultaneously —
``tests/kernels/test_crossovers.py`` pins both sides to this module.

The values are **empirical**, re-measured for this revision in interpret
mode (the container/CI substrate; re-run the sweeps on real hardware and
edit here when a TPU is available):

* fused push-back vs. the jnp scan+scatter path: the fused kernel's launch
  overhead dominates below ~32 inserted lanes per block and it loses at any
  capacity (0.1–0.8×, worst at the decode wave ``m=1``); from ``m=32`` it is
  ≥1× everywhere measured and grows to 3–17× by ``m=128``.  Hence
  :data:`FUSED_PUSH_BACK_MIN_WAVE` = 32 — this pins the serving decode
  append (one lane per sequence) to the scan path, closing the 0.08×-at-
  n=256 regression BENCH_append recorded.
* MXU dispatch matmul vs. the exact one-hot reduction: at ``m=128`` the
  emulated matmul is decisively slower (the 6× regression BENCH_kernels
  recorded); parity arrives at ``m≈256`` and holds above.  Hence
  :data:`MXU_DISPATCH_WAVE` = 256, raised from the a-priori 128 (one MXU
  lane tile) the previous revision shipped.
"""
from __future__ import annotations

__all__ = [
    "FUSED_PUSH_BACK_MIN_WAVE",
    "MXU_DISPATCH_WAVE",
    "resolve_push_back_method",
]

# Smallest per-block wave width m at which the fused Pallas push-back beats
# the jnp scan+scatter fallback (measured: 0.15× at m=1, ~1× at m=32,
# 7–17× at m=128).
FUSED_PUSH_BACK_MIN_WAVE = 32

# Smallest wave width at which the MXU dispatch matmul beats the exact
# one-hot reduction for the insert permutation (measured: 0.5× at m=128,
# ~1.05× from m=256).
MXU_DISPATCH_WAVE = 256


def resolve_push_back_method(method: str, m: int) -> str:
    """Resolve ``method="auto"`` for an ``m``-lane push-back wave.

    Explicit methods pass through untouched; ``"auto"`` picks the fused
    Pallas kernel at or above :data:`FUSED_PUSH_BACK_MIN_WAVE` lanes and the
    jnp scan+scatter path below it (launch overhead dominates small waves —
    the serving decode append is ``m=1``).
    """
    if method != "auto":
        return method
    return "fused" if m >= FUSED_PUSH_BACK_MIN_WAVE else "scan"
