"""Pure-jnp oracle for the flatten kernels (mirrors core.ggarray.flatten)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import indexing

__all__ = ["compact_blocks", "flatten_global", "gather_global"]


def compact_blocks(buckets: tuple[jax.Array, ...], b0: int) -> jax.Array:
    """(levels of (nblocks, size_b)) → (nblocks, capacity) row-major."""
    return jnp.concatenate(buckets, axis=1)


def flatten_global(compact: jax.Array, sizes: jax.Array) -> jax.Array:
    """Row-compacted (nblocks, cap) → block-major global order (nblocks·cap,)."""
    nblocks, cap = compact.shape
    starts = indexing.block_starts(sizes)
    posn = jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = posn < sizes[:, None]
    tgt = jnp.where(live, starts[:, None] + posn, nblocks * cap)
    out = jnp.zeros((nblocks * cap,), compact.dtype)
    return out.at[tgt].set(compact, mode="drop")


def gather_global(compact: jax.Array, starts: jax.Array, ends: jax.Array) -> jax.Array:
    """Gather-formulation oracle for the segmented kernel (same index math)."""
    nblocks, cap = compact.shape
    idx = jnp.arange(nblocks * cap, dtype=jnp.int32)
    blk = jnp.sum((idx[:, None] >= starts[None, :]).astype(jnp.int32), axis=1) - 1
    blk = jnp.maximum(blk, 0)
    pos = idx - starts[blk]
    live = idx < ends[blk]
    vals = compact.reshape(-1)[blk * cap + jnp.minimum(pos, cap - 1)]
    return jnp.where(live, vals, jnp.zeros_like(vals))
