"""jit'd flatten: compact kernel + one-hot dispatch matmul for global order."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import indexing
from repro.kernels import common
from repro.kernels.dispatch_mxu import ops as dispatch_ops
from repro.kernels.flatten import kernel as _kernel
from repro.kernels.flatten import ref as _ref

__all__ = ["compact_blocks", "flatten"]


@partial(jax.jit, static_argnames=("b0", "interpret", "use_ref"))
def compact_blocks(
    buckets: tuple[jax.Array, ...],
    b0: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    if use_ref:
        return _ref.compact_blocks(buckets, b0)
    nblocks = buckets[0].shape[0]
    tile = _kernel.DEFAULT_BLOCK_TILE
    pad = (-nblocks) % tile
    if pad:
        buckets = tuple(common.pad_to(b, tile, axis=0) for b in buckets)
    out = _kernel.compact_blocks_pallas(
        buckets, b0, interpret=common.should_interpret(interpret)
    )
    return out[:nblocks]


@partial(jax.jit, static_argnames=("b0", "interpret", "use_ref"))
def flatten(
    buckets: tuple[jax.Array, ...],
    sizes: jax.Array,
    b0: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Full GGArray flatten on kernels: compact + dispatch scatter-matmul."""
    compact = compact_blocks(buckets, b0, interpret=interpret, use_ref=use_ref)
    nblocks, cap = compact.shape
    starts = indexing.block_starts(sizes)
    posn = jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = posn < sizes[:, None]
    pos = jnp.where(live, starts[:, None] + posn, -1).reshape(-1)
    vals = compact.reshape(-1, 1)
    out = dispatch_ops.dispatch(
        vals, pos, nblocks * cap, interpret=interpret, use_ref=use_ref
    )
    return out[:, 0]
