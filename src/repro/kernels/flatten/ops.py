"""jit'd flatten: compact kernel + global ordering (segmented gather or matmul).

Two global-ordering implementations sit behind ``flatten(..., impl=...)``:

``"segmented"`` (default)
    Tiled segmented gather keyed off the ``block_starts`` prefix sums —
    O(n) work, the freeze path of the two-phase runtime (DESIGN.md §2).

``"dispatch"``
    The legacy one-hot dispatch matmul (kernels/dispatch_mxu) — O(n²) work;
    kept as the MXU comparison point for ``benchmarks/bench_two_phase.py``.

``memory_space`` selects the kernel tiling (``common.resolve_memory_space``:
explicit > ``REPRO_MEMORY_SPACE`` > hbm on TPU / vmem in interpret mode);
the hbm tiling keeps the compacted plane in HBM with the prefix tables as
scalar-prefetch operands (the ``"dispatch"`` ordering is vmem-only legacy —
``memory_space`` there applies to the compaction stage).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import indexing
from repro.kernels import common
from repro.kernels.dispatch_mxu import ops as dispatch_ops
from repro.kernels.flatten import kernel as _kernel
from repro.kernels.flatten import ref as _ref
from repro.obs import device

__all__ = ["compact_blocks", "flatten", "flatten_segmented", "flatten_dispatch"]


def _seg_ctr_oracle(starts, ends, nblocks: int, cap: int) -> jax.Array:
    """jnp oracle for the segmented-gather device counters: per output tile,
    the block span ``[lo_t, hi_t)`` the kernel walks (same prefix-table
    arithmetic as the hbm tiling's precomputed spans)."""
    seg_tile = _kernel.DEFAULT_SEG_TILE
    ntiles = -(-(nblocks * cap) // seg_tile)
    tbase = jnp.arange(ntiles, dtype=jnp.int32) * seg_tile
    lo = jnp.maximum(jnp.sum(starts[None, :] <= tbase[:, None], axis=1) - 1, 0)
    hi = jnp.sum(starts[None, :] <= (tbase + seg_tile - 1)[:, None], axis=1)
    return device.pack(**{
        "flatten.launches": 1,
        "flatten.rows_touched": jnp.sum(hi - lo),
        "flatten.span_rows": jnp.sum(ends - starts),
    })


@partial(jax.jit, static_argnames=("b0", "interpret", "use_ref", "memory_space"))
def compact_blocks(
    buckets: tuple[jax.Array, ...],
    b0: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
) -> jax.Array:
    if use_ref:
        return _ref.compact_blocks(buckets, b0)
    nblocks = buckets[0].shape[0]
    tile = _kernel.DEFAULT_BLOCK_TILE
    pad = (-nblocks) % tile
    if pad:
        buckets = tuple(common.pad_to(b, tile, axis=0) for b in buckets)
    out = _kernel.compact_blocks_pallas(
        buckets,
        b0,
        memory_space=common.resolve_memory_space(memory_space, interpret),
        interpret=common.should_interpret(interpret),
    )
    return out[:nblocks]


@partial(
    jax.jit,
    static_argnames=("b0", "interpret", "use_ref", "memory_space", "instrument"),
)
def flatten_segmented(
    buckets: tuple[jax.Array, ...],
    sizes: jax.Array,
    b0: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
    instrument: bool = False,
):
    """GGArray flatten: compact + linear-time segmented gather.

    ``instrument=True`` → (out, device counter vector): ``rows_touched``
    from the in-kernel block (jnp oracle under ``use_ref``), ``span_rows``
    (= Σ sizes, the information bound) from the prefix table here.
    """
    compact = compact_blocks(
        buckets, b0, interpret=interpret, use_ref=use_ref,
        memory_space=memory_space,
    )
    nblocks, cap = compact.shape
    starts = indexing.block_starts(sizes).astype(jnp.int32)
    ends = starts + sizes.astype(jnp.int32)
    if use_ref:
        out = _ref.gather_global(compact, starts, ends)
        if instrument:
            return out, _seg_ctr_oracle(starts, ends, nblocks, cap)
        return out
    outs = _kernel.segmented_gather_pallas(
        compact,
        starts,
        ends,
        memory_space=common.resolve_memory_space(memory_space, interpret),
        instrument=instrument,
        interpret=common.should_interpret(interpret),
    )
    if instrument:
        vec = device.from_block(outs[1]) + device.pack(
            **{"flatten.span_rows": jnp.sum(ends - starts)}
        )
        return outs[0], vec
    return outs


@partial(jax.jit, static_argnames=("b0", "interpret", "use_ref", "memory_space"))
def flatten_dispatch(
    buckets: tuple[jax.Array, ...],
    sizes: jax.Array,
    b0: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    memory_space: str | None = None,
) -> jax.Array:
    """GGArray flatten: compact + one-hot dispatch scatter-matmul (legacy)."""
    compact = compact_blocks(
        buckets, b0, interpret=interpret, use_ref=use_ref,
        memory_space=memory_space,
    )
    nblocks, cap = compact.shape
    starts = indexing.block_starts(sizes)
    posn = jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = posn < sizes[:, None]
    pos = jnp.where(live, starts[:, None] + posn, -1).reshape(-1)
    vals = compact.reshape(-1, 1)
    out = dispatch_ops.dispatch(
        vals, pos, nblocks * cap, interpret=interpret, use_ref=use_ref
    )
    return out[:, 0]


@partial(
    jax.jit,
    static_argnames=(
        "b0", "interpret", "use_ref", "impl", "memory_space", "instrument",
    ),
)
def flatten(
    buckets: tuple[jax.Array, ...],
    sizes: jax.Array,
    b0: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
    impl: str = "segmented",
    memory_space: str | None = None,
    instrument: bool = False,
):
    """Full GGArray flatten on kernels → (nblocks·cap,) block-major order."""
    if impl == "segmented":
        return flatten_segmented(
            buckets, sizes, b0, interpret=interpret, use_ref=use_ref,
            memory_space=memory_space, instrument=instrument,
        )
    if impl == "dispatch" and instrument:
        # legacy matmul ordering has no in-kernel plane; report the bound
        out = flatten_dispatch(
            buckets, sizes, b0, interpret=interpret, use_ref=use_ref,
            memory_space=memory_space,
        )
        return out, device.pack(**{
            "flatten.launches": 1,
            "flatten.span_rows": jnp.sum(sizes.astype(jnp.int32)),
        })
    if impl == "dispatch":
        return flatten_dispatch(
            buckets, sizes, b0, interpret=interpret, use_ref=use_ref,
            memory_space=memory_space,
        )
    raise ValueError(f"unknown flatten impl {impl!r} (want 'segmented'|'dispatch')")
