"""Bucket-compaction + segmented-gather kernels — GGArray flatten (§VI.D).

The two-phase pattern flattens the bucket chain into a contiguous array once
per growth phase.  Per-block compaction is *fully static*: bucket level ``b``
always lands at column ``B0·(2^b − 1)`` of the per-block row (the LFVector
address map), so that kernel is a pure VMEM copy with static offsets — one
grid step per block tile, all levels copied inside the body.

The dynamic part — block-major global ordering by the runtime prefix table —
has two implementations:

``segmented_gather_pallas`` (the default, O(n))
    One grid step per output tile.  Each output index ``i`` belongs to the
    block whose ``block_starts`` interval contains it; with ``nblocks``
    prefix sums resident on-chip, locating the owner is a broadcasted
    compare-and-count (a vectorized ``searchsorted``), and the element itself
    is a single gather from the compacted rows.  Work is
    O(capacity · log-ish nblocks) — linear in the array, unlike the one-hot
    dispatch matmul which multiplies a (T × S) one-hot against the data and
    is quadratic in the element count.  This is what lets the freeze step of
    the two-phase runtime run at copy speed (DESIGN.md §2).

``dispatch_mxu`` (legacy, O(n²))
    Reuses the one-hot scatter matmul kernel, kept as a comparison point for
    ``benchmarks/bench_two_phase.py`` and as the MXU-friendly fallback.

VMEM note: the gather kernel keeps the whole compacted ``(nblocks, cap)``
plane plus the tiny ``(nblocks,)`` prefix tables resident per grid step.  A
production variant would leave ``compact`` in HBM and DMA only the block rows
an output tile spans (scalar-prefetched ``block_starts`` make those bounds
computable before the body runs); the grid/index math is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import indexing

__all__ = ["compact_blocks_pallas", "segmented_gather_pallas"]

DEFAULT_BLOCK_TILE = 8
DEFAULT_SEG_TILE = 256


def _compact_kernel(*refs, starts):
    """refs = (*level_refs, out_ref); copy each level to its static columns."""
    *levels, out = refs
    for b, ref in enumerate(levels):
        size = ref.shape[1]
        out[:, starts[b] : starts[b] + size] = ref[...]


def compact_blocks_pallas(
    buckets: tuple[jax.Array, ...],  # level b: (nblocks, B0·2^b)
    b0: int,
    *,
    block_tile: int = DEFAULT_BLOCK_TILE,
    interpret: bool = False,
) -> jax.Array:
    """→ (nblocks, capacity) row-compacted array (in-block positions)."""
    nblocks = buckets[0].shape[0]
    nbuckets = len(buckets)
    if nblocks % block_tile:
        raise ValueError(f"nblocks {nblocks} must divide by tile {block_tile}")
    cap = indexing.capacity(b0, nbuckets)
    starts = indexing.bucket_starts(b0, nbuckets)
    sizes = indexing.bucket_sizes(b0, nbuckets)
    kernel = functools.partial(_compact_kernel, starts=starts)
    return pl.pallas_call(
        kernel,
        grid=(nblocks // block_tile,),
        in_specs=[
            pl.BlockSpec((block_tile, sz), lambda i, s=None: (i, 0)) for sz in sizes
        ],
        out_specs=pl.BlockSpec((block_tile, cap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, cap), buckets[0].dtype),
        interpret=interpret,
    )(*buckets)


def _segmented_gather_kernel(starts_ref, ends_ref, compact_ref, o_ref, *, seg_tile):
    """One output tile of the block-major global order.

    ``starts``/``ends`` are the runtime prefix-sum table (exclusive /
    inclusive-end per block); ``compact`` is the row-compacted plane.  The
    owning block of output index ``i`` is ``#{b : starts[b] <= i} - 1`` —
    valid because starts is non-decreasing with starts[0] == 0.
    """
    t = pl.program_id(0)
    nblocks, cap = compact_ref.shape
    idx = t * seg_tile + jax.lax.broadcasted_iota(jnp.int32, (seg_tile, 1), 0)[:, 0]
    starts = starts_ref[0, :]  # (nblocks,)
    ends = ends_ref[0, :]
    # Vectorized searchsorted over the on-chip prefix table: (seg_tile, nblocks)
    # compares, then a lane reduction — O(nblocks) per element, no matmul.
    owned = idx[:, None] >= starts[None, :]
    blk = jnp.sum(owned.astype(jnp.int32), axis=1) - 1
    blk = jnp.maximum(blk, 0)
    pos = idx - jnp.take(starts, blk)
    live = idx < jnp.take(ends, blk)
    # Single gather from the compacted plane (linearized to one axis).
    lin = blk * cap + jnp.minimum(pos, cap - 1)
    vals = jnp.take(compact_ref[...].reshape(-1), lin)
    o_ref[0, :] = jnp.where(live, vals, jnp.zeros_like(vals))


def segmented_gather_pallas(
    compact: jax.Array,  # (nblocks, cap) row-compacted in-block positions
    starts: jax.Array,  # (nblocks,) int32 exclusive prefix sums of sizes
    ends: jax.Array,  # (nblocks,) int32 starts + sizes
    *,
    seg_tile: int = DEFAULT_SEG_TILE,
    interpret: bool = False,
) -> jax.Array:
    """→ (nblocks·cap,) live elements in block-major global order, rest 0.

    The grid covers ``ceil(total / seg_tile)`` tiles; overhang indices in the
    last tile clamp to the final slot and fail the liveness test, so no input
    padding is needed for non-tile-aligned capacities.
    """
    nblocks, cap = compact.shape
    total = nblocks * cap
    total_pad = -(-total // seg_tile) * seg_tile
    out = pl.pallas_call(
        functools.partial(_segmented_gather_kernel, seg_tile=seg_tile),
        grid=(total_pad // seg_tile,),
        in_specs=[
            pl.BlockSpec((1, nblocks), lambda t: (0, 0)),
            pl.BlockSpec((1, nblocks), lambda t: (0, 0)),
            pl.BlockSpec((nblocks, cap), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seg_tile), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((1, total_pad), compact.dtype),
        interpret=interpret,
    )(
        starts.reshape(1, nblocks).astype(jnp.int32),
        ends.reshape(1, nblocks).astype(jnp.int32),
        compact,
    )
    return out[0, :total]
