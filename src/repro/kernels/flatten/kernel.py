"""Bucket-compaction + segmented-gather kernels — GGArray flatten (§VI.D).

The two-phase pattern flattens the bucket chain into a contiguous array once
per growth phase.  Per-block compaction is *fully static*: bucket level ``b``
always lands at column ``B0·(2^b − 1)`` of the per-block row (the LFVector
address map), so that kernel is a pure copy with static offsets.

The dynamic part — block-major global ordering by the runtime prefix table —
has two implementations:

``segmented_gather_pallas`` (the default, O(n))
    One grid step per output tile.  Each output index ``i`` belongs to the
    block whose ``block_starts`` interval contains it; locating the owner is
    a broadcasted compare-and-count against the (tiny) prefix table (a
    vectorized ``searchsorted``), and the element itself is a single gather
    from the compacted rows.  Work is O(capacity · log-ish nblocks) — linear
    in the array, unlike the one-hot dispatch matmul which multiplies a
    (T × S) one-hot against the data and is quadratic in the element count.
    This is what lets the freeze step of the two-phase runtime run at copy
    speed (DESIGN.md §2).

``dispatch_mxu`` (legacy, O(n²))
    Reuses the one-hot scatter matmul kernel, kept as a comparison point for
    ``benchmarks/bench_two_phase.py`` and as the MXU-friendly fallback.

Memory spaces (``common.GridPlan``, DESIGN.md §4.7): the ``vmem`` tilings
keep the whole compacted ``(nblocks, cap)`` plane (gather) / every level's
block-tile rows (compaction) resident per grid step.  On the ``hbm`` path
the prefix tables ride as scalar-prefetch operands and the planes stay in
HBM: compaction becomes a pure HBM→HBM DMA program (level rows → their
static columns), and the gather DMAs, per output tile, exactly the block
rows that tile spans — the span bounds ``[lo_t, hi_t)`` are precomputed from
the prefix table (``ops``) and prefetched, so the dynamic-trip row loop
costs sum-of-spans ≈ nblocks + ntiles DMAs total.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import indexing
from repro.kernels import common
from repro.obs import device

__all__ = ["compact_blocks_pallas", "segmented_gather_pallas"]


def _seg_ctr(ctr_ref, t, lo, hi):
    """One gather tile's device counters: ``rows_touched`` is this tile's
    block span ``hi − lo`` — exactly the rows the hbm tiling DMAs (the vmem
    tiling computes the same span from the prefix table, so the counter is
    space-invariant)."""
    first = t == 0
    device.ctr_accum(ctr_ref, first, [
        ("flatten.launches", jnp.where(first, 1, 0)),
        ("flatten.rows_touched", hi - lo),
    ])

DEFAULT_BLOCK_TILE = 8
DEFAULT_SEG_TILE = 256


# --------------------------------------------------------------------------
# compaction — bucket levels → (nblocks, capacity) rows, static columns.
# --------------------------------------------------------------------------

def _compact_vmem(*refs, starts):
    """refs = (*level_refs, out_ref); copy each level to its static columns."""
    *levels, out = refs
    for b, ref in enumerate(levels):
        size = ref.shape[1]
        out[:, starts[b] : starts[b] + size] = ref[...]


def _compact_hbm(*refs, starts, sizes, block_tile):
    """Pure DMA program: level rows → their static output columns (HBM→HBM)."""
    *levels, out, sem = refs
    i = pl.program_id(0)
    rows = pl.ds(i * block_tile, block_tile)
    for b, ref in enumerate(levels):
        cp = pltpu.make_async_copy(
            ref.at[rows],
            out.at[rows, pl.ds(starts[b], sizes[b])],
            sem,
        )
        cp.start()
        cp.wait()


def compact_blocks_pallas(
    buckets: tuple[jax.Array, ...],  # level b: (nblocks, B0·2^b)
    b0: int,
    *,
    block_tile: int = DEFAULT_BLOCK_TILE,
    memory_space: str = "vmem",
    interpret: bool = False,
) -> jax.Array:
    """→ (nblocks, capacity) row-compacted array (in-block positions)."""
    nblocks = buckets[0].shape[0]
    nbuckets = len(buckets)
    if nblocks % block_tile:
        raise ValueError(f"nblocks {nblocks} must divide by tile {block_tile}")
    cap = indexing.capacity(b0, nbuckets)
    starts = indexing.bucket_starts(b0, nbuckets)
    sizes = indexing.bucket_sizes(b0, nbuckets)
    out_shape = jax.ShapeDtypeStruct((nblocks, cap), buckets[0].dtype)
    if memory_space == "hbm":
        any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        plan = common.GridPlan(
            memory_space="hbm",
            grid=(nblocks // block_tile,),
            num_tables=0,
            table_specs=(),
            in_specs=[any_spec] * nbuckets,
            out_specs=any_spec,
            scratch_shapes=[pltpu.SemaphoreType.DMA],
        )
        kernel = functools.partial(
            _compact_hbm, starts=starts, sizes=sizes, block_tile=block_tile
        )
        return plan.pallas_call(kernel, out_shape, interpret=interpret)(*buckets)
    plan = common.GridPlan(
        memory_space="vmem",
        grid=(nblocks // block_tile,),
        num_tables=0,
        table_specs=(),
        in_specs=[
            pl.BlockSpec((block_tile, sz), lambda i, s=None: (i, 0)) for sz in sizes
        ],
        out_specs=pl.BlockSpec((block_tile, cap), lambda i: (i, 0)),
    )
    kernel = functools.partial(_compact_vmem, starts=starts)
    return plan.pallas_call(kernel, out_shape, interpret=interpret)(*buckets)


# --------------------------------------------------------------------------
# segmented gather — block-major global ordering off the prefix table.
# --------------------------------------------------------------------------

def _seg_gather_vmem(
    starts_ref, ends_ref, compact_ref, *refs, seg_tile, instrument=False,
):
    o_ref = refs[0]
    """One output tile of the block-major global order.

    ``starts``/``ends`` are the runtime prefix-sum table (exclusive /
    inclusive-end per block); ``compact`` is the row-compacted plane.  The
    owning block of output index ``i`` is ``#{b : starts[b] <= i} - 1`` —
    valid because starts is non-decreasing with starts[0] == 0.
    """
    t = pl.program_id(0)
    nblocks, cap = compact_ref.shape
    idx = t * seg_tile + jax.lax.broadcasted_iota(jnp.int32, (seg_tile, 1), 0)[:, 0]
    starts = starts_ref[0, :]  # (nblocks,)
    ends = ends_ref[0, :]
    # Vectorized searchsorted over the on-chip prefix table: (seg_tile, nblocks)
    # compares, then a lane reduction — O(nblocks) per element, no matmul.
    owned = idx[:, None] >= starts[None, :]
    blk = jnp.sum(owned.astype(jnp.int32), axis=1) - 1
    blk = jnp.maximum(blk, 0)
    pos = idx - jnp.take(starts, blk)
    live = idx < jnp.take(ends, blk)
    # Single gather from the compacted plane (linearized to one axis).
    lin = blk * cap + jnp.minimum(pos, cap - 1)
    vals = jnp.take(compact_ref[...].reshape(-1), lin)
    o_ref[0, :] = jnp.where(live, vals, jnp.zeros_like(vals))
    if instrument:
        tbase = t * seg_tile
        lo = jnp.maximum(jnp.sum((starts <= tbase).astype(jnp.int32)) - 1, 0)
        hi = jnp.sum((starts <= tbase + seg_tile - 1).astype(jnp.int32))
        _seg_ctr(refs[1], t, lo, hi)


def _seg_gather_hbm(
    starts_ref, ends_ref, lo_ref, hi_ref, compact_ref, *refs,
    seg_tile, instrument=False,
):
    o_ref, row, sem = refs[0], refs[-2], refs[-1]
    """One output tile, compact plane in HBM.

    The tile's block span ``[lo_t, hi_t)`` was precomputed from the prefix
    table; the dynamic-trip loop DMAs one block row at a time and claims the
    lanes whose global index falls inside that block's ``[start, end)``
    interval — intervals are disjoint, so each live lane is claimed exactly
    once and dead lanes keep the zero init.
    """
    t = pl.program_id(0)
    cap = compact_ref.shape[1]
    idx = t * seg_tile + jax.lax.broadcasted_iota(jnp.int32, (seg_tile, 1), 0)[:, 0]

    def claim(b, acc):
        cp = pltpu.make_async_copy(compact_ref.at[pl.ds(b, 1)], row, sem)
        cp.start()
        cp.wait()
        s, e = starts_ref[b], ends_ref[b]
        take = (idx >= s) & (idx < e)
        vals = jnp.take(row[0], jnp.clip(idx - s, 0, cap - 1))
        return jnp.where(take, vals, acc)

    zero = jnp.zeros((seg_tile,), o_ref.dtype)
    o_ref[0, :] = jax.lax.fori_loop(lo_ref[t], hi_ref[t], claim, zero)
    if instrument:
        _seg_ctr(refs[1], t, lo_ref[t], hi_ref[t])


def segmented_gather_pallas(
    compact: jax.Array,  # (nblocks, cap) row-compacted in-block positions
    starts: jax.Array,  # (nblocks,) int32 exclusive prefix sums of sizes
    ends: jax.Array,  # (nblocks,) int32 starts + sizes
    *,
    seg_tile: int = DEFAULT_SEG_TILE,
    memory_space: str = "vmem",
    instrument: bool = False,
    interpret: bool = False,
):
    """→ (nblocks·cap,) live elements in block-major global order, rest 0.

    The grid covers ``ceil(total / seg_tile)`` tiles; overhang indices in the
    last tile clamp to the final slot and fail the liveness test, so no input
    padding is needed for non-tile-aligned capacities.  With
    ``instrument=True`` → (out, counter block).
    """
    nblocks, cap = compact.shape
    total = nblocks * cap
    ntiles = -(-total // seg_tile)
    total_pad = ntiles * seg_tile
    starts = starts.reshape(nblocks).astype(jnp.int32)
    ends = ends.reshape(nblocks).astype(jnp.int32)
    out_shape = jax.ShapeDtypeStruct((1, total_pad), compact.dtype)
    if memory_space == "hbm":
        # per-tile block spans off the prefix table (ops-level jnp, tiny)
        tbase = jnp.arange(ntiles, dtype=jnp.int32) * seg_tile
        lo = jnp.maximum(
            jnp.sum(starts[None, :] <= tbase[:, None], axis=1) - 1, 0
        )
        hi = jnp.sum(starts[None, :] <= (tbase + seg_tile - 1)[:, None], axis=1)
        plan = common.GridPlan(
            memory_space="hbm",
            grid=(ntiles,),
            num_tables=4,
            table_specs=(),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=pl.BlockSpec((1, seg_tile), lambda t, s, e, lo, hi: (0, t)),
            scratch_shapes=[
                pltpu.VMEM((1, cap), compact.dtype),
                pltpu.SemaphoreType.DMA,
            ],
            instrument=instrument,
        )
        kernel = functools.partial(
            _seg_gather_hbm, seg_tile=seg_tile, instrument=instrument
        )
        outs = plan.pallas_call(kernel, out_shape, interpret=interpret)(
            starts, ends, lo, hi, compact
        )
        if instrument:
            return outs[0][0, :total], outs[1]
        return outs[0, :total]
    plan = common.GridPlan(
        memory_space="vmem",
        grid=(ntiles,),
        num_tables=2,
        table_specs=[
            pl.BlockSpec((1, nblocks), lambda t: (0, 0)),
            pl.BlockSpec((1, nblocks), lambda t: (0, 0)),
        ],
        in_specs=[pl.BlockSpec((nblocks, cap), lambda t: (0, 0))],
        out_specs=pl.BlockSpec((1, seg_tile), lambda t: (0, t)),
        instrument=instrument,
    )
    kernel = functools.partial(
        _seg_gather_vmem, seg_tile=seg_tile, instrument=instrument
    )
    outs = plan.pallas_call(kernel, out_shape, interpret=interpret)(
        starts.reshape(1, nblocks), ends.reshape(1, nblocks), compact
    )
    if instrument:
        return outs[0][0, :total], outs[1]
    return outs[0, :total]
