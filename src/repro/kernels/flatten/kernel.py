"""Bucket-compaction kernel — GGArray flatten's TPU hot phase (paper §VI.D).

The two-phase pattern flattens the bucket chain into a contiguous array once
per growth phase.  Per-block compaction is *fully static*: bucket level ``b``
always lands at column ``B0·(2^b − 1)`` of the per-block row (the LFVector
address map), so the kernel is a pure VMEM copy with static offsets — one
grid step per block tile, all levels copied inside the body.  The dynamic
part (block-major global ordering by the runtime prefix table) reuses the
one-hot dispatch matmul kernel (kernels/dispatch_mxu), as push_back does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import indexing

__all__ = ["compact_blocks_pallas"]

DEFAULT_BLOCK_TILE = 8


def _compact_kernel(*refs, starts):
    """refs = (*level_refs, out_ref); copy each level to its static columns."""
    *levels, out = refs
    for b, ref in enumerate(levels):
        size = ref.shape[1]
        out[:, starts[b] : starts[b] + size] = ref[...]


def compact_blocks_pallas(
    buckets: tuple[jax.Array, ...],  # level b: (nblocks, B0·2^b)
    b0: int,
    *,
    block_tile: int = DEFAULT_BLOCK_TILE,
    interpret: bool = False,
) -> jax.Array:
    """→ (nblocks, capacity) row-compacted array (in-block positions)."""
    nblocks = buckets[0].shape[0]
    nbuckets = len(buckets)
    if nblocks % block_tile:
        raise ValueError(f"nblocks {nblocks} must divide by tile {block_tile}")
    cap = indexing.capacity(b0, nbuckets)
    starts = indexing.bucket_starts(b0, nbuckets)
    sizes = indexing.bucket_sizes(b0, nbuckets)
    kernel = functools.partial(_compact_kernel, starts=starts)
    return pl.pallas_call(
        kernel,
        grid=(nblocks // block_tile,),
        in_specs=[
            pl.BlockSpec((block_tile, sz), lambda i, s=None: (i, 0)) for sz in sizes
        ],
        out_specs=pl.BlockSpec((block_tile, cap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, cap), buckets[0].dtype),
        interpret=interpret,
    )(*buckets)
