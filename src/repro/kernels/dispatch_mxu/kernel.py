"""One-hot dispatch/combine matmul kernels — push_back's write phase on MXU.

After the insertion scan assigns each element a unique slot, the write itself
is a scatter.  TPUs hate element-wise scatters but love matmuls, so we express
the write as ``out = Pᵀ·X`` with ``P[t, s] = 1`` iff element ``t`` goes to slot
``s`` — built on the fly from the slot vector, one VMEM tile at a time.  This
is the same trick classic MoE layers use for token dispatch, which is why the
MoE substrate (models/moe.py) and GGArray's bulk push_back share this kernel
(DESIGN.md §3).

``dispatch``: (T, D) values + (T,) slots → (S, D) buffer   (scatter, Pᵀ·X)
``combine`` : (S, D) buffer + (T,) slots → (T, D) values   (gather,  P·B)

Grid iterates destination tiles in the leading dim and accumulates over source
tiles in the (sequential) trailing dim; negative slots are dropped.

``permute_rows`` exposes the same one-hot-matmul trick as an *in-body*
building block: the push_back / slab-append kernels call it to apply their
insert permutation on the MXU when the wave is at least a lane tile wide
(``common.MXU_DISPATCH_WAVE``), instead of the exact int32 one-hot reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dispatch_pallas", "combine_pallas", "permute_rows"]


def permute_rows(onehot: jax.Array, elems: jax.Array) -> jax.Array:
    """Apply a per-row insert permutation as an MXU matmul: ``P·X``.

    ``onehot: (rows, m, m) bool`` with ``onehot[r, o, k]`` = "slot ``o`` takes
    wave lane ``k``" (at most one ``k`` per ``o``); ``elems: (rows, m, D)``.
    Returns ``(rows, m, D)`` in ``elems.dtype``.  Each output row of the
    matmul has exactly one nonzero term (value · 1.0, the rest value · 0.0),
    so the f32 accumulation is **bit-exact** for any payload whose values are
    f32-representable — f32/bf16/f16 and narrow ints; wide ints past the f32
    mantissa are the caller's ``resolve_dispatch`` exclusion.  Slots no lane
    maps to come back 0 rather than the one-hot path's lane 0 — both are dead
    under the callers' ``o < count`` write guard.
    """
    p = onehot.astype(jnp.float32)
    x = elems.astype(jnp.float32)
    out = jax.lax.dot_general(
        p, x, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    return out.astype(elems.dtype)

DEFAULT_T_TILE = 128
DEFAULT_S_TILE = 128


def _dispatch_kernel(pos_ref, x_ref, o_ref, *, s_tile):
    s, t = pl.program_id(0), pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pos = pos_ref[...]  # (T_tile, 1)
    rel = pos - s * s_tile
    slots = jax.lax.broadcasted_iota(jnp.int32, (pos.shape[0], s_tile), 1)
    onehot = ((rel == slots) & (pos >= 0)).astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(onehot.T, x, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _combine_kernel(pos_ref, buf_ref, o_ref, *, s_tile):
    t, s = pl.program_id(0), pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pos = pos_ref[...]  # (T_tile, 1)
    rel = pos - s * s_tile
    slots = jax.lax.broadcasted_iota(jnp.int32, (pos.shape[0], s_tile), 1)
    onehot = ((rel == slots) & (pos >= 0)).astype(jnp.float32)
    buf = buf_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(onehot, buf, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def dispatch_pallas(
    x: jax.Array,  # (T, D)
    pos: jax.Array,  # (T, 1) int32, -1 = drop
    n_slots: int,
    *,
    t_tile: int = DEFAULT_T_TILE,
    s_tile: int = DEFAULT_S_TILE,
    interpret: bool = False,
) -> jax.Array:
    T, D = x.shape
    if T % t_tile or n_slots % s_tile:
        raise ValueError(f"unpadded: T={T} S={n_slots}; pad to ({t_tile},{s_tile})")
    import functools

    return pl.pallas_call(
        functools.partial(_dispatch_kernel, s_tile=s_tile),
        grid=(n_slots // s_tile, T // t_tile),
        in_specs=[
            pl.BlockSpec((t_tile, 1), lambda s, t: (t, 0)),
            pl.BlockSpec((t_tile, D), lambda s, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((s_tile, D), lambda s, t: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((n_slots, D), x.dtype),
        interpret=interpret,
    )(pos, x)


def combine_pallas(
    buf: jax.Array,  # (S, D)
    pos: jax.Array,  # (T, 1) int32, -1 = zeros
    n_out: int,
    *,
    t_tile: int = DEFAULT_T_TILE,
    s_tile: int = DEFAULT_S_TILE,
    interpret: bool = False,
) -> jax.Array:
    S, D = buf.shape
    if n_out % t_tile or S % s_tile:
        raise ValueError(f"unpadded: T={n_out} S={S}; pad to ({t_tile},{s_tile})")
    import functools

    return pl.pallas_call(
        functools.partial(_combine_kernel, s_tile=s_tile),
        grid=(n_out // t_tile, S // s_tile),
        in_specs=[
            pl.BlockSpec((t_tile, 1), lambda t, s: (t, 0)),
            pl.BlockSpec((s_tile, D), lambda t, s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((t_tile, D), lambda t, s: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, D), buf.dtype),
        interpret=interpret,
    )(pos, buf)
