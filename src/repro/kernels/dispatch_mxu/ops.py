"""jit'd wrappers for the one-hot dispatch/combine kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.dispatch_mxu import kernel as _kernel
from repro.kernels.dispatch_mxu import ref as _ref

__all__ = ["dispatch", "combine"]


@partial(jax.jit, static_argnames=("n_slots", "interpret", "use_ref"))
def dispatch(
    x: jax.Array,
    pos: jax.Array,
    n_slots: int,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Scatter ``x: (T, D)`` rows to ``pos: (T,)`` slots of a (n_slots, D) buffer."""
    if use_ref:
        return _ref.dispatch(x, pos, n_slots)
    T = x.shape[0]
    xp = common.pad_to(x, _kernel.DEFAULT_T_TILE, axis=0)
    pp = common.pad_to(pos.reshape(-1, 1).astype(jnp.int32), _kernel.DEFAULT_T_TILE, axis=0, value=-1)
    s_pad = -(-n_slots // _kernel.DEFAULT_S_TILE) * _kernel.DEFAULT_S_TILE
    out = _kernel.dispatch_pallas(
        xp, pp, s_pad, interpret=common.should_interpret(interpret)
    )
    return out[:n_slots]


@partial(jax.jit, static_argnames=("n_out", "interpret", "use_ref"))
def combine(
    buf: jax.Array,
    pos: jax.Array,
    n_out: int | None = None,
    *,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Gather rows of ``buf: (S, D)`` at ``pos: (T,)`` (zeros where pos < 0)."""
    n_out = pos.shape[0] if n_out is None else n_out
    if use_ref:
        return _ref.combine(buf, pos, n_out)
    bp = common.pad_to(buf, _kernel.DEFAULT_S_TILE, axis=0)
    pp = common.pad_to(pos.reshape(-1, 1).astype(jnp.int32), _kernel.DEFAULT_T_TILE, axis=0, value=-1)
    t_pad = pp.shape[0]
    out = _kernel.combine_pallas(
        bp, pp, t_pad, interpret=common.should_interpret(interpret)
    )
    return out[:n_out]
