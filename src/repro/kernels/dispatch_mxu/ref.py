"""Pure-jnp oracles for dispatch/combine (scatter-add / gather semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dispatch", "combine"]


def dispatch(x: jax.Array, pos: jax.Array, n_slots: int) -> jax.Array:
    """out[pos[t]] += x[t] for pos[t] >= 0 (matches the one-hot matmul)."""
    pos = pos.reshape(-1)
    tgt = jnp.where(pos >= 0, pos, n_slots)
    out = jnp.zeros((n_slots, x.shape[1]), dtype=x.dtype)
    return out.at[tgt].add(x, mode="drop")


def combine(buf: jax.Array, pos: jax.Array, n_out: int) -> jax.Array:
    """out[t] = buf[pos[t]] (zeros where pos < 0)."""
    pos = pos.reshape(-1)[:n_out]
    vals = buf[pos.clip(0, buf.shape[0] - 1)]
    return jnp.where((pos >= 0)[:, None], vals, jnp.zeros_like(vals))
