from repro.kernels.dispatch_mxu import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
