"""Activation-sharding context: model code asks, the launcher decides.

Model modules call ``constrain(x, ("batch", "seq", None))`` with *logical*
axis names; when a launcher has activated a mesh context the names resolve to
mesh axes (with per-dim divisibility checks), otherwise the call is a no-op —
so the same model code runs on a laptop CPU and a 512-chip mesh.

Logical activation axes:
  batch   → ('pod', 'data')                       (DP)
  seq     → 'model'                               (sequence parallelism: the
            period-boundary residual stream is seq-sharded, which is what
            keeps 64-layer × 1M-token activations inside HBM)
  tokens  → ('pod', 'data', 'model')              (flattened B·S, MoE routing)
  experts → 'model'                               (EP)
  heads   → 'model'
  kv_seq  → 'model'                               (decode cache seq dim)
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_mesh", "constrain", "active_mesh", "LOGICAL_AXES"]

LOGICAL_AXES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": "model",
    "tokens": ("pod", "data", "model"),
    "experts": "model",
    # flattened E·C dim, expert-major: E over 'model' (EP), capacity over the
    # data axes — one (expert-shard, capacity-shard) tile per device, so the
    # expert FFN intermediates scale down with the FULL mesh, not just EP.
    "expert_slots": ("model", "pod", "data"),
    "expert_cap": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "ssm_inner": "model",
    "kv_seq": "model",
    # logits vocab dim: sharding V over 'model' keeps the unembed backward's
    # per-device partial d(table) at (V/16, D) instead of a full (V, D) f32
    # partial per device (≈3 GB each on 150k vocabs; caught by the dry-run)
    "vocab": "model",
}

_STATE = threading.local()


def active_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def activation_mesh(mesh: Mesh | None):
    """Activate ``mesh`` for constrain() calls within the block."""
    prev = active_mesh()
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    out = 1
    for a in axes:
        if a not in mesh.shape:
            return 0  # axis absent on this mesh → cannot shard
        out *= mesh.shape[a]
    return out


def constrain_tree(tree, specs_tree):
    """with_sharding_constraint a pytree against PartitionSpecs; no-op
    without an active mesh. Used to pin gradient/accumulator shardings to
    the parameter layout (unconstrained f32 accumulators otherwise replicate
    and drag full param-shaped all-reduces into every microbatch)."""
    mesh = active_mesh()
    if mesh is None or specs_tree is None:
        return tree
    return jax.tree.map(
        lambda x, spec: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec)
        ),
        tree,
        specs_tree,
    )


def constrain(x: jax.Array, axes: tuple) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, name in zip(x.shape, axes):
        mesh_axis = LOGICAL_AXES.get(name) if name else None
        if isinstance(mesh_axis, tuple):
            mesh_axis = tuple(a for a in mesh_axis if a in mesh.shape) or None
        size = _axis_size(mesh, mesh_axis)
        spec.append(mesh_axis if mesh_axis and size > 0 and dim % size == 0 else None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
