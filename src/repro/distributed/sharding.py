"""Sharding rules: parameter-name → logical axes → mesh PartitionSpec.

Scheme (DESIGN.md §5):
- **TP** over ``'model'``: d_ff (all archs divide by 16), experts (all MoE
  archs have exactly 16), padded vocab, attention heads *when divisible*
  (else head_dim when divisible, else replicated — starcoder2's 24H and
  llama4's 40H fall back to head_dim=128).
- **FSDP** over ``'data'``: the d_model dim of every weight (all assigned
  d_models divide by 16), which also shards AdamW moments (ZeRO).
- **DP** over ``('pod', 'data')`` for the batch dim of activations.
- Decode KV caches shard batch over ``'data'`` and the *sequence* dim over
  ``'model'`` (flash-decode layout — a 32k×128-seq cache never fits
  replicated).
- Anything 1-D (norms, biases, scalars) is replicated.

Rules attach to the *last* ndims of each leaf so period-stacked layer params
(leading ``n_periods`` dim) reuse the per-layer rule unchanged.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = [
    "logical_rules",
    "param_specs",
    "param_shardings",
    "batch_specs",
    "data_axes",
    "shard_if_divisible",
]


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes carrying data parallelism: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def shard_if_divisible(mesh: Mesh, dim_size: int, axis) -> Any:
    """axis if dim divides over it, else None (replicate)."""
    return axis if axis is not None and dim_size % _axis_size(mesh, axis) == 0 else None


def logical_rules(cfg: ModelConfig, mesh: Mesh) -> dict[str, Any]:
    """Logical axis name → mesh axis (or None), with divisibility fallbacks."""
    model = "model" if "model" in mesh.shape else None
    fsdp = "data" if "data" in mesh.shape else None
    msize = _axis_size(mesh, model)
    heads_ok = model and cfg.n_heads % msize == 0
    kv_ok = model and cfg.n_kv_heads % msize == 0
    hd_ok = model and cfg.head_dim % msize == 0
    rules: dict[str, Any] = {
        "embed": shard_if_divisible(mesh, cfg.d_model, fsdp),
        "vocab": shard_if_divisible(mesh, cfg.padded_vocab, model),
        "ff": shard_if_divisible(mesh, cfg.d_ff, model) if cfg.d_ff else None,
        "heads": model if heads_ok else None,
        "head_dim": model if (not heads_ok and hd_ok) else None,
        "kv_heads": model if kv_ok else None,
        "kv_head_dim": model if (not kv_ok and hd_ok) else None,
        "experts": None,
        "ff_expert": None,
        "ssm_inner": None,
    }
    if cfg.moe is not None:
        rules["experts"] = shard_if_divisible(mesh, cfg.moe.n_experts, model)
        if rules["experts"] is None:  # fall back to TP inside each expert
            rules["ff_expert"] = shard_if_divisible(mesh, cfg.moe.d_ff_expert, model)
    if cfg.ssm is not None:
        rules["ssm_inner"] = shard_if_divisible(
            mesh, cfg.ssm.d_inner(cfg.d_model), model
        )
    return rules


# parameter name → logical axes of its *trailing* dims
_NAME_AXES: dict[str, tuple[str | None, ...]] = {
    "embed": ("vocab", "embed"),
    "unembed": ("vocab", "embed"),
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "kv_head_dim"),
    "wv": ("embed", "kv_heads", "kv_head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "w_gate": ("embed", "ff"),
    "w_up": ("embed", "ff"),
    "w_down": ("ff", "embed"),
    "router": ("embed", None),
    "wz": ("embed", "ssm_inner"),
    "wx": ("embed", "ssm_inner"),
    "wBC": ("embed", None),
    "wdt": ("embed", None),
    "out_proj": ("ssm_inner", "embed"),
}
# MoE expert tensors carry a leading experts dim
_MOE_NAME_AXES: dict[str, tuple[str | None, ...]] = {
    "w_gate": ("experts", "embed", "ff_expert"),
    "w_up": ("experts", "embed", "ff_expert"),
    "w_down": ("experts", "ff_expert", "embed"),
}


def _leaf_spec(path, leaf, rules) -> P:
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1] if keys else ""
    in_moe = "moe" in keys
    axes = (_MOE_NAME_AXES if in_moe and name in _MOE_NAME_AXES else _NAME_AXES).get(name)
    if axes is None or leaf.ndim < len(axes):
        return P()  # norms, biases, scalars, conv — replicated
    mesh_axes = tuple(rules.get(a) if a else None for a in axes)
    pad = (None,) * (leaf.ndim - len(mesh_axes))  # period-stacked leading dims
    return P(*pad, *mesh_axes)


def param_specs(params, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec pytree matching ``params`` (also fits AdamW m/v, EF)."""
    rules = logical_rules(cfg, mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, rules), params
    )


def constrain_param_tree(tree, cfg: ModelConfig):
    """Pin a (sub)tree of parameters to its rule shardings, ambient-mesh.

    Called INSIDE the period-scan body on the sliced layer params: the
    transpose of with_sharding_constraint is itself, so this also pins the
    per-period parameter *cotangents* inside the scan backward — without it
    GSPMD computes replicated f32 dW and all-reduces full param-shaped
    tensors over the TP axis every (microbatch × period) (§Perf).
    """
    from repro.distributed.context import active_mesh

    mesh = active_mesh()
    if mesh is None:
        return tree
    rules = logical_rules(cfg, mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, _leaf_spec(path, leaf, rules))
        ),
        tree,
    )


def param_shardings(params, cfg: ModelConfig, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params, cfg, mesh)
    )


def cache_specs(caches_like, cfg: ModelConfig, mesh: Mesh):
    """Decode-cache PartitionSpecs: batch→data, seq→model (flash-decode
    layout), mamba heads/channels→model — each with divisibility fallback."""
    dp = data_axes(mesh)
    model = "model" if "model" in mesh.shape else None

    def spec(path, leaf) -> P:
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1] if keys else ""
        b_axis = dp if dp and leaf.shape[1] % _axis_size(mesh, dp) == 0 else None
        if name == "conv":  # (P, B, W, CH)
            ch = shard_if_divisible(mesh, leaf.shape[-1], model)
            return P(None, b_axis, None, ch)
        if name == "ssd":  # (P, B, NH, HD, N)
            nh = shard_if_divisible(mesh, leaf.shape[2], model)
            return P(None, b_axis, nh, None, None)
        # k/v levels (P, B, L, KH, Dh) and their scale tensors (P, B, L, KH)
        seq = shard_if_divisible(mesh, leaf.shape[2], model)
        spec = (None, b_axis, seq) + (None,) * (leaf.ndim - 3)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec, caches_like)


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_size: int) -> dict[str, P]:
    """Input batch specs: batch dim over ('pod','data') when divisible."""
    dp = data_axes(mesh)
    b_axis = dp if dp and batch_size % _axis_size(mesh, dp) == 0 else None
    out = {"tokens": P(b_axis, None)}
    if cfg.n_enc_layers:
        out["frames"] = P(b_axis, None, None)
    elif cfg.n_prefix_embeds:
        out["prefix_embeds"] = P(b_axis, None, None)
    return out
