import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY in this process (dry-run).

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape) cell, on the 16×16 single-pod mesh and
the 2×16×16 multi-pod mesh:

    with mesh:
        lowered  = jax.jit(step).lower(*input_specs)   # sharded SDS, no alloc
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes → results JSON

Failures here (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the framework.  Results are cached per cell under results/dryrun/ so
the sweep is resumable; EXPERIMENTS.md §Dry-run / §Roofline read these files.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import gzip
import json
import time
import traceback

import jax

from repro.analysis import flops as flops_mod
from repro.analysis import roofline
from repro.configs import SHAPES, get as get_cfg
from repro.distributed.context import activation_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import Cell, build_cell, plan_cells

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _result_path(cell: Cell, multi_pod: bool, opt: bool = False) -> str:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{mesh_tag}__opt" if opt else mesh_tag
    return os.path.join(RESULTS_DIR, f"{cell.arch}__{cell.shape}__{tag}.json")


def run_cell(cell: Cell, *, multi_pod: bool, force: bool = False, opt: bool = False) -> dict:
    path = _result_path(cell, multi_pod, opt)
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    os.makedirs(RESULTS_DIR, exist_ok=True)

    out: dict = {
        "cell": cell.name,
        "arch": cell.arch,
        "shape": cell.shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    if cell.skip_reason:
        out["status"] = "skipped"
        out["skip_reason"] = cell.skip_reason
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    try:
        step, args, jit_kwargs = build_cell(cell, mesh, opt=opt)
        with mesh, activation_mesh(mesh):
            # scan-aware global FLOP/traffic count from the jaxpr (XLA's
            # cost_analysis counts while bodies once — see analysis/flops.py)
            jcount = flops_mod.count_fn(step, *args)
            lowered = jax.jit(step, **jit_kwargs).lower(*args)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        # keep the post-SPMD HLO for recompile-free re-analysis (§Perf)
        with gzip.open(path.replace(".json", ".hlo.txt.gz"), "wt") as f:
            f.write(hlo)
        coll = roofline.collective_bytes(hlo)
        per_dev = {
            "flops": jcount["flops"] / out["chips"],
            "bytes accessed": jcount["hbm_bytes"] / out["chips"],
        }
        terms = roofline.roofline_terms(per_dev, coll)
        shape = SHAPES[cell.shape]
        mf = roofline.model_flops(get_cfg(cell.arch), shape, out["chips"])
        hbm_used = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        out.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=int(mem.argument_size_in_bytes),
                output_bytes=int(mem.output_size_in_bytes),
                temp_bytes=int(mem.temp_size_in_bytes),
                alias_bytes=int(mem.alias_size_in_bytes),
                hbm_used_bytes=hbm_used,
                fits_16gb=bool(hbm_used < 16e9),
            ),
            cost_xla_scan_once={k: cost[k] for k in ("flops", "bytes accessed") if k in cost},
            cost_jaxpr_global={"flops": jcount["flops"], "hbm_bytes": jcount["hbm_bytes"]},
            collectives={k: round(v, 1) for k, v in coll.items()},
            roofline=terms,
            model_flops=mf,
            useful_flop_ratio=(
                mf["model_flops_per_device"] / terms["flops_per_device"]
                if terms["flops_per_device"] else None
            ),
        )
    except Exception as e:  # record the failure — these are framework bugs
        out.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true", help="§Perf optimized variants")
    args = ap.parse_args()

    cells = plan_cells()
    if not args.all:
        cells = [
            c for c in cells
            if (not args.arch or c.arch == args.arch)
            and (not args.shape or c.shape == args.shape)
        ]
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    failures = 0
    for cell in cells:
        for multi_pod in meshes:
            tag = "2x16x16" if multi_pod else "16x16"
            r = run_cell(cell, multi_pod=multi_pod, force=args.force, opt=args.opt)
            status = r["status"]
            extra = ""
            if status == "ok":
                extra = (
                    f" hbm/dev={r['memory']['hbm_used_bytes'] / 1e9:.2f}GB"
                    f" bound={r['roofline']['bound']}"
                    f" compile={r.get('compile_s', 0):.0f}s"
                )
            elif status == "error":
                failures += 1
                extra = " " + r["error"][:140]
            print(f"[{status:7s}] {cell.name:44s} mesh={tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
