"""Serving launcher: batched generation with a growth-on-demand KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --policy ggarray --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.models import transformer
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=configs.ARCH_NAMES)
    ap.add_argument("--policy", default="ggarray", choices=["static", "semistatic", "ggarray"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-len", type=int, default=512)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch, cache_b0=16)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, policy=args.policy, max_len=args.max_len)

    prompts = [[(7 * i + j) % cfg.vocab_size for j in range(3 + i)] for i in range(args.batch)]
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens, temperature=args.temperature)
    dt = time.perf_counter() - t0
    s = eng.stats
    tput = args.batch * args.new_tokens / dt
    print(f"policy={args.policy} tokens/s={tput:.1f} grow_events={s.grow_events} "
          f"copied={s.copied_bytes/1e6:.2f}MB allocated={s.allocated_bytes/1e6:.2f}MB "
          f"compiles={s.compiles}")
    for i, seq in enumerate(out[:2]):
        print(f"  seq{i}: {seq[:16]}...")


if __name__ == "__main__":
    main()
