"""Dry-run cell plan + step builders (assignment: MULTI-POD DRY-RUN steps 2–3).

``plan_cells()`` enumerates all 40 (arch × shape) cells with skip annotations;
``build_cell()`` returns a jit-able step function plus fully-sharded
ShapeDtypeStruct arguments — weak-type-correct stand-ins, no allocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, sub_quadratic_ready
from repro.data.synthetic import batch_spec
from repro.distributed import sharding as sh
from repro.models import encdec, transformer
from repro.optim import adamw
from repro.serving import steps as serve_steps
from repro.train import step as train_mod

__all__ = ["Cell", "plan_cells", "build_cell"]


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    skip_reason: str | None = None

    @property
    def name(self) -> str:
        return f"{self.arch}×{self.shape}"


def plan_cells() -> list[Cell]:
    cells = []
    for arch in configs.ARCH_NAMES:
        cfg = configs.get(arch)
        for shape_name, shape in SHAPES.items():
            skip = None
            if shape_name == "long_500k" and not sub_quadratic_ready(cfg):
                skip = "pure full attention: 500k decode needs sub-quadratic (DESIGN.md §6)"
            cells.append(Cell(arch, shape_name, skip))
    return cells


def _sds_with(tree_sds, tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        tree_sds,
        tree_specs,
    )


def _decode_length_hint(cfg: ModelConfig, shape: ShapeConfig) -> int:
    # serve_step: one new token with a cache of seq_len ⇒ capacity covers
    # seq_len + 1 under the active policy.
    return shape.seq_len + 1


def input_specs(arch: str, shape_name: str, mesh: Mesh, *, opt: bool = False) -> tuple:
    """ShapeDtypeStruct stand-ins for every model input of one cell
    (weak-type-correct, shardable, no device allocation) — assignment step 2."""
    _, args, _ = build_cell(Cell(arch, shape_name), mesh, opt=opt)
    return args


def build_cell(cell: Cell, mesh: Mesh, *, opt: bool = False) -> tuple[Callable, tuple, dict]:
    """→ (step_fn, sharded SDS args, jit kwargs) for jit(...).lower(*args).

    Donation aliases the big in-place buffers (train state / decode caches);
    prefill pins ``out_shardings`` for the emitted caches — the bucket slicing
    is not tile-aligned, so without explicit output specs GSPMD replicates
    the 32k KV cache across the model axis (18 GB/device, dry-run-caught).

    ``opt=True`` applies the §Perf hillclimb variants: triangular causal
    attention (prefill/train), int8 KV cache (decode), microbatches=2 (train).
    """
    cfg = configs.get(cell.arch)
    shape = SHAPES[cell.shape]
    microbatches = None
    if opt:
        if shape.kind == "decode":
            cfg = dataclasses.replace(cfg, cache_quant=True)
        else:
            cfg = dataclasses.replace(
                cfg, attention_impl="blockwise_tri", attention_chunk=2048
            )
        if shape.kind == "train":
            microbatches = 2
    if shape.kind == "train":
        fn, args = _build_train(cfg, shape, mesh, microbatches=microbatches)
        return fn, args, {"donate_argnums": (0,)}
    if shape.kind == "prefill":
        fn, args = _build_prefill(cfg, shape, mesh)
        out_sds = jax.eval_shape(fn, *args)
        logits_spec = P(sh.data_axes(mesh) or None, "model")
        out_specs = (
            NamedSharding(mesh, logits_spec),
            jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                sh.cache_specs(out_sds[1], cfg, mesh),
            ),
        )
        return fn, args, {"out_shardings": out_specs}
    fn, args = _build_decode(cfg, shape, mesh)
    return fn, args, {"donate_argnums": (2,)}


# --------------------------------------------------------------------------

TRAIN_MICROBATCHES = 8  # gradient accumulation: global 256 → 8 × 32-seq
# microbatches; the standard memory/throughput trade at this batch size and
# the overlap point for grad-reduction/backward (train/step.py).


def _build_train(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, microbatches: int | None = None):
    microbatches = TRAIN_MICROBATCHES if microbatches is None else microbatches
    opt_cfg = adamw.AdamWConfig()
    key = jax.random.PRNGKey(0)
    state_sds = jax.eval_shape(
        lambda k: train_mod.init_train_state(k, cfg), key
    )
    pspecs = sh.param_specs(state_sds.params, cfg, mesh)
    state_specs = train_mod.TrainState(params=pspecs, opt=adamw.AdamWState(step=P(), m=pspecs, v=pspecs), ef=None)
    state_in = _sds_with(state_sds, state_specs, mesh)

    bs = batch_spec(cfg, shape.global_batch, shape.seq_len)
    bspecs = sh.batch_specs(cfg, mesh, shape.global_batch)
    batch_in = _sds_with(bs, bspecs, mesh)
    lr = jax.ShapeDtypeStruct((), jnp.float32, sharding=NamedSharding(mesh, P()))

    def step(state, batch, lr_scale):
        new_state, metrics = train_mod.train_step(
            state, batch, cfg, opt_cfg, lr_scale,
            microbatches=microbatches, grad_specs=pspecs,
        )
        return new_state, metrics["loss"]

    return step, (state_in, batch_in, lr)


def _build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    bs = batch_spec(cfg, shape.global_batch, shape.seq_len)
    bspecs = sh.batch_specs(cfg, mesh, shape.global_batch)
    batch_in = _sds_with(bs, bspecs, mesh)

    params_sds = jax.eval_shape(lambda k: transformer.init_params(k, cfg), jax.random.PRNGKey(0))
    params_in = _sds_with(params_sds, sh.param_specs(params_sds, cfg, mesh), mesh)

    def step(params, batch):
        memory = None
        kw = {}
        if cfg.n_enc_layers:
            memory = encdec.encode(params["encoder"], batch["frames"], cfg)
            kw["memory"] = memory
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        logits, caches = serve_steps.prefill(
            params, batch["tokens"], cfg, capacity_hint=shape.seq_len, **kw
        )
        return logits, caches

    return step, (params_in, batch_in)


def _build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    B = shape.global_batch
    hint = _decode_length_hint(cfg, shape)
    params_sds = jax.eval_shape(lambda k: transformer.init_params(k, cfg), jax.random.PRNGKey(0))
    params_in = _sds_with(params_sds, sh.param_specs(params_sds, cfg, mesh), mesh)

    enc_len = shape.seq_len if cfg.n_enc_layers else None
    caches_sds = jax.eval_shape(
        lambda: serve_steps.init_decode_caches(cfg, B, hint, enc_len=enc_len)
    )
    caches_in = _sds_with(caches_sds, sh.cache_specs(caches_sds, cfg, mesh), mesh)

    dp = sh.data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_axis = dp if dp and B % dp_size == 0 else None
    token_in = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=NamedSharding(mesh, P(b_axis)))
    length_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))

    def step(params, token, caches, length):
        return serve_steps.decode_step(params, token, caches, length, cfg)

    return step, (params_in, token_in, caches_in, length_in)
