"""Production mesh construction (assignment: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips ('data', 'model'); multi-pod:
2×16×16 = 512 chips ('pod', 'data', 'model').
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
