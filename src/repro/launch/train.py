"""Training launcher.

Local (CPU / small mesh) end-to-end driver with the fault-tolerant loop:
checkpoints, deterministic resume, straggler logging.  On a real pod this is
the per-process entrypoint (jax.distributed.initialize + the production mesh
from launch/mesh.py); the dry-run (launch/dryrun.py) proves the production
mesh lowers/compiles for every assigned cell.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --preset tiny \
        --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.train import loop as loop_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=configs.ARCH_NAMES)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.preset == "tiny":
        cfg = configs.reduced(args.arch)
    elif args.preset == "small":
        cfg = configs.reduced(
            args.arch, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, n_layers=8,
            vocab_size=32768,
        )
    else:
        cfg = configs.get(args.arch)

    n_params = cfg.param_counts()
    print(f"arch={cfg.name} preset={args.preset} params={n_params['total']/1e6:.1f}M "
          f"(active {n_params['active']/1e6:.1f}M) devices={jax.device_count()}")

    loop = loop_mod.LoopConfig(
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        seed=args.seed,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        async_ckpt=True,
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        fail_at_step=args.fail_at_step,
        step_deadline_s=60.0,
    )
    out = loop_mod.run(cfg, loop)
    print(f"done: start_step={out['start_step']} final_loss={out['losses'][-1]:.4f} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
