"""Token packing with GGArray push_back semantics (DESIGN.md §3 touchpoint 3).

Variable-length documents are pushed into per-block sequence buffers; when a
training batch is due, ``flatten`` emits the packed token stream — the
paper's two-phase pattern (grow → flatten → static work) as a data pipeline.
Block-local insertion means parallel workers pack without coordination; the
prefix-sum table gives global sample offsets for sequence-boundary masks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ggarray as gg

__all__ = ["Packer"]


@dataclasses.dataclass
class Packer:
    """Greedy block-local document packer over a GGArray token buffer."""

    nblocks: int = 8
    b0: int = 256

    def __post_init__(self):
        self._arr = gg.init(self.nblocks, self.b0, dtype=jnp.int32)
        self._bounds = gg.init(self.nblocks, max(self.b0 // 16, 1), dtype=jnp.int32)
        self._next_block = 0

    @property
    def total_tokens(self) -> int:
        return int(jax.device_get(gg.total_size(self._arr)))

    def add_document(self, tokens: list[int] | np.ndarray) -> None:
        """Push one document into the least-loaded block (greedy balance)."""
        toks = np.asarray(tokens, np.int32)
        sizes = np.asarray(jax.device_get(self._arr.sizes))
        block = int(np.argmin(sizes))
        self._arr = gg.ensure_capacity(self._arr, len(toks))
        elems = np.zeros((self.nblocks, len(toks)), np.int32)
        mask = np.zeros((self.nblocks, len(toks)), bool)
        elems[block] = toks
        mask[block] = True
        self._arr, _ = gg.push_back(self._arr, jnp.asarray(elems), jnp.asarray(mask))
        # record the document end position (per-block boundary list)
        self._bounds = gg.ensure_capacity(self._bounds, 1)
        bval = np.zeros((self.nblocks, 1), np.int32)
        bmask = np.zeros((self.nblocks, 1), bool)
        bval[block] = int(sizes[block]) + len(toks)
        bmask[block] = True
        self._bounds, _ = gg.push_back(self._bounds, jnp.asarray(bval), jnp.asarray(bmask))

    def pack(self, batch: int, seq: int, pad_id: int = 0) -> dict:
        """Flatten → (batch, seq) token matrix + loss mask (phase transition)."""
        flat, total = gg.flatten(self._arr)
        n = int(jax.device_get(total))
        need = batch * seq
        stream = np.full((need,), pad_id, np.int32)
        take = min(n, need)
        stream[:take] = np.asarray(jax.device_get(flat))[:take]
        tokens = stream.reshape(batch, seq)
        mask = (np.arange(need) < take).reshape(batch, seq)
        return {"tokens": jnp.asarray(tokens), "loss_mask": jnp.asarray(mask)}
