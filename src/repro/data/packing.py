"""Token packing on the two-phase runtime (DESIGN.md §3 touchpoint 3).

Variable-length documents are pushed into per-block sequence buffers owned by
a :class:`repro.runtime.TwoPhasePipeline`; when a training batch is due,
``pack`` freezes the pipeline — the linear-time segmented flatten emits the
packed token stream — then thaws it so ingestion can continue.  This is the
paper's two-phase pattern (grow → flatten → static work) as a data pipeline:
block-local insertion means parallel workers pack without coordination, and
the freeze-time prefix table gives global sample offsets for boundary masks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ggarray as gg
from repro.runtime import TwoPhasePipeline

__all__ = ["Packer"]


@dataclasses.dataclass
class Packer:
    """Greedy block-local document packer over a two-phase token buffer.

    ``backend="pipeline"`` (default) owns per-block GGArray buckets;
    ``backend="arena"`` runs the same lifecycle over a shared slab pool
    (``repro.pool.SlabArena`` with one logical array per block) — many
    packers / streams can then share one device pool, with per-block growth
    claiming slabs instead of allocating buckets (DESIGN.md §4).
    """

    nblocks: int = 8
    b0: int = 256
    flatten_impl: str = "segmented"
    backend: str = "pipeline"

    def __post_init__(self):
        if self.backend == "arena":
            from repro.pool import SlabArena

            self._pipe = TwoPhasePipeline.from_arena(
                SlabArena(self.nblocks, self.b0, dtype=jnp.int32)
            )
        elif self.backend == "pipeline":
            self._pipe = TwoPhasePipeline(
                self.nblocks, self.b0, dtype=jnp.int32, flatten_impl=self.flatten_impl
            )
        else:
            raise ValueError(f"unknown Packer backend {self.backend!r}")
        self._bounds = gg.init(self.nblocks, max(self.b0 // 16, 1), dtype=jnp.int32)
        # host mirrors of the per-block token/boundary counts: the packer
        # constructs every mask itself, so greedy balancing and capacity
        # planning need no device read per document
        self._sizes_host = np.zeros((self.nblocks,), np.int64)
        self._nbounds_host = np.zeros((self.nblocks,), np.int64)

    @property
    def total_tokens(self) -> int:
        return self._pipe.total_size()

    @property
    def sizes(self) -> jax.Array:
        """Per-block token counts (the greedy-balance load vector)."""
        return self._pipe.sizes

    @property
    def stats(self):
        """Freeze/grow lifecycle counters of the underlying pipeline."""
        return self._pipe.stats

    def add_document(self, tokens: list[int] | np.ndarray) -> None:
        """Push one document into the least-loaded block (greedy balance).

        Fully host-planned: block choice and boundary positions come from the
        host-side size mirror, and both appends run the donated sync-free
        path — ingestion performs zero device→host transfers per document.
        """
        toks = np.asarray(tokens, np.int32)
        block = int(np.argmin(self._sizes_host))
        elems = np.zeros((self.nblocks, len(toks)), np.int32)
        mask = np.zeros((self.nblocks, len(toks)), bool)
        elems[block] = toks
        mask[block] = True
        # the mask stays a host array: the planner advances the target
        # block's bound by len(toks) and every other block's by 0, so the
        # greedy-balanced skew never inflates the scalar upper bound
        self._pipe.append(jnp.asarray(elems), mask)
        # record the document end position (per-block boundary list); the
        # host mirror gives the exact max, so reserve never reads the device
        self._bounds = gg.reserve(
            self._bounds, 1, max_size=int(self._nbounds_host.max())
        )
        bval = np.zeros((self.nblocks, 1), np.int32)
        bmask = np.zeros((self.nblocks, 1), bool)
        bval[block] = int(self._sizes_host[block]) + len(toks)
        bmask[block] = True
        self._bounds, _, _ = gg.append(
            self._bounds, jnp.asarray(bval), jnp.asarray(bmask)
        )
        self._sizes_host[block] += len(toks)
        self._nbounds_host[block] += 1

    def pack(self, batch: int, seq: int, pad_id: int = 0) -> dict:
        """Freeze → (batch, seq) token matrix + loss mask → thaw (resume grow)."""
        frozen = self._pipe.freeze()
        n = int(jax.device_get(frozen.size))
        need = batch * seq
        stream = np.full((need,), pad_id, np.int32)
        take = min(n, need)
        stream[:take] = np.asarray(jax.device_get(frozen.data))[:take]
        self._pipe.thaw()  # zero-copy: the bucket chain is intact
        tokens = stream.reshape(batch, seq)
        mask = (np.arange(need) < take).reshape(batch, seq)
        return {"tokens": jnp.asarray(tokens), "loss_mask": jnp.asarray(mask)}
