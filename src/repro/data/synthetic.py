"""Deterministic synthetic data: step-indexed batches for exact resume.

Every batch is a pure function of (seed, step) — after a restart the loop
re-generates precisely the batches it would have seen, making checkpoint
resume bitwise-reproducible (the fault-tolerance integration test relies on
this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["make_batch", "batch_spec"]


def _key(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def make_batch(
    cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0, step: int = 0
) -> dict:
    """Synthetic batch matching input_specs() for this family."""
    key = _key(seed, step)
    k1, k2 = jax.random.split(key)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)}
    if cfg.n_enc_layers:
        out["frames"] = (
            jax.random.normal(k2, (batch, seq, cfg.d_model)) * 0.02
        ).astype(jnp.dtype(cfg.dtype))
    elif cfg.n_prefix_embeds:
        out["prefix_embeds"] = (
            jax.random.normal(k2, (batch, cfg.n_prefix_embeds, cfg.d_model)) * 0.02
        ).astype(jnp.dtype(cfg.dtype))
    return out


def batch_spec(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct mirror of make_batch (dry-run input_specs)."""
    out = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.n_enc_layers:
        out["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dt)
    elif cfg.n_prefix_embeds:
        out["prefix_embeds"] = jax.ShapeDtypeStruct((batch, cfg.n_prefix_embeds, cfg.d_model), dt)
    return out
