"""Phase-aware runtime for the paper's two-phase usage pattern (§VI.D).

``TwoPhasePipeline`` owns a GGArray through its growth phase, freezes it into
a contiguous :class:`FrozenArray` via the linear-time segmented flatten
kernel, and hands the frozen view to static-phase consumers (serving decode,
token packing, benchmarks).  See DESIGN.md §2–§3.
"""
from repro.runtime.phases import (
    FreezeStats,
    FrozenArray,
    Phase,
    PhaseError,
    TwoPhasePipeline,
)

__all__ = ["FreezeStats", "FrozenArray", "Phase", "PhaseError", "TwoPhasePipeline"]
