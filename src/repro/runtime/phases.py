"""Two-phase runtime: grow → freeze → static pipeline (paper §VI.D).

The paper's headline usage pattern is two-phased: a *growth* phase where the
final element count is unknown (GGArray absorbs insertions copy-free), then a
*static* phase where the data no longer grows and should be read at flat-array
speed.  ``TwoPhasePipeline`` models that handoff explicitly:

* **GROW** — the pipeline owns a :class:`repro.core.ggarray.GGArray`;
  ``append`` runs the amortized growth protocol (``CapacityPlanner.reserve``
  + donated ``gg.append`` — block-local, no collectives, zero host
  transfers in steady state, O(log n) growth events and host contacts
  total; DESIGN.md §2).
* **freeze()** — one-shot flatten into a contiguous, globally-ordered
  :class:`FrozenArray` via the linear-time segmented-gather Pallas kernel
  (``kernels/flatten``, keyed off the ``block_starts`` prefix sums).  This is
  the only O(n) copy the pattern ever pays per phase, replacing the legacy
  O(n²) one-hot dispatch matmul.
* **FROZEN** — reads are direct indexing (no bucket walk, no binary search);
  ``map_frozen`` runs static work kernels over the contiguous buffer.
* **thaw()** — back to GROW for re-growth: zero-copy by default (the bucket
  chain was never destroyed), or ``rebalance=True`` to redistribute the
  frozen contents evenly across blocks via ``from_flat``.

Allocation model and touchpoints: DESIGN.md §2 / §3.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import ggarray as gg
from repro.kernels.flatten import ops as flatten_ops
from repro.obs import MetricsRegistry

__all__ = ["Phase", "PhaseError", "FrozenArray", "FreezeStats", "TwoPhasePipeline"]

FLATTEN_IMPLS = ("segmented", "dispatch", "core")


class Phase(str, enum.Enum):
    GROW = "grow"
    FROZEN = "frozen"


class PhaseError(RuntimeError):
    """Operation invoked in the wrong phase of the two-phase lifecycle."""


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FrozenArray:
    """Contiguous block-major snapshot of a GGArray (the static-phase view).

    ``data`` is capacity-shaped (XLA static shapes); ``data[:size]`` are the
    live elements in global order, slots beyond are zero.  ``block_starts``
    records where each source block's segment begins — the freeze-time prefix
    table, kept for segment-aware consumers (masks, shard handoff, thaw).
    """

    data: jax.Array  # (capacity, *item_shape)
    size: jax.Array  # () int32 live element count
    block_starts: jax.Array  # (nblocks,) int32

    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    @property
    def item_shape(self) -> tuple[int, ...]:
        return self.data.shape[1:]

    @property
    def dtype(self):
        return self.data.dtype

    def read(self, idx: jax.Array) -> jax.Array:
        """O(1) contiguous read — no bucket walk, no block search."""
        return self.data[idx]

    def live_mask(self) -> jax.Array:
        return jnp.arange(self.capacity) < self.size


class FreezeStats:
    """Lifecycle counters for benchmarks / engine accounting.

    A thin read view over an ``obs`` metrics registry (DESIGN.md §9): the
    legacy attribute names survive, each now reads a ``runtime.*`` metric.
    Counters the host knows for free (waves, phase switches, growths) are
    host-side counter increments.  ``elements_frozen`` is **lazy
    device-side** (``Counter.add_lazy``): each freeze accumulates the
    live-count scalar on device and the total is transferred only when the
    property is read — so freezing never forces a host round-trip (the
    host-sync-free contract, DESIGN.md §2).  ``host_syncs`` reads the live
    planner/arena accounting (O(log n) scalar reads per growth phase).

    ``last_freeze_s`` is wall time of the most recent ``freeze()`` — the
    *first* freeze of a given bucket structure includes jit trace/compile
    time, which off-TPU dwarfs the O(n) copy itself.  For warm numbers use
    ``benchmarks/bench_two_phase.py`` (which warms up before timing) or
    compare a repeat freeze of the same structure.
    """

    def __init__(
        self,
        registry: MetricsRegistry | None = None,
        host_syncs_fn: Any = None,
    ):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._host_syncs_fn = host_syncs_fn

    def _ct(self, name: str) -> int:
        return int(self.registry.counter(name).total())

    @property
    def appends(self) -> int:
        return self._ct("runtime.appends")

    @property
    def grow_events(self) -> int:
        return self._ct("runtime.grow_events")

    @property
    def freezes(self) -> int:
        return self._ct("runtime.freezes")

    @property
    def thaws(self) -> int:
        return self._ct("runtime.thaws")

    @property
    def host_syncs(self) -> int:
        return int(self._host_syncs_fn()) if self._host_syncs_fn else 0

    @property
    def last_freeze_s(self) -> float:
        return float(self.registry.gauge("runtime.last_freeze_s").value())

    @property
    def total_freeze_s(self) -> float:
        return float(self.registry.counter("runtime.freeze_s").total())

    @property
    def elements_frozen(self) -> int:
        """Materialize the device-side accumulator (one explicit transfer)."""
        return int(self.registry.counter("runtime.elements_frozen").total())

    def __repr__(self) -> str:
        host = ", ".join(
            f"{n}={getattr(self, n)}"
            for n in ("appends", "grow_events", "freezes", "thaws",
                      "host_syncs", "last_freeze_s", "total_freeze_s")
        )
        return f"FreezeStats({host})"  # elements_frozen omitted: reading syncs


class TwoPhasePipeline:
    """Owns one GGArray across its grow → frozen → (re-grow) lifecycle.

    ``flatten_impl`` selects the freeze path: ``"segmented"`` (linear-time
    Pallas gather, the default), ``"dispatch"`` (legacy O(n²) one-hot matmul,
    kept for comparison), or ``"core"`` (pure-jnp scatter in core.ggarray —
    also the fallback whenever ``item_shape`` is non-scalar, which the 2-D
    kernels do not cover).
    """

    def __init__(
        self,
        nblocks: int = 8,
        b0: int = 8,
        item_shape: Sequence[int] = (),
        dtype: Any = jnp.float32,
        nbuckets: int = 1,
        *,
        flatten_impl: str = "segmented",
        memory_space: str | None = None,
        registry: MetricsRegistry | None = None,
    ):
        if flatten_impl not in FLATTEN_IMPLS:
            raise ValueError(f"flatten_impl {flatten_impl!r} not in {FLATTEN_IMPLS}")
        self._gg = gg.init(nblocks, b0, item_shape, dtype, nbuckets=nbuckets)
        self._arena = None
        self._frozen: FrozenArray | None = None
        self._phase = Phase.GROW
        self.flatten_impl = flatten_impl
        self.memory_space = memory_space
        self.stats = FreezeStats(
            registry, host_syncs_fn=lambda: self._planner.host_syncs
        )
        self._planner = gg.CapacityPlanner()  # fresh array: bound 0, no sync

    @classmethod
    def from_ggarray(
        cls,
        arr: gg.GGArray,
        *,
        flatten_impl: str = "segmented",
        memory_space: str | None = None,
    ):
        """Adopt an existing GGArray (no throwaway default allocation)."""
        if flatten_impl not in FLATTEN_IMPLS:
            raise ValueError(f"flatten_impl {flatten_impl!r} not in {FLATTEN_IMPLS}")
        pipe = cls.__new__(cls)
        pipe._gg = arr
        pipe._arena = None
        pipe._frozen = None
        pipe._phase = Phase.GROW
        pipe.flatten_impl = flatten_impl
        pipe.memory_space = memory_space
        pipe.stats = FreezeStats(host_syncs_fn=lambda: pipe._planner.host_syncs)
        pipe._planner = gg.CapacityPlanner.for_array(arr)  # one seed read
        return pipe

    @classmethod
    def from_arena(cls, arena):
        """Run the two-phase lifecycle over arena-backed storage.

        ``arena`` is a :class:`repro.pool.SlabArena` whose ``narrays`` play
        the role of blocks: append claims shared-pool slabs instead of
        growing owned buckets, and freeze() flattens through the page tables
        (paged gather + the same segmented global ordering, DESIGN.md §4).
        The phase discipline, FrozenArray view, and stats surface are
        identical — consumers (``data/packing.py``'s Packer) switch backends
        without code changes.  This includes segmented-extent arenas
        (``grow_chunk="doubling"``/``"tz"``, DESIGN.md §8): the paged gather
        resolves the two-level table transparently and ``stats.grow_events``
        then counts zero-copy extent appends instead of realloc copies.
        """
        pipe = cls.__new__(cls)
        pipe._gg = None
        pipe._arena = arena
        pipe._frozen = None
        pipe._phase = Phase.GROW
        pipe.flatten_impl = "segmented"
        pipe.memory_space = arena.memory_space  # the arena owns the choice
        # share the arena's registry: pool.* and runtime.* metrics land in
        # one snapshot (the arena's host-sync accounting backs host_syncs)
        pipe.stats = FreezeStats(
            arena.registry, host_syncs_fn=lambda: arena.host_syncs
        )
        pipe._planner = None  # the arena's TenantPlanner owns the bounds
        return pipe

    # ---- introspection ---------------------------------------------------
    @property
    def phase(self) -> Phase:
        return self._phase

    @property
    def array(self) -> gg.GGArray:
        """The underlying GGArray (valid in either phase; grows only in GROW)."""
        if self._gg is None:
            raise PhaseError("arena-backed pipeline: use .arena, not .array")
        return self._gg

    @property
    def arena(self):
        if self._arena is None:
            raise PhaseError("ggarray-backed pipeline: use .array, not .arena")
        return self._arena

    @property
    def _store(self):
        return self._arena if self._arena is not None else self._gg

    @property
    def nblocks(self) -> int:
        return self._store.nblocks

    @property
    def sizes(self) -> jax.Array:
        return self._store.sizes

    def total_size(self) -> int:
        return int(jax.device_get(jnp.sum(self._store.sizes)))

    def memory_elems(self) -> int:
        if self._arena is not None:
            return self._arena.memory_elems()
        return gg.memory_elems(self._gg)

    def _require(self, phase: Phase, op: str) -> None:
        if self._phase is not phase:
            raise PhaseError(
                f"{op} requires phase {phase.value!r}, pipeline is "
                f"{self._phase.value!r} (freeze()/thaw() switch phases)"
            )

    # ---- GROW phase ------------------------------------------------------
    def append(
        self, elems: jax.Array, mask: jax.Array | None = None, *, method: str = "scan"
    ) -> jax.Array:
        """Donated push_back of up to ``m`` elements per block — sync-free.

        ``elems: (nblocks, m, *item_shape)`` → assigned in-block positions
        ``(nblocks, m)`` (−1 where masked out).  Capacity planning goes
        through the :class:`repro.core.ggarray.CapacityPlanner`: in the
        steady state (host-known headroom covers the wave) the call issues
        **zero** device→host transfers; only when a growth might be needed
        does the planner read one scalar (the headroom flag the previous
        donated append left behind).  Passing ``mask`` as a host (numpy)
        array lets the planner advance per-block bounds by the actual lane
        counts — skewed masked loads then sync O(log n) times too.  The
        underlying buffers are donated — a previously captured
        ``pipeline.array`` reference is dead after this call.
        """
        self._require(Phase.GROW, "append")
        reg = self.stats.registry
        if self._arena is not None:
            before = self._arena.pool_grow_events
            pos = self._arena.append(elems, mask)
            reg.counter("runtime.grow_events").inc(
                self._arena.pool_grow_events - before
            )
            reg.counter("runtime.appends").inc()
            return pos
        before = self._gg.nbuckets
        self._gg = self._planner.reserve(self._gg, elems.shape[1], mask=mask)
        reg.counter("runtime.grow_events").inc(self._gg.nbuckets - before)
        self._gg, pos, headroom = gg.append(self._gg, elems, mask, method=method)
        self._planner.note_append(self._gg, headroom)
        reg.counter("runtime.appends").inc()
        return pos

    # ---- the handoff -----------------------------------------------------
    def freeze(self) -> FrozenArray:
        """Flatten into a contiguous global-order array; enter FROZEN phase."""
        self._require(Phase.GROW, "freeze")
        t0 = time.perf_counter()
        if self._arena is not None:
            flat, total, starts = self._arena.flatten()
        else:
            arr = self._gg
            starts = gg.block_starts(arr)
            if self.flatten_impl == "core" or arr.item_shape:
                flat, total = gg.flatten(arr)
            else:
                flat = flatten_ops.flatten(
                    arr.buckets, arr.sizes, arr.b0, impl=self.flatten_impl,
                    memory_space=self.memory_space,
                )
                total = jnp.sum(arr.sizes)
        flat = jax.block_until_ready(flat)
        dt = time.perf_counter() - t0
        self._frozen = FrozenArray(
            data=flat, size=total.astype(jnp.int32), block_starts=starts
        )
        self._phase = Phase.FROZEN
        reg = self.stats.registry
        reg.counter("runtime.freezes").inc()
        # lazy device-side accumulation — no device_get per freeze; the
        # scalar stays on device until the counter is read (one batched
        # transfer for every pending freeze)
        reg.counter("runtime.elements_frozen").add_lazy(total)
        reg.gauge("runtime.last_freeze_s").set(dt)
        reg.counter("runtime.freeze_s").inc(dt)
        reg.histogram("runtime.freeze_ms", "freeze() wall-clock").observe(dt * 1e3)
        return self._frozen

    def thaw(self, *, rebalance: bool = False) -> gg.GGArray:
        """Re-enter GROW. Zero-copy by default (the bucket chain is intact);
        ``rebalance=True`` redistributes the frozen contents evenly instead."""
        self._require(Phase.FROZEN, "thaw")
        t0 = time.perf_counter()
        if rebalance and self._arena is not None:
            raise PhaseError(
                "arena-backed pipelines cannot rebalance on thaw: slabs are "
                "shared-pool pages, not redistributable owned buffers"
            )
        if rebalance:
            frozen = self._frozen
            assert frozen is not None
            n = int(jax.device_get(frozen.size))
            self._gg = gg.from_flat(frozen.data, n, self._gg.nblocks, self._gg.b0)
            # redistribution gives exact per-block sizes — reseed the bound
            # without a device read, carrying the lifetime sync count over
            planner = gg.CapacityPlanner(-(-n // self._gg.nblocks))
            planner.host_syncs = self._planner.host_syncs
            self._planner = planner
        self._frozen = None
        self._phase = Phase.GROW
        reg = self.stats.registry
        reg.counter("runtime.thaws").inc()
        reg.histogram("runtime.thaw_ms", "thaw() wall-clock").observe(
            (time.perf_counter() - t0) * 1e3
        )
        return self._store

    # ---- FROZEN phase ----------------------------------------------------
    @property
    def frozen(self) -> FrozenArray:
        if self._phase is not Phase.FROZEN or self._frozen is None:
            raise PhaseError("no frozen view: call freeze() first")
        return self._frozen

    def read(self, idx: jax.Array) -> jax.Array:
        """Static-phase read: direct contiguous gather."""
        return self.frozen.read(idx)

    def map_frozen(self, fn: Callable[[jax.Array], jax.Array]) -> FrozenArray:
        """Run a static work kernel over the contiguous buffer (live slots).

        Dead (beyond-``size``) slots are left untouched so repeated maps stay
        zero there; ``fn`` must be shape-preserving.
        """
        frozen = self.frozen
        out = fn(frozen.data)
        if out.shape != frozen.data.shape:
            raise ValueError(f"map_frozen fn changed shape: {out.shape}")
        cond = frozen.live_mask().reshape((-1,) + (1,) * len(frozen.item_shape))
        self._frozen = dataclasses.replace(
            frozen, data=jnp.where(cond, out, frozen.data)
        )
        return self._frozen
