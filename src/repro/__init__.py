"""repro — GGArray (CS.DC 2022) as a TPU-native substrate for a multi-pod
JAX LM framework. See README.md / DESIGN.md for the map."""

__version__ = "0.1.0"
