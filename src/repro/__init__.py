"""repro — GGArray (cs.DC 2022) as a TPU-native substrate for a multi-pod
JAX LM framework, organized around the paper's two-phase pattern:
``runtime.TwoPhasePipeline`` grows a GGArray copy-free, freezes it through
the linear-time segmented flatten kernel, and serves the frozen contiguous
view to the static phase.  See README.md / DESIGN.md for the map."""

__version__ = "0.2.0"
