"""Scan-aware FLOP / HBM-traffic counting over jaxprs.

XLA's ``compiled.cost_analysis()`` counts a ``while`` (scan) body **once**,
which undercounts a 64-layer scanned model by ~64× (verified empirically in
the dry-run).  This module walks the *jaxpr* instead: ``scan`` multiplies its
body cost by trip count, remat recompute appears explicitly (so the
MODEL_FLOPS/HLO ratio still exposes remat waste), and the numbers are
backend-independent.

Conventions:
- ``flops``: matmul/conv only (2·M·N·K), the MFU convention.
- ``hbm_bytes``: an *unfused traffic model* — every eqn's output bytes, plus
  operand bytes for data-moving/contracting ops (dot, conv, gather, scatter,
  reduce, sort).  Fusion makes real traffic lower; the model is consistent
  across before/after comparisons, which is what the §Perf loop needs.
  XLA's own (scan-once) numbers are recorded alongside in the dry-run JSON.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np

__all__ = ["count_fn", "count_jaxpr"]

_CONTRACTING = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "scatter_add", "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "reduce_and", "reduce_or", "argmax", "argmin", "sort", "cumsum",
    "cumlogsumexp", "cummax", "cumprod", "dynamic_slice", "dynamic_update_slice",
}
_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr")


def _aval_bytes(v) -> int:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize if aval.shape else aval.dtype.itemsize


def _dot_flops(eqn) -> int:
    (lhs, rhs) = eqn.invars[:2]
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    lshape = lhs.aval.shape
    batch = int(np.prod([lshape[i] for i in lb], dtype=np.int64)) if lb else 1
    k = int(np.prod([lshape[i] for i in lc], dtype=np.int64)) if lc else 1
    m = int(np.prod([d for i, d in enumerate(lshape) if i not in lc and i not in lb], dtype=np.int64))
    rshape = rhs.aval.shape
    n = int(np.prod([d for i, d in enumerate(rshape) if i not in rc and i not in rb], dtype=np.int64))
    return 2 * batch * m * n * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    out_elems = int(np.prod(out.shape, dtype=np.int64))
    # flops per output element ≈ 2 × (kernel spatial × in-channels)
    kernel = int(np.prod(rhs.shape, dtype=np.int64)) // max(rhs.shape[-1], 1)
    return 2 * out_elems * kernel


def count_jaxpr(jaxpr, mult: int = 1) -> dict[str, float]:
    flops = 0.0
    byts = 0.0
    notes: list[str] = []
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub = None
        submult = mult
        if prim == "scan":
            sub = eqn.params["jaxpr"].jaxpr
            submult = mult * int(eqn.params["length"])
        elif prim == "while":
            sub = eqn.params["body_jaxpr"].jaxpr
            notes.append("while:trip-count-unknown(counted once)")
        elif prim == "cond":
            branches = eqn.params["branches"]
            costs = [count_jaxpr(b.jaxpr, mult) for b in branches]
            best = max(costs, key=lambda c: c["flops"])
            flops += best["flops"]
            byts += best["hbm_bytes"]
            continue
        else:
            for key in _SUBJAXPR_PARAMS:
                if key in eqn.params:
                    cj = eqn.params[key]
                    sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
                    break
        if sub is not None:
            inner = count_jaxpr(sub, submult)
            flops += inner["flops"]
            byts += inner["hbm_bytes"]
            notes.extend(inner.get("notes", []))
            continue
        out_bytes = sum(_aval_bytes(v) for v in eqn.outvars)
        byts += mult * out_bytes
        if prim == "dot_general":
            flops += mult * _dot_flops(eqn)
            byts += mult * sum(_aval_bytes(v) for v in eqn.invars)
        elif prim == "conv_general_dilated":
            flops += mult * _conv_flops(eqn)
            byts += mult * sum(_aval_bytes(v) for v in eqn.invars)
        elif prim in _CONTRACTING or prim.startswith(("reduce", "cum")):
            byts += mult * sum(_aval_bytes(v) for v in eqn.invars)
    return {"flops": flops, "hbm_bytes": byts, "notes": notes}


def count_fn(fn, *args, **kwargs) -> dict[str, float]:
    """Trace ``fn`` (ShapeDtypeStruct args fine) and count its jaxpr."""
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    return count_jaxpr(jaxpr.jaxpr)
