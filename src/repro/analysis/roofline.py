"""Roofline extraction from compiled dry-run artifacts (assignment §Roofline).

Terms per (arch × shape × mesh) cell, all in seconds per step:

  compute    = FLOPs_per_device / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = bytes_per_device / HBM_bw                (819 GB/s)
  collective = Σ collective_bytes_per_device × traffic_factor / link_bw
                                                        (50 GB/s/link ICI)

``cost_analysis()`` on the SPMD-partitioned executable reports **per-device**
FLOPs/bytes (verified empirically), so no chip division is needed.
Collective bytes are not in cost_analysis: we parse the post-SPMD HLO text
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, weighting by the standard ring traffic
factors — all-reduce 2(n−1)/n, all-gather & reduce-scatter (n−1)/n,
all-to-all (n−1)/n, permute 1 — with n = participants per replica group
(parsed from the op's ``replica_groups``).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops"]

# TPU v5e per chip (assignment constants)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_SHAPE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO result type (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_SHAPE_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def _traffic_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def _parse_computations(hlo_text: str) -> dict[str, list[str]]:
    """Split HLO text into {computation_name: [lines]}."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        # computation headers: `%name (args...) -> ret {` — args/ret may nest
        # parens/brackets (tuples), so match greedily up to the trailing `{`.
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


_CALL_RE = re.compile(r"(?:to_apply|body|calls)=%?([\w.\-]+)")
_COND_REF_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"direction=LT")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _while_trip_count(cond_lines: list[str], body_lines: list[str]) -> int:
    """Best-effort trip count: LT-compare against a constant in the condition."""
    consts = []
    for line in cond_lines:
        if "compare" in line and _TRIP_RE.search(line):
            consts += [int(c) for c in _CONST_RE.findall(line)]
    for line in cond_lines:  # constants defined on their own lines
        if "constant(" in line and "s32" in line:
            consts += [int(c) for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device weighted collective bytes by op kind, **loop-aware**.

    XLA prints each while body once; collectives inside execute trip-count
    times.  We walk the computation call graph from ENTRY, multiplying by
    parsed trip counts (best-effort: unparsed loops count once and are
    flagged in ``unparsed_loops``).
    """
    comps = _parse_computations(hlo_text)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))

    out: dict[str, float] = {"total_weighted": 0.0, "total_raw": 0.0, "unparsed_loops": 0.0}
    seen_stack: set[str] = set()

    def walk(name: str, mult: float) -> None:
        if name not in comps or name in seen_stack:
            return
        seen_stack.add(name)
        for line in comps[name]:
            m = _COLL_RE.match(line)
            if m:
                type_str, op = m.group(1), m.group(2)
                raw = _shape_bytes(type_str)
                n = _group_size(line)
                w = raw * _traffic_factor(op, n)
                out[op] = out.get(op, 0.0) + w * mult
                out["total_weighted"] += w * mult
                out["total_raw"] += raw * mult
            if " while(" in line or line.strip().startswith("while("):
                body = _CALL_RE.search(line)
                cond = _COND_REF_RE.search(line)
                trips = 1
                if body and cond and cond.group(1) in comps:
                    trips = _while_trip_count(comps[cond.group(1)], comps.get(body.group(1), []))
                    if trips <= 1:
                        out["unparsed_loops"] += 1
                if body:
                    walk(body.group(1), mult * max(trips, 1))
                continue
            for callee in _CALL_RE.findall(line):
                walk(callee, mult)
        seen_stack.discard(name)

    if entry:
        walk(entry, 1.0)
    return out


def roofline_terms(
    cost: dict[str, Any], coll: dict[str, float], hw: HW = HW()
) -> dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("total_weighted", 0.0))
    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    coll_s = cbytes / hw.ici_bw
    bound = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    step_s = max(compute_s, memory_s, coll_s)
    return {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": cbytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bound": bound,
        "step_s_lower_bound": step_s,
    }


def model_flops(cfg, shape, chips: int) -> dict[str, float]:
    """Useful-model-FLOPs convention (assignment §Roofline):
    train: 6·N_active·D tokens; prefill: 2·N_active·D; decode: 2·N_active·B."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    if shape.kind == "train":
        total = 6.0 * n_active * shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        total = 2.0 * n_active * shape.seq_len * shape.global_batch
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return {
        "model_flops_total": total,
        "model_flops_per_device": total / chips,
        "params_total": counts["total"],
        "params_active": n_active,
    }
