"""Checkpointing: atomic per-array save, async writer, elastic restore.

Format: ``<dir>/step_<N>/`` with a ``manifest.json`` (treedef + per-leaf
shape/dtype + user metadata) and one ``.npy`` per leaf.  Writes go to a temp
dir renamed into place, so a crash mid-save never corrupts the latest
checkpoint (the loop always restores from the newest *complete* step).

Elastic restore: arrays are loaded on host and ``device_put`` against
whatever shardings the *restoring* mesh prescribes — a checkpoint written on
one mesh restores onto any other (the dry-run meshes included), which is the
elastic-scaling path.  On a real multi-host pod each process would write its
addressable shards (``save`` takes the fully-addressable view here; the
format is shard-agnostic).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

_PENDING: list[threading.Thread] = []


def _paths_of(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key or "leaf", leaf))
    return out


def save(ckpt_dir: str, step: int, tree, *, metadata: dict | None = None) -> str:
    """Blocking atomic save → final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    entries = []
    for i, (key, leaf) in enumerate(_paths_of(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        entries.append({"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {"step": step, "leaves": entries, "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def save_async(ckpt_dir: str, step: int, tree, *, metadata: dict | None = None) -> threading.Thread:
    """Non-blocking save: device_get + write happen on a worker thread."""
    t = threading.Thread(target=save, args=(ckpt_dir, step, tree), kwargs={"metadata": metadata}, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a complete manifest (ignores .tmp partials)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, *, shardings=None):
    """Load step ``step`` into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedSharding — arrays are
    placed per the *restoring* topology (elastic reshard).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"structure expects {len(leaves_like)}"
        )
    arrays = [np.load(os.path.join(d, e["file"])) for e in manifest["leaves"]]
    restored = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    else:
        restored = jax.tree.map(jax.device_put, restored)
    return restored, manifest["metadata"]
