from repro.train import step

__all__ = ["step"]
