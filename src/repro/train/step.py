"""Loss + train step: next-token CE, grad accumulation, AdamW, compression."""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import constrain_tree
from repro.models import encdec, transformer
from repro.optim import adamw, compression

__all__ = ["TrainState", "init_train_state", "loss_fn", "train_step", "make_train_step"]

AUX_LOSS_WEIGHT = 0.01


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState
    ef: compression.EFState | None  # error feedback (grad compression)


def init_train_state(
    key: jax.Array, cfg: ModelConfig, *, grad_compression: bool = False
) -> TrainState:
    params = transformer.init_params(key, cfg)
    return TrainState(
        params=params,
        opt=adamw.init(params),
        ef=compression.ef_init(params) if grad_compression else None,
    )


def _ce_loss(logits: jax.Array, targets: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross-entropy; logits (B,S,V) f32, targets (B,S).

    ``mask``: optional (B,S) loss mask (padding from the packing pipeline)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    memory = None
    if cfg.n_enc_layers:
        memory = encdec.encode(params["encoder"], batch["frames"], cfg)
    logits, aux = transformer.forward(
        params,
        tokens,
        cfg,
        prefix_embeds=batch.get("prefix_embeds"),
        memory=memory,
    )
    # only token positions predict the next token (prefix embeds are inputs)
    P = logits.shape[1] - tokens.shape[1]
    mask = batch.get("loss_mask")
    ce = _ce_loss(logits[:, P:-1], tokens[:, 1:], None if mask is None else mask[:, 1:])
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def train_step(
    state: TrainState,
    batch: dict,
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
    *,
    microbatches: int = 1,
    grad_specs=None,
) -> tuple[TrainState, dict]:
    """One optimizer step; ``microbatches > 1`` accumulates gradients.

    Microbatch accumulation splits the global batch along axis 0 and scans,
    which is also where compute/communication overlap comes from at scale:
    XLA overlaps the k-th microbatch's backward with the (k−1)-th's gradient
    reduction.  ``grad_specs`` (a PartitionSpec pytree matching params) pins
    gradients + the f32 accumulator to the parameter sharding — without it
    GSPMD replicates the accumulator and every microbatch all-reduces full
    param-shaped f32 gradients over the TP axis (dry-run-caught, §Perf).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if microbatches == 1:
        (loss, metrics), grads = grad_fn(state.params, batch, cfg)
        grads = constrain_tree(grads, grad_specs)
    else:
        B = batch["tokens"].shape[0]
        if B % microbatches:
            raise ValueError(f"batch {B} not divisible by microbatches {microbatches}")
        mb = {k: v.reshape(microbatches, B // microbatches, *v.shape[1:]) for k, v in batch.items()}

        def body(carry, mbatch):
            acc_grads, acc_loss = carry
            (loss, metrics), grads = grad_fn(state.params, mbatch, cfg)
            grads = constrain_tree(grads, grad_specs)
            acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
            acc_grads = constrain_tree(acc_grads, grad_specs)
            return (acc_grads, acc_loss + loss), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        zero = constrain_tree(zero, grad_specs)
        (grads, loss_sum), metrics = jax.lax.scan(body, (zero, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        loss = loss_sum / microbatches
        metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)

    ef = state.ef
    if ef is not None:
        grads, ef = compression.compress_grads(grads, ef)
    params, opt, gnorm = adamw.update(grads, state.opt, state.params, opt_cfg, lr_scale)
    metrics = dict(metrics, loss=loss, grad_norm=gnorm)
    return TrainState(params=params, opt=opt, ef=ef), metrics


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, *, microbatches: int = 1):
    """jit-ready closure (static model/opt config captured)."""

    def step(state: TrainState, batch: dict, lr_scale):
        return train_step(state, batch, cfg, opt_cfg, lr_scale, microbatches=microbatches)

    return step
