"""Fault-tolerant training driver.

Responsibilities (the large-scale-runnability contract):
- **checkpoint/restart**: periodic (optionally async) checkpoints; on start
  the loop resumes from the newest complete checkpoint automatically.
- **deterministic resume**: data is step-indexed (data/synthetic.py), so a
  restarted run recomputes the identical batch sequence — losses after resume
  match an uninterrupted run bitwise (integration-tested).
- **failure injection**: ``fail_at_step`` raises mid-run to exercise the
  restart path in tests; on a real pod the same surface catches preemptions.
- **straggler mitigation**: per-step deadline; slow steps are counted and
  logged (on multi-host this is where a re-slice/despecialize hook goes —
  the counter is the policy trigger).
- **emergency checkpoint**: best-effort save on any crash.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.data.synthetic import make_batch
from repro.optim import adamw, schedule
from repro.train import step as step_mod

__all__ = ["LoopConfig", "run"]


@dataclasses.dataclass
class LoopConfig:
    steps: int = 20
    batch: int = 8
    seq: int = 64
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 10
    async_ckpt: bool = False
    microbatches: int = 1
    warmup: int = 5
    lr: float = 1e-3
    grad_compression: bool = False
    fail_at_step: int | None = None  # failure injection (tests)
    step_deadline_s: float | None = None  # straggler threshold
    log_every: int = 10


def run(
    cfg: ModelConfig,
    loop: LoopConfig,
    *,
    on_metrics: Callable[[int, dict], None] | None = None,
) -> dict:
    """Train; returns {'losses': [...], 'start_step': int, 'stragglers': int}."""
    opt_cfg = adamw.AdamWConfig(lr=loop.lr)
    key = jax.random.PRNGKey(loop.seed)

    state = step_mod.init_train_state(key, cfg, grad_compression=loop.grad_compression)
    start_step = 0
    if loop.ckpt_dir:
        latest = ckpt.latest_step(loop.ckpt_dir)
        if latest is not None:
            state, meta = ckpt.restore(loop.ckpt_dir, latest, state)
            start_step = int(meta.get("next_step", latest))

    train_fn = jax.jit(
        lambda s, b, lr_scale: step_mod.train_step(
            s, b, cfg, opt_cfg, lr_scale, microbatches=loop.microbatches
        )
    )

    losses: list[float] = []
    stragglers = 0
    try:
        for it in range(start_step, loop.steps):
            if loop.fail_at_step is not None and it == loop.fail_at_step:
                raise RuntimeError(f"injected failure at step {it}")
            batch = make_batch(cfg, loop.batch, loop.seq, seed=loop.seed, step=it)
            lr_scale = schedule.warmup_cosine(it, warmup=loop.warmup, total=loop.steps)
            t0 = time.monotonic()
            state, metrics = train_fn(state, batch, lr_scale)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            if loop.step_deadline_s is not None and dt > loop.step_deadline_s:
                stragglers += 1
                print(f"[straggler] step {it} took {dt:.3f}s > {loop.step_deadline_s}s")
            losses.append(loss)
            if on_metrics:
                on_metrics(it, metrics)
            if loop.ckpt_dir and (it + 1) % loop.ckpt_every == 0:
                saver = ckpt.save_async if loop.async_ckpt else ckpt.save
                saver(loop.ckpt_dir, it + 1, state, metadata={"next_step": it + 1})
            if (it + 1) % loop.log_every == 0:
                print(f"step {it + 1}/{loop.steps} loss={loss:.4f}")
    except Exception:
        if loop.ckpt_dir:  # emergency checkpoint (best effort)
            try:
                ckpt.wait_pending()
            except Exception:
                pass
        raise
    finally:
        if loop.ckpt_dir:
            ckpt.wait_pending()
    return {"losses": losses, "start_step": start_step, "stragglers": stragglers}
