"""Admission scheduler for :class:`BatchEngine` — bucketed chunked prefill.

Pure host state over a :class:`repro.pool.PageBook` (no model, no device),
so the scheduling invariants are property-testable in isolation
(``tests/serving/test_scheduler.py``).  The engine drives it per step:

1. ``admit()`` — scan the FIFO queue, assigning a free decode slot and
   **reserving** the prompt's full slab need (``planner.SlabAllocator``
   reservation ledger) for every request the pool can cover.  Reserving up
   front is the §7 invariant: decode-growth claims see
   ``free − reserved`` availability, so a decode burst can never strand an
   admitted prefill halfway through its chunks.
2. ``next_chunks()`` — one :class:`ChunkTask` per prefilling slot (oldest
   admission first): the next ``chunk``-sized window of the prompt, padded
   to a **geometric length bucket**, plus the slab claim that covers it.
3. ``chunk_done()`` — advance the slot; the final chunk flips it to the
   decode phase.

Bucketed padding is what bounds compilation: every chunk is one of
``bucket_widths(b0, chunk)`` widths (``b0·2^i`` up to ``chunk``), so a fleet
of arbitrary prompt lengths compiles **O(log chunk)** prefill traces instead
of one per distinct length.  ``exact_tail=True`` (hybrid SSM layouts) opts
the *final* chunk out of padding: pad tokens are exactly dead lanes for
attention (DESIGN.md §7 bit-exactness contract) but would pollute the Mamba
conv/SSD recurrence (``dt = softplus(dt_bias) ≠ 0`` on pad rows).

Admission order is FIFO with bounded skip-ahead: a request whose slab need
cannot be covered is skipped (smaller later requests may still admit — the
"admit whenever slots AND slabs allow" policy), but once the oldest waiter
has been skipped ``starvation_limit`` times it head-of-line blocks the queue
until it fits.  Two requests with equal slab need therefore always admit in
submission order (FIFO-within-bucket), and no request waits forever.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable

import numpy as np

from repro.obs import ServingTimeline
from repro.pool import PageBook, QuotaExceeded

__all__ = ["Scheduler", "ChunkTask", "bucket_widths", "bucket_for"]


def bucket_widths(b0: int, chunk: int) -> tuple[int, ...]:
    """Geometric chunk-width buckets ``b0·2^i`` capped at ``chunk``."""
    if b0 <= 0 or chunk <= 0:
        raise ValueError(f"need positive b0/chunk, got {b0}/{chunk}")
    out = []
    w = min(b0, chunk)
    while w < chunk:
        out.append(w)
        w *= 2
    out.append(chunk)
    return tuple(out)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket ≥ n (buckets ascending; n ≤ buckets[-1])."""
    for w in buckets:
        if w >= n:
            return w
    raise ValueError(f"length {n} exceeds largest bucket {buckets[-1]}")


@dataclasses.dataclass
class ChunkTask:
    """One prefill chunk for the engine to execute."""

    rid: int
    slot: int
    t0: int  # prompt tokens already prefilled
    live: int  # live tokens in this chunk
    width: int  # padded (bucketed) chunk width ≥ live
    new_slabs: int  # slabs to claim-from-reservation before running it
    final: bool  # last chunk → slot flips to decode


@dataclasses.dataclass
class _Waiting:
    rid: int
    length: int
    skips: int = 0
    submit_tick: int = 0  # scheduler tick (admit() round) at submission


class Scheduler:
    """Host-only admission + chunk planning over a shared ``PageBook``."""

    def __init__(
        self,
        book: PageBook,
        *,
        slab_tokens: int,
        chunk: int,
        buckets: tuple[int, ...] | None = None,
        exact_tail: bool = False,
        max_chunks_per_step: int | None = None,
        starvation_limit: int = 4,
        obs: ServingTimeline | None = None,
    ):
        self.book = book
        self.obs = obs if obs is not None else ServingTimeline()
        self.T = slab_tokens
        self.C = chunk
        self.buckets = (
            bucket_widths(min(slab_tokens, chunk), chunk)
            if buckets is None
            else tuple(buckets)
        )
        if self.buckets[-1] != chunk:
            raise ValueError(f"buckets {self.buckets} must end at chunk={chunk}")
        self.exact_tail = exact_tail
        self.starvation_limit = starvation_limit
        B = len(book.npages)
        self.B = B
        self.max_chunks = B if max_chunks_per_step is None else max_chunks_per_step
        self.rid_of_slot: list[int | None] = [None] * B
        self.phase = ["idle"] * B  # idle | prefill | decode
        self.t0 = np.zeros((B,), np.int64)
        self.length = np.zeros((B,), np.int64)
        self.pending: collections.deque[_Waiting] = collections.deque()
        self._prefillq: collections.deque[int] = collections.deque()
        self.tick = 0  # completed admit() rounds — the queue-wait clock

    # ---- queries ---------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self.pending) or any(p != "idle" for p in self.phase)

    @property
    def prefilling(self) -> list[int]:
        return list(self._prefillq)

    @property
    def decoding(self) -> list[int]:
        return [s for s in range(self.B) if self.phase[s] == "decode"]

    def slabs_for(self, length: int) -> int:
        """Total slabs a prompt of ``length`` occupies (≥ 1)."""
        return max(math.ceil(length / self.T), 1)

    # ---- lifecycle -------------------------------------------------------
    def submit(self, rid: int, length: int) -> None:
        self.pending.append(_Waiting(rid, length, submit_tick=self.tick))

    def admit(
        self,
        ensure: Callable[[int], bool] | None = None,
        match: Callable[[int, int], int] | None = None,
    ) -> list[tuple[int, int, int]]:
        """Admit what fits → [(rid, slot, reserved_slabs)].

        ``ensure(short)`` asks the caller to grow the pool by ``short``
        slabs; returning False leaves the request waiting.  FIFO scan with
        skip-ahead; the oldest waiter head-of-line blocks after
        ``starvation_limit`` skips.  Raises :class:`QuotaExceeded` when a
        request's whole-prompt need breaches its slot quota (it can never
        admit, so waiting would deadlock the queue).

        ``match(rid, length)`` is the prefix-cache hook (DESIGN.md §10): it
        returns the request's cached-prefix length in tokens (slab-aligned,
        0 = cold).  The whole-prompt reservation shrinks to the **uncached
        suffix** and prefill starts at the first uncached token
        (``t0[slot]`` = cached length); the caller aliases the cached slabs
        into the slot's page table right after ``admit`` returns, before
        planning chunks.  A fully cached prompt admits with zero prefill
        chunks — the slot goes straight to the decode phase and the caller
        arms decode on the last prompt token.
        """
        out: list[tuple[int, int, int]] = []
        survivors: collections.deque[_Waiting] = collections.deque()
        blocked = False
        free = collections.deque(
            s for s in range(self.B) if self.phase[s] == "idle"
        )
        while self.pending:
            w = self.pending.popleft()
            if blocked or not free:
                survivors.append(w)
                continue
            cached = 0 if match is None else min(match(w.rid, w.length), w.length)
            need = self.slabs_for(w.length) - cached // self.T
            slot = free[0]
            short = self.book.shortfall(need)
            if short and not (ensure is not None and ensure(short)):
                w.skips += 1
                self.obs.registry.counter(
                    "sched.starvation_skips", "waiters passed over for slabs"
                ).inc()
                self.obs.event("starve_skip", rid=w.rid, skips=w.skips)
                survivors.append(w)
                if len(survivors) == 1 and w.skips >= self.starvation_limit:
                    blocked = True  # aged head: no more skip-ahead past it
                    self.obs.registry.counter(
                        "sched.head_blocks", "aged head halted skip-ahead"
                    ).inc()
                    self.obs.event("head_block", rid=w.rid)
                continue
            try:
                self.book.reserve(slot, need)
            except QuotaExceeded:
                survivors.append(w)
                survivors.extend(self.pending)
                self.pending = survivors
                raise
            free.popleft()
            self.rid_of_slot[slot] = w.rid
            self.t0[slot] = cached
            self.length[slot] = w.length
            if cached >= w.length:  # fully cached: no prefill chunks at all
                self.phase[slot] = "decode"
            else:
                self.phase[slot] = "prefill"
                self._prefillq.append(slot)
            self.obs.registry.histogram(
                "sched.queue_wait_ticks", "admit() rounds waited in queue"
            ).observe(self.tick - w.submit_tick, rid=w.rid)
            out.append((w.rid, slot, need))
        self.pending = survivors
        self.tick += 1
        return out

    def next_chunks(self) -> list[ChunkTask]:
        """Chunk tasks for this step — ≤ ``max_chunks``, oldest slot first.

        Call once per step and report each executed task via
        ``chunk_done``; tasks are *plans*, nothing is claimed yet.
        """
        out = []
        for slot in list(self._prefillq)[: self.max_chunks]:
            t0 = int(self.t0[slot])
            L = int(self.length[slot])
            live = min(self.C, L - t0)
            final = t0 + live >= L
            if final and self.exact_tail:
                width = live
            else:
                width = bucket_for(live, self.buckets)
            cover = self.slabs_for(t0 + live)
            new = max(cover - int(self.book.npages[slot]), 0)
            out.append(
                ChunkTask(
                    rid=self.rid_of_slot[slot], slot=slot, t0=t0, live=live,
                    width=width, new_slabs=new, final=final,
                )
            )
        return out

    def chunk_done(self, task: ChunkTask) -> None:
        self.t0[task.slot] += task.live
        if self.t0[task.slot] >= self.length[task.slot]:
            self.phase[task.slot] = "decode"
            self._prefillq.remove(task.slot)

    def complete(self, slot: int) -> None:
        """The slot's request finished (caller released its slabs)."""
        if self.phase[slot] == "prefill":
            self._prefillq.remove(slot)
        self.phase[slot] = "idle"
        self.rid_of_slot[slot] = None
        self.t0[slot] = 0
        self.length[slot] = 0

    def describe(self) -> dict:
        """Full host state → JSON-ready dict (flight-recorder bundles)."""
        return {
            "tick": self.tick,
            "phase": list(self.phase),
            "rid_of_slot": list(self.rid_of_slot),
            "t0": self.t0.tolist(),
            "length": self.length.tolist(),
            "prefilling": list(self._prefillq),
            "pending": [
                {
                    "rid": w.rid, "length": w.length, "skips": w.skips,
                    "submit_tick": w.submit_tick,
                }
                for w in self.pending
            ],
            "buckets": list(self.buckets),
            "chunk": self.C,
            "max_chunks_per_step": self.max_chunks,
        }
