"""KV-cache policies — the paper's §III structures as serving substrate.

Decode is a per-step ``push_back`` into per-layer K/V arrays whose final
length is unknown at allocation time — exactly the paper's motivating
scenario.  Three policies mirror its comparison (DESIGN.md §3), and a fourth
(``two_phase``, realized in ``serving/engine.py`` via ``freeze_cache`` /
``thaw_cache`` below) applies the paper's §VI.D pattern to the prefill →
decode handoff:

``static``      pre-allocate ``max_seq_len`` (paper's static array).  Fails
                (truncates) past capacity; pays worst-case VRAM up front.
``semistatic``  doubling buffer; **copies the whole cache** on growth (the
                host-resize baseline; the paper's memMap variant remaps pages
                instead — no XLA analog, so the copy is real here).
``ggarray``     geometric seq-dim buckets (bucket b holds ``B0·2^b`` steps):
                growth appends a bucket, never copies; capacity stays < 2×
                the live context + B0.  Attention walks the bucket chain with
                online-softmax merging — the rw_b access pattern.
``paged``       the slab arena (DESIGN.md §4): K/V live in one shared pool
                of ``slab_tokens``-sized slabs; each sequence holds a page
                table of slab indices.  Growth is "claim a slab" (no copy,
                no per-sequence worst case) and the fleet's capacity is
                bounded by live tokens + one slab per sequence.  Attention
                walks the pages in *geometric groups* (level b = pages
                ``[2^b−1, 2^(b+1)−1)``), which reproduces the ggarray bucket
                walk segment-for-segment — bit-exact when ``slab_tokens ==
                cache_b0``.  Served by ``serving/engine.py::BatchEngine``
                (continuous batching, slab reclamation).

A cache *slot* (one attention layer kind) is a dict of arrays; the serving
stack stacks slots over scan periods.  Bucket count is static per compiled
step; growth events change the pytree structure at the program boundary
(O(log n) recompiles total, warm-cached — DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import indexing
from repro.models.attention import MASK_VALUE
from repro.obs import device

__all__ = [
    "init_cache",
    "cache_capacity",
    "capacity_of",
    "append",
    "attend",
    "copy_slab",
    "chunk_attend",
    "scatter_chunk",
    "grow_ggarray",
    "freeze_cache",
    "thaw_cache",
    "fill_from_prefill",
    "needed_levels",
    "cache_bytes",
]

Cache = dict[str, Any]


def needed_levels(b0: int, length: int) -> int:
    return max(indexing.min_buckets_for(b0, length), 1)


def cache_capacity(cfg: ModelConfig, policy: str, length_hint: int) -> int:
    if policy == "static":
        return length_hint
    if policy == "semistatic":
        cap = max(cfg.cache_b0, 1)
        while cap < length_hint:
            cap *= 2
        return cap
    if policy == "paged":
        T = cfg.slab_tokens
        return max(-(-length_hint // T), 1) * T
    return indexing.capacity(cfg.cache_b0, needed_levels(cfg.cache_b0, length_hint))


def _level_shapes(cfg: ModelConfig, nlevels: int) -> list[int]:
    return list(indexing.bucket_sizes(cfg.cache_b0, nlevels))


def init_cache(
    cfg: ModelConfig,
    batch: int,
    length_hint: int,
    policy: str | None = None,
    *,
    stack: int | None = None,
    dtype=None,
) -> Cache:
    """Empty cache slot sized for ``length_hint`` under ``policy``.

    ``stack``: leading periods dim (scan-over-layers stacking).
    ``cfg.cache_quant``: int8 K/V with per-(token, kv-head) scales — halves
    the decode memory-roofline term (the cache stream dominates it).
    """
    policy = cfg.cache_policy if policy is None else policy
    quant = cfg.cache_quant
    dtype = (jnp.int8 if quant else jnp.dtype(cfg.dtype)) if dtype is None else dtype
    lead = (stack,) if stack else ()
    kh, dh = cfg.n_kv_heads, cfg.head_dim

    def z(length):
        return jnp.zeros((*lead, batch, length, kh, dh), dtype)

    def zs(length):  # per-(token, head) dequant scales
        return jnp.zeros((*lead, batch, length, kh), jnp.bfloat16)

    if policy in ("static", "semistatic"):
        cap = cache_capacity(cfg, policy, length_hint)
        out = {"k": z(cap), "v": z(cap)}
        if quant:
            out["ks"] = zs(cap)
            out["vs"] = zs(cap)
        return out
    if policy == "paged":
        # Standalone slot: slabs pre-assigned batch-major (sequence b owns
        # slabs [b·maxp, (b+1)·maxp)).  BatchEngine instead manages pages
        # through a shared SlabAllocator (claim on growth, release on
        # completion) — see init_paged_caches/serving/engine.py.
        T = cfg.slab_tokens
        maxp = max(-(-length_hint // T), 1)
        n_slabs = batch * maxp
        base = jnp.arange(n_slabs, dtype=jnp.int32).reshape(batch, maxp)
        out = {
            "k_pool": jnp.zeros((*lead, n_slabs, T, kh, dh), dtype),
            "v_pool": jnp.zeros((*lead, n_slabs, T, kh, dh), dtype),
            "pages": jnp.broadcast_to(base, (*lead, batch, maxp)).copy()
            if lead
            else base,
        }
        if quant:
            out["ks_pool"] = jnp.zeros((*lead, n_slabs, T, kh), jnp.bfloat16)
            out["vs_pool"] = jnp.zeros((*lead, n_slabs, T, kh), jnp.bfloat16)
        return out
    nlevels = needed_levels(cfg.cache_b0, length_hint)
    cache: Cache = {}
    for lvl, size in enumerate(_level_shapes(cfg, nlevels)):
        cache[f"k{lvl}"] = z(size)
        cache[f"v{lvl}"] = z(size)
        if quant:
            cache[f"ks{lvl}"] = zs(size)
            cache[f"vs{lvl}"] = zs(size)
    return cache


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(…, L, KH, Dh) → int8 values + (…, L, KH) scales."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


import re as _re

_LEVEL_KEY = _re.compile(r"^k(\d+)$")


def _levels(cache: Cache) -> int:
    return sum(1 for key in cache if _LEVEL_KEY.match(key))


def _is_ggarray(cache: Cache) -> bool:
    return "k0" in cache


def _is_paged(cache: Cache) -> bool:
    return "k_pool" in cache


def _is_quant(cache: Cache) -> bool:
    return "ks0" in cache or "ks" in cache or "ks_pool" in cache


# ---- segmented pools (pool/extents) ---------------------------------------
# A paged pool value (``k_pool``/``v_pool``/``ks_pool``/``vs_pool``) is
# either a flat array (single-extent layout — the original trace) or a
# tuple of extents: growth appended an extent instead of copying the pool,
# and global slab ids resolve through the two-level (extent, offset) table.

def _pool_exts(pool) -> tuple[jax.Array, ...]:
    return tuple(pool) if isinstance(pool, (tuple, list)) else (pool,)


def _pool_first(pool) -> jax.Array:
    return _pool_exts(pool)[0]


def _pool_slabs(pool) -> int:
    return sum(e.shape[0] for e in _pool_exts(pool))


def _scatter_pool(pool, slab: jax.Array, slot: jax.Array, vals: jax.Array):
    """``pool.at[slab, slot].set(vals, mode="drop")`` through the extent
    table; ``slab`` entries < 0 or ≥ n_slabs drop.  Returns the pool in its
    own structure (flat array or tuple of extents)."""
    exts = _pool_exts(pool)
    if not isinstance(pool, (tuple, list)):
        S = exts[0].shape[0]
        tgt = jnp.where((slab >= 0) & (slab < S), slab, S)
        return exts[0].at[tgt, slot].set(vals, mode="drop")
    from repro.pool import extents as _extents

    ext_t, off_t = _extents.resolve_pages(
        slab, tuple(e.shape[0] for e in exts)
    )
    out = list(exts)
    for e, ext in enumerate(exts):
        tgt = jnp.where(ext_t == e, off_t, ext.shape[0])
        out[e] = ext.at[tgt, slot].set(vals, mode="drop")
    return tuple(out)


def copy_slab(pool, src: int, dst: int, *, axis: int = 0):
    """Device copy of one slab ``src → dst`` across the flat or extent
    layout — the **copy-on-write** private copy (DESIGN.md §10): a decode or
    chunk append that would write into a *shared* slab (refcount > 1) first
    duplicates that one slab into a fresh claim, then appends there, so the
    cached original is never mutated in place.

    ``src``/``dst`` are host ints, so extent routing is pure host
    arithmetic; the copy itself is one sliced gather + scatter on device
    (one slab's bytes — never the pool).  ``axis`` is the slab axis
    (0 for arena pools, 1 for the engine's period-stacked pools).
    """
    exts = list(_pool_exts(pool))
    flat = not isinstance(pool, (tuple, list))

    def locate(s: int) -> tuple[int, int]:
        base = 0
        for e, ext in enumerate(exts):
            if s < base + ext.shape[axis]:
                return e, s - base
            base += ext.shape[axis]
        raise IndexError(f"slab {s} outside pool of {base}")

    se, so = locate(src)
    de, do = locate(dst)
    lead = (slice(None),) * axis
    exts[de] = exts[de].at[lead + (do,)].set(exts[se][lead + (so,)])
    return exts[0] if flat else tuple(exts)


def _scatter_slab(pool, slab: jax.Array, vals: jax.Array):
    """Whole-slab scatter (``pool.at[slab].set``) through the extent table;
    ``slab`` entries < 0 or ≥ n_slabs drop."""
    exts = _pool_exts(pool)
    if not isinstance(pool, (tuple, list)):
        S = exts[0].shape[0]
        tgt = jnp.where((slab >= 0) & (slab < S), slab, S)
        return exts[0].at[tgt].set(vals, mode="drop")
    from repro.pool import extents as _extents

    ext_t, off_t = _extents.resolve_pages(
        slab, tuple(e.shape[0] for e in exts)
    )
    out = list(exts)
    for e, ext in enumerate(exts):
        tgt = jnp.where(ext_t == e, off_t, ext.shape[0])
        out[e] = ext.at[tgt].set(vals, mode="drop")
    return tuple(out)


def capacity_of(cache: Cache) -> int:
    """Sequence-slot capacity of one cache slot — static host-side metadata.

    Capacity is pytree *structure* (shapes), never device data, so the
    engine's per-step growth check costs zero transfers.  For paged caches
    this is the page-table reach (claimed or not); the live guarantee is the
    allocator's, not the shape's.
    """
    if _is_paged(cache):
        return cache["pages"].shape[-1] * _pool_first(cache["k_pool"]).shape[-3]
    if "k" in cache:
        return cache["k"].shape[-3]
    return indexing.capacity(cache["k0"].shape[-3], _levels(cache))


def grow_ggarray(cache: Cache, cfg: ModelConfig, levels: int = 1) -> Cache:
    """Copy-free growth: append the next geometric bucket level(s)."""
    n = _levels(cache)
    proto = cache["k0"]
    out = dict(cache)
    for lvl in range(n, n + levels):
        size = cfg.cache_b0 * (1 << lvl)
        shape = (*proto.shape[:-3], size, *proto.shape[-2:])
        out[f"k{lvl}"] = jnp.zeros(shape, proto.dtype)
        out[f"v{lvl}"] = jnp.zeros(shape, proto.dtype)
        if _is_quant(cache):
            sshape = (*proto.shape[:-3], size, proto.shape[-2])
            out[f"ks{lvl}"] = jnp.zeros(sshape, jnp.bfloat16)
            out[f"vs{lvl}"] = jnp.zeros(sshape, jnp.bfloat16)
    return out


def cache_bytes(cache: Cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


# --------------------------------------------------------------------------
# freeze / thaw — the two-phase handoff at the prefill → decode boundary.
#
# A ggarray cache's per-sequence layout is the LFVector address map: level
# ``lvl`` covers contiguous in-sequence positions [start_lvl, start_lvl +
# size_lvl).  Flattening is therefore a *static* concatenation along the seq
# axis (the kernels' segmented gather degenerates to a copy — there is no
# ragged per-block table here), and thaw is the inverse static slicing.
# Frozen caches use the static-policy layout, so ``attend`` takes the
# single-segment path: one softmax pass instead of one per bucket level —
# the "regular access" speed the paper's two-phase pattern is about.
# --------------------------------------------------------------------------

_KEY_AXIS = {"k": -3, "v": -3, "ks": -2, "vs": -2}


def freeze_cache(cache: Cache) -> Cache:
    """ggarray cache → contiguous static-layout cache (runtime freeze()).

    Pass-through keys (``cross_k``/``cross_v``, already-static caches) are
    preserved.  This is the once-per-phase O(n) copy the pattern amortizes.
    """
    if not _is_ggarray(cache):
        return dict(cache)
    n = _levels(cache)
    bases = ["k", "v"] + (["ks", "vs"] if _is_quant(cache) else [])
    out = {
        key: val
        for key, val in cache.items()
        if not any(key.startswith(b) and key[len(b) :].isdigit() for b in bases)
    }
    for base in bases:
        out[base] = jnp.concatenate(
            [cache[f"{base}{lvl}"] for lvl in range(n)], axis=_KEY_AXIS[base]
        )
    return out


def _slice_level(arr: jax.Array, lo: int, size: int, axis: int) -> jax.Array:
    """arr[..., lo:lo+size, ...] along ``axis``, zero-padded to ``size``."""
    axis = axis % arr.ndim
    cap = arr.shape[axis]
    take = max(min(cap - lo, size), 0)
    idx = [slice(None)] * arr.ndim
    idx[axis] = slice(lo, lo + take)
    seg = arr[tuple(idx)]
    if take < size:
        widths = [(0, 0)] * arr.ndim
        widths[axis] = (0, size - take)
        seg = jnp.pad(seg, widths)
    return seg


def thaw_cache(cache: Cache, b0: int) -> Cache:
    """Contiguous static-layout cache → ggarray cache (runtime thaw()).

    Produces the smallest bucket chain whose capacity covers the frozen
    buffer; the last level zero-pads past it.  Inverse of ``freeze_cache``
    up to that tail padding.
    """
    if _is_ggarray(cache):
        return dict(cache)
    cap = cache["k"].shape[-3]
    nlev = max(indexing.min_buckets_for(b0, cap), 1)
    starts = indexing.bucket_starts(b0, nlev)
    sizes = indexing.bucket_sizes(b0, nlev)
    bases = ["k", "v"] + (["ks", "vs"] if _is_quant(cache) else [])
    out = {key: val for key, val in cache.items() if key not in bases}
    for base in bases:
        for lvl in range(nlev):
            out[f"{base}{lvl}"] = _slice_level(
                cache[base], starts[lvl], sizes[lvl], _KEY_AXIS[base]
            )
    return out


# --------------------------------------------------------------------------
# append — push_back of one decode step. k/v: (B, 1, KH, Dh); pos: (B,) or ().
# --------------------------------------------------------------------------

def append(
    cache: Cache,
    k: jax.Array,
    v: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig | None = None,
) -> Cache:
    """``cfg`` (optional) threads ``kernel_memory_space`` to the fused
    push-back kernel; without it the kernel-layer default applies (hbm on
    TPU, vmem in interpret mode — ``kernels/common``)."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), k.shape[:1])  # (B,)
    rows = jnp.arange(k.shape[0])
    quant = _is_quant(cache)
    if quant:
        k, k_s = _quantize_kv(k)
        v, v_s = _quantize_kv(v)
    if _is_paged(cache):
        # scatter through the page table: slab = pages[b, pos // T].  An
        # unclaimed page (−1) or out-of-table position drops the write —
        # the idle-slot / truncation semantics of the batch engine.
        T = _pool_first(cache["k_pool"]).shape[-3]
        maxp = cache["pages"].shape[-1]
        pidx = jnp.clip(pos // T, 0, maxp - 1)
        slab = cache["pages"][rows, pidx]
        slab = jnp.where((slab >= 0) & (pos < maxp * T), slab, -1)  # ⇒ drop
        slot = pos % T
        if cfg is not None and cfg.instrument:
            # one decode token per lane; a −1 slab is a dropped (wasted) lane
            device.record(device.pack(**{
                "slab_append.waves": 1,
                "slab_append.lanes": int(k.shape[0]),
                "slab_append.active_lanes": jnp.sum((slab >= 0).astype(jnp.int32)),
            }))
        out = dict(cache)
        out["k_pool"] = _scatter_pool(cache["k_pool"], slab, slot, k[:, 0])
        out["v_pool"] = _scatter_pool(cache["v_pool"], slab, slot, v[:, 0])
        if quant:
            out["ks_pool"] = _scatter_pool(cache["ks_pool"], slab, slot, k_s[:, 0])
            out["vs_pool"] = _scatter_pool(cache["vs_pool"], slab, slot, v_s[:, 0])
        return out
    if not _is_ggarray(cache):
        cap = cache["k"].shape[-3]
        tgt = jnp.where(pos < cap, pos, cap)  # static policy truncates past cap
        out = {
            "k": cache["k"].at[rows, tgt].set(k[:, 0], mode="drop"),
            "v": cache["v"].at[rows, tgt].set(v[:, 0], mode="drop"),
        }
        if quant:
            out["ks"] = cache["ks"].at[rows, tgt].set(k_s[:, 0], mode="drop")
            out["vs"] = cache["vs"].at[rows, tgt].set(v_s[:, 0], mode="drop")
        return out
    # ggarray: the decode hot path is a one-lane-per-sequence wave (m=1),
    # which sits far below the measured fused-kernel crossover
    # (kernels/tuning.FUSED_PUSH_BACK_MIN_WAVE) — the empirical "auto"
    # resolution pins it to the jnp scan+scatter path (``use_ref``), which is
    # bit-identical and ~7× faster at this wave width.  Wider waves (batched
    # cache refill) go through the fused Pallas kernel: offsets + every-level
    # scatter in one aliased pass, all payloads (k/v + quant scales) sharing
    # one mask/permutation via the multi-group variant.
    from repro.kernels.push_back import ops as push_back_ops
    from repro.kernels.tuning import resolve_push_back_method

    n = _levels(cache)
    b0 = cache["k0"].shape[-3]
    lane = jnp.ones((k.shape[0], 1), bool)
    bases = ["k", "v"] + (["ks", "vs"] if quant else [])
    payloads = [k, v] + ([k_s, v_s] if quant else [])
    bucket_groups = tuple(
        tuple(cache[f"{base}{lvl}"] for lvl in range(n)) for base in bases
    )
    inst = cfg is not None and cfg.instrument
    outs = push_back_ops.push_back_fused_multi(
        bucket_groups, pos, b0, tuple(payloads), lane,
        use_ref=resolve_push_back_method("auto", k.shape[1]) != "fused",
        memory_space=cfg.kernel_memory_space if cfg is not None else None,
        instrument=inst,
    )
    groups = outs[0]
    if inst:
        device.record(outs[3])
    out = dict(cache)
    for base, levels in zip(bases, groups):
        for lvl in range(n):
            out[f"{base}{lvl}"] = levels[lvl]
    return out


# --------------------------------------------------------------------------
# attend — one-token attention against the cache (rw_b bucket walk).
# --------------------------------------------------------------------------

def _partial_scores(q, k, v, kpos, live_len, state):
    """Online-softmax update of ``state`` with one K/V segment.

    q: (B, KH, G, Dh) f32 · k/v: (B, L, KH, Dh) · kpos: (L,) global positions.
    """
    m, l, acc = state
    s = jnp.einsum("bkgd,blkd->bkgl", q, k.astype(jnp.float32))
    live = kpos[None, :] < live_len[:, None]  # (B, L)
    s = jnp.where(live[:, None, None, :], s, MASK_VALUE)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l = l * alpha + jnp.sum(p, axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum("bkgl,blkd->bkgd", p, v.astype(jnp.float32))
    return m_new, l, acc


def attend(
    cache: Cache, q: jax.Array, length: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """q: (B, 1, H, Dh); ``length``: live entries per sequence ((B,) or ()).

    Returns (B, 1, H, Dh).  For ggarray caches this is the paper's bucket
    walk: one partial-softmax pass per level, merged online — the O(log n)
    'multiple pointers' cost the paper measures in Fig. 5 is the extra
    per-level masking/merge here.  Paged caches walk the page table in the
    same geometric segmentation (level b = pages [2^b−1, 2^(b+1)−1), padded
    to the full level width), so with ``slab_tokens == cache_b0`` the result
    is **bit-exact** vs the ggarray walk whenever ``length ≥ 1`` — stale
    slab contents only ever sit behind exact-zero softmax weights.
    """
    B, _, H, Dh = q.shape
    kh = cfg.n_kv_heads
    g = H // kh
    scale = Dh ** -0.5
    qf = q[:, 0].reshape(B, kh, g, Dh).astype(jnp.float32) * scale
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    state = (
        jnp.full((B, kh, g), MASK_VALUE, jnp.float32),
        jnp.zeros((B, kh, g), jnp.float32),
        jnp.zeros((B, kh, g, Dh), jnp.float32),
    )
    quant = _is_quant(cache)

    def _kv(ck, cv, sk, sv):
        if not quant:
            return ck, cv
        return _dequant(ck, sk), _dequant(cv, sv)

    if _is_paged(cache):
        out = _attend_paged(cache, qf, length, cfg, state, _kv)
        return out.reshape(B, 1, H, Dh).astype(q.dtype)
    if _is_ggarray(cache):
        n = _levels(cache)
        b0 = cache["k0"].shape[-3]
        starts = indexing.bucket_starts(b0, n)
        for lvl in range(n):
            kpos = starts[lvl] + jnp.arange(cache[f"k{lvl}"].shape[-3])
            kk, vv = _kv(
                cache[f"k{lvl}"], cache[f"v{lvl}"],
                cache.get(f"ks{lvl}"), cache.get(f"vs{lvl}"),
            )
            state = _partial_scores(qf, kk, vv, kpos, length, state)
    else:
        kpos = jnp.arange(cache["k"].shape[-3])
        kk, vv = _kv(cache["k"], cache["v"], cache.get("ks"), cache.get("vs"))
        state = _partial_scores(qf, kk, vv, kpos, length, state)
    m, l, acc = state
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def _gather_pool(pool, grp: jax.Array) -> jax.Array:
    """pool (S, T, …) or tuple of extents, page group (B, w) → (B, w·T, …);
    −1 pages gather slab 0 (the values are dead: every lane they cover is
    softmax-masked).  Multi-extent pools resolve global ids through the
    two-level table and select per extent."""
    exts = _pool_exts(pool)
    T = exts[0].shape[1]
    B, w = grp.shape
    if len(exts) == 1:
        S = exts[0].shape[0]
        out = exts[0][jnp.clip(grp, 0, max(S - 1, 0))]  # (B, w, T, …)
        return out.reshape(B, w * T, *exts[0].shape[2:])
    from repro.pool import extents as _extents

    ext_t, off_t = _extents.resolve_pages(grp, tuple(e.shape[0] for e in exts))
    out = jnp.zeros((B, w, *exts[0].shape[1:]), exts[0].dtype)
    for e, ext in enumerate(exts):
        g = ext[jnp.clip(off_t, 0, ext.shape[0] - 1)]
        sel = (ext_t == e).reshape(B, w, *([1] * (g.ndim - 2)))
        out = jnp.where(sel, g, out)
    return out.reshape(B, w * T, *exts[0].shape[2:])


def _levels_walk_ctr(pages, length, T: int, npools: int) -> jax.Array:
    """Device counters for the jnp geometric-levels walk: every level is
    gathered at its padded-to-power-of-two width (−1 pages included — the
    walk masks them in softmax, it does not skip them), so ``masked_lanes``
    is the real over-read this path pays vs the gated Pallas kernel."""
    from repro.pool.arena import geometric_page_groups

    B = pages.shape[0]
    tiles = 0
    lanes = 0
    live_pages = jnp.zeros((), jnp.int32)
    masked = jnp.zeros((), jnp.int32)
    kv = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    for lo, hi in geometric_page_groups(pages.shape[-1]):
        full = 1
        while full < hi - lo:
            full *= 2
        tiles += B * full
        lanes += B * full * T
        live_pages = live_pages + jnp.sum((pages[:, lo:hi] >= 0).astype(jnp.int32))
        live_lanes = jnp.clip(kv - lo * T, 0, full * T)
        masked = masked + jnp.sum(full * T - live_lanes)
    return device.pack(**{
        "paged_gather.launches": npools,
        "paged_gather.tiles": npools * live_pages,
        "paged_gather.masked_tiles": npools * (tiles - live_pages),
        "paged_attend.launches": 1,
        "paged_attend.tiles": tiles,
        "paged_attend.lanes": lanes,
        "paged_attend.masked_lanes": masked,
    })


def _attend_paged(cache, qf, length, cfg, state, _kv):
    """The paged walk: geometric page groups, or the flash-decode kernel."""
    from repro.pool.arena import geometric_page_groups

    pages = cache["pages"]
    T = _pool_first(cache["k_pool"]).shape[-3]
    if cfg.paged_attend_impl == "pallas" and not _is_quant(cache):
        from repro.kernels.paged import ops as paged_ops

        if cfg.instrument:
            out, vec = paged_ops.paged_attend(
                qf, cache["k_pool"], cache["v_pool"], pages, length,
                memory_space=cfg.kernel_memory_space, instrument=True,
            )
            device.record(vec)
            return out
        return paged_ops.paged_attend(
            qf, cache["k_pool"], cache["v_pool"], pages, length,
            memory_space=cfg.kernel_memory_space,
        )
    if cfg.instrument:
        device.record(
            _levels_walk_ctr(pages, length, T, 4 if _is_quant(cache) else 2)
        )
    for lo, hi in geometric_page_groups(pages.shape[-1]):
        width = hi - lo
        full = 1
        while full < width:
            full *= 2
        grp = pages[:, lo:hi]
        if width < full:  # pad to the ggarray level width (exact no-op lanes)
            grp = jnp.pad(grp, ((0, 0), (0, full - width)), constant_values=-1)
        kk, vv = _kv(
            _gather_pool(cache["k_pool"], grp),
            _gather_pool(cache["v_pool"], grp),
            _gather_pool(cache["ks_pool"], grp) if "ks_pool" in cache else None,
            _gather_pool(cache["vs_pool"], grp) if "vs_pool" in cache else None,
        )
        kpos = lo * T + jnp.arange(full * T)
        state = _partial_scores(qf, kk, vv, kpos, length, state)
    m, l, acc = state
    return acc / jnp.maximum(l[..., None], 1e-30)


# --------------------------------------------------------------------------
# chunked prefill over a paged slot — prefix walk + in-chunk causal pass.
#
# Bit-exactness contract (DESIGN.md §7): with ``attention_chunk`` c in the
# cache's geometric chain and the prefill chunk size a multiple of c, the
# chunk/monolithic partitions put the same *live* score lanes into the same
# online-softmax updates, and dead lanes (pad tokens, unwritten slab slots,
# whole future chunks) contribute exactly 0.0 — ``exp(MASK_VALUE − m)``
# underflows to 0.0 and ``x + 0.0 == x`` — so chunked prefill reproduces the
# monolithic blockwise attention bit for bit.  The update body below is a
# verbatim transcription of ``attention._blockwise_attention``'s scan body
# for that reason: same einsums, same mask/max/exp/accumulate order.
# --------------------------------------------------------------------------


def _chunk_state_update(state, qr, kk, vv, live):
    """One online-softmax update — attention._blockwise_attention's body."""
    from repro.models.attention import SoftmaxState

    s = jnp.einsum("bqkgd,bckd->bqkgc", qr, kk.astype(jnp.float32))
    s = jnp.where(live, s, MASK_VALUE)
    m_new = jnp.maximum(state.m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(state.m - m_new)
    l = state.l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bqkgc,bckd->bqkgd", p, vv.astype(jnp.float32))
    acc = state.acc * alpha[..., None] + pv
    return SoftmaxState(m_new, l, acc)


def chunk_attend(
    cache: Cache,
    pages_row: jax.Array,  # (maxp,) claimed slab ids for this slot (−1 pad)
    q: jax.Array,  # (1, Cb, H, Dh) chunk queries
    k_chunk: jax.Array,  # (1, Cb, KH, Dh) chunk keys (pre-scatter)
    v_chunk: jax.Array,
    t0: jax.Array,  # () tokens already prefilled (chunk's global offset)
    live: jax.Array,  # () live tokens in this chunk (≤ Cb; rest is padding)
    cfg: ModelConfig,
    first: bool = False,  # STATIC t0 == 0: skip the (all-dead) prefix walk
) -> jax.Array:
    """Chunk-of-prefill attention for one paged slot → (1, Cb, H, Dh).

    The prefix ([0, t0), gathered through ``pages_row``) is walked in
    ``attention_chunk`` steps carrying the online-softmax state, then the
    chunk attends itself causally — one linear pass, exactly the monolithic
    chunk sequence restricted to this chunk's queries.  ``first=True`` skips
    the prefix walk: at t0 = 0 every prefix lane is dead, and dead-lane
    updates are exact no-ops (the §7 contract), so dropping them is
    bit-identical and saves the gather.
    """
    from repro.models.attention import SoftmaxState

    B, Sq, H, Dh = q.shape
    kh = cfg.n_kv_heads
    g = H // kh
    c = cfg.attention_chunk
    qr = q.reshape(B, Sq, kh, g, Dh).astype(jnp.float32) * (Dh ** -0.5)
    state = SoftmaxState(
        m=jnp.full((B, Sq, kh, g), MASK_VALUE, jnp.float32),
        l=jnp.zeros((B, Sq, kh, g), jnp.float32),
        acc=jnp.zeros((B, Sq, kh, g, Dh), jnp.float32),
    )
    quant = _is_quant(cache)

    def _kv(ck, cv, sk, sv):
        if not quant:
            return ck, cv
        return _dequant(ck, sk), _dequant(cv, sv)

    # ---- prefix: pool gather, fixed maxp·T width (one trace ∀ t0 > 0) ----
    T = _pool_first(cache["k_pool"]).shape[-3]
    Skv = pages_row.shape[0] * T
    if Skv and not first:
        if cfg.instrument:
            # fixed-width prefix gather: every page slot walked, −1 = waste
            np_ = 4 if quant else 2
            live_p = jnp.sum((pages_row >= 0).astype(jnp.int32))
            device.record(device.pack(**{
                "paged_gather.launches": np_,
                "paged_gather.tiles": np_ * live_p,
                "paged_gather.masked_tiles": np_ * (pages_row.shape[0] - live_p),
            }))
        grp = pages_row[None]  # (1, maxp)
        pk, pv_ = _kv(
            _gather_pool(cache["k_pool"], grp),
            _gather_pool(cache["v_pool"], grp),
            _gather_pool(cache["ks_pool"], grp) if quant else None,
            _gather_pool(cache["vs_pool"], grp) if quant else None,
        )
        cc = min(c, Skv)
        pad = (-Skv) % cc
        if pad:
            pk = jnp.pad(pk, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pv_ = jnp.pad(pv_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nch = pk.shape[1] // cc
        kc = jnp.moveaxis(pk.reshape(B, nch, cc, kh, Dh), 1, 0)
        vc = jnp.moveaxis(pv_.reshape(B, nch, cc, kh, Dh), 1, 0)

        def body(st, xs):
            ci, kk, vv = xs
            kpos = ci * cc + jnp.arange(cc)
            live_m = (kpos < t0)[None, None, None, None, :]
            return _chunk_state_update(st, qr, kk, vv, live_m), None

        state, _ = jax.lax.scan(body, state, (jnp.arange(nch), kc, vc))

    # ---- the chunk itself: causal, pad lanes (≥ live) dead ---------------
    co = min(c, Sq)
    pad = (-Sq) % co
    kc_own = jnp.pad(k_chunk, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k_chunk
    vc_own = jnp.pad(v_chunk, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v_chunk
    qpos = jnp.arange(Sq)
    for ci in range(kc_own.shape[1] // co):
        j = ci * co + jnp.arange(co)
        live_m = (j[None, :] < live) & (qpos[:, None] >= j[None, :])
        state = _chunk_state_update(
            state,
            qr,
            kc_own[:, ci * co : (ci + 1) * co],
            vc_own[:, ci * co : (ci + 1) * co],
            live_m[None, :, None, None, :],
        )
    out = state.acc / jnp.maximum(state.l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def scatter_chunk(
    cache: Cache,
    pages_row: jax.Array,  # (maxp,) claimed slab ids (−1 pad)
    k_chunk: jax.Array,  # (1, Cb, KH, Dh)
    v_chunk: jax.Array,
    t0: jax.Array,
    live: jax.Array,
    cfg: ModelConfig,
) -> Cache:
    """Write a chunk's live K/V into the slot's claimed slabs → new pools.

    Per-token int8 quantization is chunk-invariant, so the stored codes are
    identical to a monolithic fill.  Dead lanes (pad, unclaimed page) route
    to the out-of-bounds slab and drop.
    """
    T = _pool_first(cache["k_pool"]).shape[-3]
    maxp = pages_row.shape[0]
    Cb = k_chunk.shape[1]
    quant = _is_quant(cache)
    k, v = k_chunk[0], v_chunk[0]  # (Cb, KH, Dh)
    if quant:
        k, k_s = _quantize_kv(k)
        v, v_s = _quantize_kv(v)
    pos = t0 + jnp.arange(Cb)
    pidx = jnp.clip(pos // T, 0, maxp - 1)
    slab = pages_row[pidx]
    ok = (jnp.arange(Cb) < live) & (slab >= 0) & (pos < maxp * T)
    slab = jnp.where(ok, slab, -1)  # dead lanes ⇒ mode="drop"
    slot = pos % T
    if cfg.instrument:
        device.record(device.pack(**{
            "slab_append.waves": 1,
            "slab_append.lanes": Cb,
            "slab_append.active_lanes": jnp.sum(ok.astype(jnp.int32)),
        }))
    out = dict(cache)
    out["k_pool"] = _scatter_pool(cache["k_pool"], slab, slot, k)
    out["v_pool"] = _scatter_pool(cache["v_pool"], slab, slot, v)
    if quant:
        out["ks_pool"] = _scatter_pool(cache["ks_pool"], slab, slot, k_s)
        out["vs_pool"] = _scatter_pool(cache["vs_pool"], slab, slot, v_s)
    return out


# --------------------------------------------------------------------------
# prefill → cache (the phase transition: contiguous K/V sliced into buckets).
# --------------------------------------------------------------------------

def fill_from_prefill(
    cache: Cache, k_full: jax.Array, v_full: jax.Array
) -> Cache:
    """Load (B, S, KH, Dh) prefill K/V into an (empty) cache slot.

    ggarray: bucket b receives the contiguous slice [start_b, start_b+len_b)
    — static slicing, no search (the inverse of ``flatten``).
    """
    S = k_full.shape[1]
    quant = _is_quant(cache)
    k_s = v_s = None
    if quant:
        k_full, k_s = _quantize_kv(k_full)
        v_full, v_s = _quantize_kv(v_full)
    if _is_paged(cache):
        # page-sliced scatter: page p takes positions [p·T, (p+1)·T); rows
        # whose page is unclaimed drop (shorter sequences in the batch)
        T = _pool_first(cache["k_pool"]).shape[-3]
        maxp = cache["pages"].shape[-1]
        npages = min(-(-S // T), maxp)
        rows = jnp.arange(k_full.shape[0])

        def _seg(x, p):  # (B, ≤T, …) zero-padded to T
            seg = x[:, p * T : (p + 1) * T]
            if seg.shape[1] < T:
                widths = [(0, 0)] * x.ndim
                widths[1] = (0, T - seg.shape[1])
                seg = jnp.pad(seg, widths)
            return seg

        out = dict(cache)
        for p in range(npages):
            slab = cache["pages"][rows, p]  # −1 unclaimed ⇒ drop
            out["k_pool"] = _scatter_slab(out["k_pool"], slab, _seg(k_full, p))
            out["v_pool"] = _scatter_slab(out["v_pool"], slab, _seg(v_full, p))
            if quant:
                out["ks_pool"] = _scatter_slab(out["ks_pool"], slab, _seg(k_s, p))
                out["vs_pool"] = _scatter_slab(out["vs_pool"], slab, _seg(v_s, p))
        return out
    if not _is_ggarray(cache):
        cap = cache["k"].shape[-3]
        n = min(S, cap)
        out = {
            "k": cache["k"].at[:, :n].set(k_full[:, :n]),
            "v": cache["v"].at[:, :n].set(v_full[:, :n]),
        }
        if quant:
            out["ks"] = cache["ks"].at[:, :n].set(k_s[:, :n])
            out["vs"] = cache["vs"].at[:, :n].set(v_s[:, :n])
        return out
    nlev = _levels(cache)
    b0 = cache["k0"].shape[-3]
    starts = indexing.bucket_starts(b0, nlev)
    sizes = indexing.bucket_sizes(b0, nlev)
    out = dict(cache)
    for lvl in range(nlev):
        lo = starts[lvl]
        if lo >= S:
            break
        n = min(sizes[lvl], S - lo)
        out[f"k{lvl}"] = cache[f"k{lvl}"].at[:, :n].set(k_full[:, lo : lo + n])
        out[f"v{lvl}"] = cache[f"v{lvl}"].at[:, :n].set(v_full[:, lo : lo + n])
        if quant:
            out[f"ks{lvl}"] = cache[f"ks{lvl}"].at[:, :n].set(k_s[:, lo : lo + n])
            out[f"vs{lvl}"] = cache[f"vs{lvl}"].at[:, :n].set(v_s[:, lo : lo + n])
    return out
