"""Token sampling: greedy / temperature."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample"]


def sample(key: jax.Array, logits: jax.Array, temperature: float = 0.0) -> jax.Array:
    """logits (B, V) → tokens (B,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)
