from repro.serving import engine, kvcache, sampler, steps

__all__ = ["engine", "kvcache", "sampler", "steps"]
