from repro.serving import engine, kvcache, prefix, sampler, steps

__all__ = ["engine", "kvcache", "prefix", "sampler", "steps"]
