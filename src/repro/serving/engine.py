"""Batched serving engine with growth-on-demand KV caches.

The engine realizes the paper's runtime dynamics end to end: prompts are
prefetched into a cache sized for *the prompt only* (no worst-case
pre-allocation), then decode pushes tokens until capacity, at which point the
policy's growth event fires:

- ``ggarray``   → ``grow_ggarray``: allocate the next geometric bucket,
                  **no copy**; the step function recompiles once per level
                  (O(log n) total, warm-cached thereafter).
- ``semistatic``→ doubling realloc: allocate 2× and copy every live K/V byte.
- ``static``    → no growth; the engine must have pre-allocated ``max_len``
                  up front (the worst-case VRAM the paper's Fig. 3 prices).
- ``two_phase`` → the paper's §VI.D pattern as a serving policy: prefill
                  grows a ggarray cache (copy-free), then the cache is
                  **frozen** (``freeze_cache``) into the contiguous static
                  layout before decode, so every decode step attends in one
                  softmax pass instead of one per bucket level.  On capacity
                  exhaustion the engine thaws → grows a bucket → refreezes
                  (an O(n) copy, but only O(log n) times over a generation —
                  the amortized freeze the runtime's TwoPhasePipeline models).

The decode loop follows the host-sync-free protocol (DESIGN.md §2): the
jitted step **donates** the cache pytree (K/V scatters reuse the input
buffers instead of double-buffering the cache), the capacity/growth check is
pure host arithmetic against a length mirror (decode appends exactly one
slot per step), and sampled tokens are materialized once after the loop —
so a generation's device→host contacts are O(log n) growth events plus one
final token transfer, not O(steps).

``Engine.stats`` exposes alloc/copy/grow counters and byte volumes so the
benchmarks can reproduce the paper's Table II / Fig. 6 structure.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving import kvcache, steps
from repro.serving.sampler import sample

__all__ = ["Engine", "EngineStats"]


@dataclasses.dataclass
class EngineStats:
    grow_events: int = 0
    freeze_events: int = 0
    copied_bytes: int = 0
    allocated_bytes: int = 0
    decode_steps: int = 0
    compiles: int = 0


class Engine:
    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        policy: str | None = None,
        max_len: int = 4096,
        seed: int = 0,
    ):
        self.params = params
        self.cfg = cfg
        self.policy = cfg.cache_policy if policy is None else policy
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self._decode_compiled: dict[Any, Any] = {}

    # -- capacity of the current cache (seq slots) -------------------------
    def _capacity(self, caches) -> int:
        for slot, kind in enumerate(self.cfg.layout):
            if kind == "attn":
                return kvcache.capacity_of(caches[slot])
        return 1 << 30  # attention-free: no cache capacity limit

    def _grow(self, caches) -> list:
        """Policy growth event; updates stats with alloc/copy volumes."""
        self.stats.grow_events += 1
        cfg = self.cfg
        out = []
        for slot, kind in enumerate(cfg.layout):
            c = caches[slot]
            if kind != "attn":
                out.append(c)
                continue
            if self.policy == "ggarray":
                grown = kvcache.grow_ggarray(c, cfg)
                self.stats.allocated_bytes += kvcache.cache_bytes(grown) - kvcache.cache_bytes(c)
                out.append(grown)
            elif self.policy == "two_phase":
                # thaw → add a bucket (copy-free) → refreeze for flat decode.
                grown = kvcache.grow_ggarray(kvcache.thaw_cache(c, cfg.cache_b0), cfg)
                frozen = kvcache.freeze_cache(grown)
                self.stats.copied_bytes += kvcache.cache_bytes(c)
                self.stats.allocated_bytes += kvcache.cache_bytes(frozen) - kvcache.cache_bytes(c)
                self.stats.freeze_events += 1
                out.append(frozen)
            elif self.policy == "semistatic":
                old_k, old_v = c["k"], c["v"]
                cap = old_k.shape[-3]
                new_k = jnp.zeros((*old_k.shape[:-3], cap * 2, *old_k.shape[-2:]), old_k.dtype)
                new_v = jnp.zeros_like(new_k)
                # THE copy (realloc semantics — what GGArray avoids)
                new_k = jax.lax.dynamic_update_slice_in_dim(new_k, old_k, 0, axis=old_k.ndim - 3)
                new_v = jax.lax.dynamic_update_slice_in_dim(new_v, old_v, 0, axis=old_v.ndim - 3)
                self.stats.allocated_bytes += kvcache.cache_bytes({"k": new_k, "v": new_v})
                self.stats.copied_bytes += kvcache.cache_bytes(c)
                out.append(dict(c, k=new_k, v=new_v))
            else:
                raise RuntimeError("static cache cannot grow: pre-allocate max_len")
        return out

    def _decode_fn(self, caches):
        """jit'd decode_step per cache pytree structure (growth ⇒ new entry).

        The cache argument is **donated**: the step scatters the new K/V into
        the input buffers instead of double-buffering the whole cache, and
        the engine rebinds the returned pytree each step.  One executable per
        bucket structure → O(log n) compiles over a generation.
        """
        key = jax.tree.structure((caches,))
        if key not in self._decode_compiled:
            self.stats.compiles += 1
            cfg = self.cfg

            @functools.partial(jax.jit, donate_argnums=(2,))
            def fn(params, token, caches, length):
                return steps.decode_step(params, token, caches, length, cfg)

            self._decode_compiled[key] = fn
        return self._decode_compiled[key]

    # -- public API --------------------------------------------------------
    def generate(
        self,
        prompts: list[list[int]],
        max_new_tokens: int,
        *,
        temperature: float = 0.0,
    ) -> list[list[int]]:
        cfg = self.cfg
        B = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        Lp = int(lens.max())
        toks = np.zeros((B, Lp), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p

        hint = Lp if self.policy != "static" else self.max_len
        # two_phase: the grow phase is a ggarray prefill; frozen below.
        prefill_policy = "ggarray" if self.policy == "two_phase" else self.policy
        logits, caches = steps.prefill(
            self.params, jnp.asarray(toks), cfg,
            capacity_hint=hint, policy=prefill_policy, lengths=jnp.asarray(lens),
        )
        if self.policy == "two_phase":
            caches = [
                kvcache.freeze_cache(c) if kind == "attn" else c
                for c, kind in zip(caches, cfg.layout)
            ]
            self.stats.freeze_events += 1
        self.stats.allocated_bytes += sum(
            kvcache.cache_bytes(c) for c, k in zip(caches, cfg.layout) if k == "attn"
        )
        lengths = jnp.asarray(lens)
        # Host mirror of the longest live context: decode appends exactly one
        # slot per step, so the growth check is pure host arithmetic — the
        # amortized protocol touches the device only at actual growth events
        # (O(log n) per generation), never per step.
        max_len_host = int(lens.max())
        out = [list(p) for p in prompts]
        self.key, k = jax.random.split(self.key)
        sampled = [sample(k, logits, temperature)]

        for _ in range(max_new_tokens - 1):
            if max_len_host + 1 >= self._capacity(caches) and self.policy != "static":
                caches = self._grow(caches)
            fn = self._decode_fn(caches)
            logits, caches = fn(self.params, sampled[-1], caches, lengths)
            lengths = lengths + 1
            max_len_host += 1
            self.stats.decode_steps += 1
            self.key, k = jax.random.split(self.key)
            sampled.append(sample(k, logits, temperature))
        # one transfer for the whole generation, after the loop dispatched
        tokens = np.asarray(jax.device_get(jnp.stack(sampled)))  # (T, B)
        for i in range(B):
            out[i].extend(int(t) for t in tokens[:, i])
        return out
