"""Batched serving engine with growth-on-demand KV caches.

The engine realizes the paper's runtime dynamics end to end: prompts are
prefetched into a cache sized for *the prompt only* (no worst-case
pre-allocation), then decode pushes tokens until capacity, at which point the
policy's growth event fires:

- ``ggarray``   → ``grow_ggarray``: allocate the next geometric bucket,
                  **no copy**; the step function recompiles once per level
                  (O(log n) total, warm-cached thereafter).
- ``semistatic``→ doubling realloc: allocate 2× and copy every live K/V byte.
- ``static``    → no growth; the engine must have pre-allocated ``max_len``
                  up front (the worst-case VRAM the paper's Fig. 3 prices).
- ``two_phase`` → the paper's §VI.D pattern as a serving policy: prefill
                  grows a ggarray cache (copy-free), then the cache is
                  **frozen** (``freeze_cache``) into the contiguous static
                  layout before decode, so every decode step attends in one
                  softmax pass instead of one per bucket level.  On capacity
                  exhaustion the engine thaws → grows a bucket → refreezes
                  (an O(n) copy, but only O(log n) times over a generation —
                  the amortized freeze the runtime's TwoPhasePipeline models).

The decode loop follows the host-sync-free protocol (DESIGN.md §2): the
jitted step **donates** the cache pytree (K/V scatters reuse the input
buffers instead of double-buffering the cache), the capacity/growth check is
pure host arithmetic against a length mirror (decode appends exactly one
slot per step), and sampled tokens are materialized once after the loop —
so a generation's device→host contacts are O(log n) growth events plus one
final token transfer, not O(steps).

``Engine.stats`` exposes alloc/copy/grow counters and byte volumes so the
benchmarks can reproduce the paper's Table II / Fig. 6 structure.

A fifth policy lives in its own engine: :class:`BatchEngine` serves the
``paged`` cache policy (the slab arena, DESIGN.md §4) with **continuous
batching** — per-request admit/evict into a fixed slot grid, one shared slab
pool for the whole fleet, slab reclamation when a sequence completes.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.obs import DeviceCounterPlane, ServingTimeline
from repro.serving import kvcache, prefix as prefix_mod, scheduler as sched_mod, steps
from repro.serving.sampler import sample

__all__ = ["Engine", "EngineStats", "BatchEngine", "BatchStats", "Request"]


# --------------------------------------------------------------------------
# Shared jit factories — keyed on the (hashable, frozen) ModelConfig, so
# every engine instance serving the same config reuses one traced executable
# instead of re-tracing per instance (``jax.jit`` caches per function object:
# a per-engine ``functools.partial`` made warm-up engines useless).
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _decode_step_fn(cfg: ModelConfig):
    return jax.jit(
        functools.partial(steps.decode_step, cfg=cfg), donate_argnums=(2,)
    )


@functools.lru_cache(maxsize=None)
def _prefill_chunk_fn(cfg: ModelConfig):
    return jax.jit(
        functools.partial(steps.prefill_chunk, cfg=cfg),
        donate_argnums=(2,), static_argnames=("first",),
    )


class _StatsView:
    """Base for the legacy ``*Stats`` surfaces: read-only properties over an
    ``obs`` metrics registry.  The dataclass field names survive; the engine
    writes the registry, the view computes on read — one source of truth.
    """

    def __init__(self, registry):
        self._reg = registry

    def _ct(self, name: str) -> int:
        return int(self._reg.counter(name).total())

    def _hwm(self, name: str) -> int:
        return int(self._reg.gauge(name).hwm())

    def __repr__(self) -> str:
        fields = ", ".join(
            f"{n}={getattr(self, n)}"
            for n in dir(type(self))
            if isinstance(getattr(type(self), n), property)
        )
        return f"{type(self).__name__}({fields})"


class EngineStats(_StatsView):
    """Legacy Engine counters — a thin view over ``engine.obs.registry``."""

    grow_events = property(lambda s: s._ct("engine.grow_events"))
    freeze_events = property(lambda s: s._ct("engine.freeze_events"))
    copied_bytes = property(lambda s: s._ct("engine.copied_bytes"))
    allocated_bytes = property(lambda s: s._ct("engine.allocated_bytes"))
    decode_steps = property(lambda s: s._ct("engine.decode_steps"))
    compiles = property(lambda s: s._ct("engine.compiles"))


class Engine:
    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        policy: str | None = None,
        max_len: int = 4096,
        instrument: bool = False,
        seed: int = 0,
        obs: ServingTimeline | None = None,
    ):
        if instrument:
            cfg = dataclasses.replace(cfg, instrument=True)
        self.params = params
        self.cfg = cfg
        self.policy = cfg.cache_policy if policy is None else policy
        if self.policy == "paged":
            raise ValueError(
                "the paged (slab-arena) policy is served by BatchEngine, "
                "which owns the pool/page-table lifecycle"
            )
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.obs = obs if obs is not None else ServingTimeline()
        self.stats = EngineStats(self.obs.registry)
        self.devctr = DeviceCounterPlane(self.obs.registry)
        self._decode_compiled: dict[Any, Any] = {}

    def _host_read(self, x, site: str):
        """The audited device→host read: every transfer lands in one metric."""
        self.obs.registry.counter(
            "serve.host_syncs", "device→host reads, by site"
        ).inc(site=site)
        return jax.device_get(x)

    # -- capacity of the current cache (seq slots) -------------------------
    def _capacity(self, caches) -> int:
        for slot, kind in enumerate(self.cfg.layout):
            if kind == "attn":
                return kvcache.capacity_of(caches[slot])
        return 1 << 30  # attention-free: no cache capacity limit

    def _grow(self, caches) -> list:
        """Policy growth event; updates metrics with alloc/copy volumes."""
        reg = self.obs.registry
        reg.counter("engine.grow_events").inc()
        self.obs.event("grow", policy=self.policy)
        cfg = self.cfg
        out = []
        for slot, kind in enumerate(cfg.layout):
            c = caches[slot]
            if kind != "attn":
                out.append(c)
                continue
            if self.policy == "ggarray":
                grown = kvcache.grow_ggarray(c, cfg)
                reg.counter("engine.allocated_bytes").inc(
                    kvcache.cache_bytes(grown) - kvcache.cache_bytes(c)
                )
                out.append(grown)
            elif self.policy == "two_phase":
                # thaw → add a bucket (copy-free) → refreeze for flat decode.
                grown = kvcache.grow_ggarray(kvcache.thaw_cache(c, cfg.cache_b0), cfg)
                frozen = kvcache.freeze_cache(grown)
                reg.counter("engine.copied_bytes").inc(kvcache.cache_bytes(c))
                reg.counter("engine.allocated_bytes").inc(
                    kvcache.cache_bytes(frozen) - kvcache.cache_bytes(c)
                )
                reg.counter("engine.freeze_events").inc()
                out.append(frozen)
            elif self.policy == "semistatic":
                old_k, old_v = c["k"], c["v"]
                cap = old_k.shape[-3]
                new_k = jnp.zeros((*old_k.shape[:-3], cap * 2, *old_k.shape[-2:]), old_k.dtype)
                new_v = jnp.zeros_like(new_k)
                # THE copy (realloc semantics — what GGArray avoids)
                new_k = jax.lax.dynamic_update_slice_in_dim(new_k, old_k, 0, axis=old_k.ndim - 3)
                new_v = jax.lax.dynamic_update_slice_in_dim(new_v, old_v, 0, axis=old_v.ndim - 3)
                reg.counter("engine.allocated_bytes").inc(
                    kvcache.cache_bytes({"k": new_k, "v": new_v})
                )
                reg.counter("engine.copied_bytes").inc(kvcache.cache_bytes(c))
                out.append(dict(c, k=new_k, v=new_v))
            else:
                raise RuntimeError("static cache cannot grow: pre-allocate max_len")
        return out

    def _decode_fn(self, caches):
        """jit'd decode_step per cache pytree structure (growth ⇒ new entry).

        The cache argument is **donated**: the step scatters the new K/V into
        the input buffers instead of double-buffering the whole cache, and
        the engine rebinds the returned pytree each step.  One executable per
        bucket structure → O(log n) compiles over a generation.
        """
        key = jax.tree.structure((caches,))
        if key not in self._decode_compiled:
            self.obs.registry.counter("engine.compiles").inc()
            cfg = self.cfg

            @functools.partial(jax.jit, donate_argnums=(2,))
            def fn(params, token, caches, length):
                return steps.decode_step(params, token, caches, length, cfg)

            self._decode_compiled[key] = fn
        return self._decode_compiled[key]

    # -- public API --------------------------------------------------------
    def generate(
        self,
        prompts: list[list[int]],
        max_new_tokens: int,
        *,
        temperature: float = 0.0,
    ) -> list[list[int]]:
        cfg = self.cfg
        B = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        Lp = int(lens.max())
        toks = np.zeros((B, Lp), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p

        hint = Lp if self.policy != "static" else self.max_len
        # two_phase: the grow phase is a ggarray prefill; frozen below.
        prefill_policy = "ggarray" if self.policy == "two_phase" else self.policy
        logits, caches = steps.prefill(
            self.params, jnp.asarray(toks), cfg,
            capacity_hint=hint, policy=prefill_policy, lengths=jnp.asarray(lens),
        )
        if self.policy == "two_phase":
            caches = [
                kvcache.freeze_cache(c) if kind == "attn" else c
                for c, kind in zip(caches, cfg.layout)
            ]
            self.obs.registry.counter("engine.freeze_events").inc()
        self.obs.registry.counter("engine.allocated_bytes").inc(sum(
            kvcache.cache_bytes(c) for c, k in zip(caches, cfg.layout) if k == "attn"
        ))
        lengths = jnp.asarray(lens)
        # Host mirror of the longest live context: decode appends exactly one
        # slot per step, so the growth check is pure host arithmetic — the
        # amortized protocol touches the device only at actual growth events
        # (O(log n) per generation), never per step.
        max_len_host = int(lens.max())
        out = [list(p) for p in prompts]
        self.key, k = jax.random.split(self.key)
        sampled = [sample(k, logits, temperature)]

        for _ in range(max_new_tokens - 1):
            if max_len_host + 1 >= self._capacity(caches) and self.policy != "static":
                caches = self._grow(caches)
            fn = self._decode_fn(caches)
            if cfg.instrument:
                logits, caches, ctr = fn(self.params, sampled[-1], caches, lengths)
                self.devctr.add(ctr)
            else:
                logits, caches = fn(self.params, sampled[-1], caches, lengths)
            lengths = lengths + 1
            max_len_host += 1
            self.obs.registry.counter("engine.decode_steps").inc()
            self.key, k = jax.random.split(self.key)
            sampled.append(sample(k, logits, temperature))
        # one transfer for the whole generation, after the loop dispatched
        tokens = np.asarray(self._host_read(jnp.stack(sampled), "token_drain"))  # (T, B)
        for i in range(B):
            out[i].extend(int(t) for t in tokens[:, i])
        return out


# --------------------------------------------------------------------------
# BatchEngine — continuous batching over the slab arena (policy="paged").
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One sequence in flight: prompt in, ``max_new_tokens`` greedy out."""

    rid: int
    prompt: list[int]
    max_new_tokens: int
    slot: int = -1
    admit_step: int = -1  # index into the decode stream at admission
    generated: int = 0  # tokens sampled so far (incl. the prefill sample)
    first_tok: Any = None  # device scalar — materialized once, at the end
    done: bool = False
    submit_t: float = 0.0  # host wall-clock at submit()
    queue_wait: float = 0.0  # submit → admission (seconds)
    ttft: float = 0.0  # submit → first sampled token (dispatch wall-clock)
    decode_s: float = 0.0  # wall-clock spent in decode steps this req was in
    tpot_ms: float = 0.0  # decode_s / (generated − 1), set at completion


class BatchStats(_StatsView):
    """Legacy BatchEngine counters — a thin view over ``be.obs.registry``.

    The field names of the old dataclass survive unchanged; each is now a
    read of the metrics registry (DESIGN.md §9 catalog), so the legacy view
    and the telemetry snapshot agree by construction.  ``peak_*`` are gauge
    high-water marks; ``host_syncs`` is the total across *every* audited
    device→host read site (``serve.host_syncs{site=…}``), not just the
    stop-token drain.
    """

    admitted = property(lambda s: s._ct("serve.admitted"))
    completed = property(lambda s: s._ct("serve.completed"))
    prefills = property(lambda s: s._ct("serve.prefills"))
    prefill_chunks = property(lambda s: s._ct("serve.prefill_chunks"))
    prefill_traces = property(
        lambda s: int(s._reg.gauge("serve.prefill_traces").value())
    )
    decode_steps = property(lambda s: s._ct("serve.decode_steps"))
    pool_grow_events = property(lambda s: s._ct("pool.grow_events"))
    pool_copied_bytes = property(lambda s: s._ct("pool.copied_bytes"))
    grown_slabs = property(lambda s: s._ct("pool.grown_slabs"))
    reused_slabs = property(lambda s: s._ct("pool.reused_slabs"))
    released_slabs = property(lambda s: s._ct("pool.released_slabs"))
    prefix_hits = property(lambda s: s._ct("serve.prefix_hits"))
    prefix_tokens_reused = property(lambda s: s._ct("serve.prefix_tokens_reused"))
    cow_copies = property(lambda s: s._ct("serve.cow_copies"))
    peak_live_tokens = property(lambda s: s._hwm("pool.live_tokens"))
    peak_pool_tokens = property(lambda s: s._hwm("pool.capacity_tokens"))
    host_syncs = property(lambda s: s._ct("serve.host_syncs"))


class BatchEngine:
    """Continuous-batch serving over one shared slab pool (DESIGN.md §4).

    ``max_batch`` decode *slots* run in lockstep; requests stream through
    them: admit → prefill → batched donated decode steps (idle slots are
    inert: their page rows are −1 so appends drop, and zero lengths mask
    their attention) → completion (slabs released to the free list, slot
    re-admitted).  All per-layer caches share one page table per sequence;
    K/V pools are per scan period.

    Admission (``admission=``, see ``serving/scheduler``):

    - ``"chunked"`` (default) — the scheduler reserves the prompt's whole
      slab need up front, then streams the prompt through ``prefill_chunk``
      in bucket-padded windows *interleaved with decode steps* (vLLM-style
      chunked prefill).  Prefill compiles O(log chunk) traces total; decode
      keeps running for already-admitted sequences while new prompts fill.
      A prefilling slot is inert to the decode step: its device page row
      stays −1 (appends drop), its length is 0 (attention masked), and the
      ``active`` mask gates its Mamba state rows; its claimed pages land in
      the device table only on the final chunk.  Attention is bit-identical
      to monolithic admission (dead-lane contract, DESIGN.md §7); int8
      caches attend the *dequantized* prefix on chunks after the first, so
      multi-chunk quantized prompts are approximate (stored codes still
      match exactly).
    - ``"monolithic"`` — the original path: one eager whole-prompt prefill
      scattered into the claimed slabs at admission (compiles per prompt
      length, decode stalls for the whole prompt).

    Scheduling is **host-sync-free** by default: completion is budget
    arithmetic on host length mirrors, and every sampled token stays on
    device until ``run()`` materializes the whole stream in one transfer.
    Passing ``stop_token`` trades that for one (B,) read per step (counted
    in ``stats.host_syncs``).

    Pool sizing: the pool grows only when the free list is exhausted
    (released slabs are always reused first), by
    ``pool.planner.growth_amount(n_slabs, shortfall, grow_chunk)`` slabs.
    With the default ``grow_chunk=1`` capacity tracks demand exactly: at
    every instant ``pool_tokens ≤ live_tokens + slab_tokens ·
    active_sequences`` — the fleet-level analog of the paper's 2× bound,
    asserted in the acceptance test.  ``grow_chunk="geometric"`` doubles the
    pool instead (O(log slabs) realloc copies over a run), and a high-water
    pre-carve trades idle capacity for zero growth copies at steady state.

    ``grow_chunk="doubling"`` / ``"tz"`` select the **segmented extent
    layout** (``pool/extents``, DESIGN.md §8): the K/V pools become tuples
    of extents and growth *appends an extent* instead of realloc-copying —
    ``stats.pool_copied_bytes`` stays 0 for the whole run.  Global slab ids
    are unchanged (extent-order), so page tables, the free bitmap, and the
    allocator are identical across layouts; the attention/scatter paths
    resolve ids through the host-derived two-level (extent, offset) table.
    Growth sizing counts reserved-but-unclaimed slabs from in-flight chunked
    prefills as committed demand, so converting a reservation to claims
    cannot trigger an immediate second grow.  Each growth changes the cache
    pytree structure → one decode retrace per extent (O(log n) under
    doubling, O(√n) under tz — the same boundary-recompile pattern as
    ggarray bucket growth).

    ``prefix_cache=True`` (chunked, attention-only layouts) turns on
    **copy-on-write prefix caching** (DESIGN.md §10): completed prompts
    publish their full slabs into a host-side trie; a new request aliases
    the longest cached prefix into its page table (refcount++, zero bytes
    moved) and prefills only the uncached suffix — a fully cached prompt
    admits with zero prefill chunks and takes its first token from the
    first decode step.  Appends into a shared slab copy that one slab first
    (``serve.cow_copies``), so cached data is never mutated in place and
    outputs stay bit-exact vs cold-start.  Off by default: retained cached
    slabs intentionally outlive their sequences, which relaxes the tight
    pool-capacity bound above (LRU eviction under pool pressure bounds the
    retention instead).

    Kernel memory space follows ``cfg.kernel_memory_space``
    (``kernels/common``: hbm on TPU, vmem in interpret mode by default).
    """

    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        max_batch: int = 8,
        grow_chunk: int | str = 1,
        quota_slabs: int | None = None,
        stop_token: int | None = None,
        admission: str = "chunked",
        prefill_chunk: int | None = None,
        max_chunks_per_step: int | None = None,
        initial_slabs: int = 0,
        max_pages_hint: int = 0,
        prefix_cache: bool = False,
        instrument: bool = False,
        seed: int = 0,
        obs: ServingTimeline | None = None,
    ):
        from repro.pool import PageBook, is_extent_schedule

        if instrument:
            # baked into the (frozen, hashable) config so the shared jit
            # factories key on it: an uninstrumented engine reuses the
            # pre-PR executables byte for byte (compile-spy tested)
            cfg = dataclasses.replace(cfg, instrument=True)
        if cfg.n_enc_layers or cfg.n_prefix_embeds:
            raise NotImplementedError("BatchEngine serves decoder-only stacks")
        if admission not in ("chunked", "monolithic"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if prefix_cache and admission != "chunked":
            raise ValueError("prefix_cache requires chunked admission")
        if prefix_cache and "mamba" in cfg.layout:
            # SSM state is a recurrence, not a page table: a cached prefix
            # carries no conv/SSD state to resume from, so hybrid layouts
            # must prefill every prompt token.
            raise ValueError("prefix_cache requires an attention-only layout")
        self.params = params
        self.cfg = cfg
        self.T = cfg.slab_tokens
        self.B = max_batch
        self.grow_chunk = grow_chunk
        # "doubling"/"tz" → segmented extent pools (zero-copy growth);
        # _extent_sizes mirrors the tuple structure of every pool entry.
        self._extent_mode = is_extent_schedule(grow_chunk)
        self._extent_sizes: list[int] = [0] if self._extent_mode else []
        self.stop_token = stop_token
        self.admission = admission
        self.key = jax.random.PRNGKey(seed)
        self.obs = obs if obs is not None else ServingTimeline()
        self.stats = BatchStats(self.obs.registry)
        # device counter plane (DESIGN.md §9.x): step functions hand their
        # counter vectors here; draining stays lazy (Counter.add_lazy)
        self.devctr = DeviceCounterPlane(self.obs.registry)
        # shared host bookkeeping (same object the arena uses): allocator +
        # per-slot page counts + slab→page mapping + table-width policy
        self.book = PageBook(max_batch, quota_slabs=quota_slabs)
        # device-side free-list bitmap (mirrors alloc.free; tests cross-check)
        self.free_dev = jnp.ones((0,), bool)
        self._len_host = np.zeros((max_batch,), np.int64)
        self.caches = self._init_caches()
        self.lengths = jnp.zeros((max_batch,), jnp.int32)
        self.cur_tok = jnp.zeros((max_batch,), jnp.int32)
        self._slots: list[Request | None] = [None] * max_batch
        self._pending: collections.deque[Request] = collections.deque()
        self._requests: dict[int, Request] = {}
        self._stream: list[jax.Array] = []  # sampled (B,) per decode step
        self._next_rid = 0
        self._decode = _decode_step_fn(cfg)
        self.sched: sched_mod.Scheduler | None = None
        self._trace_keys: set = set()
        if admission == "chunked":
            C = cfg.attention_chunk if prefill_chunk is None else prefill_chunk
            hybrid = "mamba" in cfg.layout
            # Bit-exactness alignment (DESIGN.md §7): chunk boundaries must
            # land on the monolithic attention grid, and on the SSD chunk
            # grid for hybrid layouts.
            if "attn" in cfg.layout and C % cfg.attention_chunk:
                raise ValueError(
                    f"prefill_chunk={C} must be a multiple of "
                    f"attention_chunk={cfg.attention_chunk}"
                )
            if hybrid and C % cfg.ssm.chunk_size:
                raise ValueError(
                    f"prefill_chunk={C} must be a multiple of "
                    f"ssm.chunk_size={cfg.ssm.chunk_size}"
                )
            self.sched = sched_mod.Scheduler(
                self.book, slab_tokens=self.T, chunk=C,
                exact_tail=hybrid, max_chunks_per_step=max_chunks_per_step,
                obs=self.obs,
            )
        # prefix cache (DESIGN.md §10): completed prompts publish their full
        # slabs into a host-side trie; admission aliases matched prefixes
        # into the new page table and prefills only the uncached suffix.
        self.prefix = (
            prefix_mod.PrefixCache(self.alloc, slab_tokens=self.T, obs=self.obs)
            if prefix_cache
            else None
        )
        self._matched: dict[int, np.ndarray] = {}  # rid → pinned slab ids
        # pre-carve: pool capacity / table width paid at init (not counted as
        # growth events — growth stats measure *demand*-driven reallocs)
        if max_pages_hint:
            self._ensure_table_width(max_pages_hint)
        if initial_slabs:
            self._grow_pool(initial_slabs, count=False)

    @property
    def alloc(self):
        return self.book.alloc

    # ---- telemetry helpers ----------------------------------------------
    def _host_read(self, x, site: str):
        """The audited device→host read: every transfer lands in one metric
        (``serve.host_syncs{site=…}``), so ``stats.host_syncs`` counts *all*
        sites — stop drains, final stream drains, debug checks — not just
        the stop-token path.
        """
        self.obs.registry.counter(
            "serve.host_syncs", "device→host reads, by site"
        ).inc(site=site)
        return jax.device_get(x)

    def _sample_live(self) -> None:
        """Refresh the pool occupancy gauges (host arithmetic only).

        Live tokens include the already-prefilled prefix of in-flight
        chunked admissions (``sched.t0``): those K/V rows occupy pool slabs
        even though the slot's published length is still 0, so the true
        high-water mark (``peak_live_tokens``) must see them.
        """
        live = self.live_tokens
        if self.sched is not None:
            live += sum(int(self.sched.t0[s]) for s in self.sched.prefilling)
        cap = self.pool_tokens
        self.obs.gauge_sample("pool.live_tokens", live)
        self.obs.gauge_sample("pool.capacity_tokens", cap)
        self.obs.gauge_sample("pool.utilization", live / cap if cap else 0.0)

    def drain_device_counters(self) -> dict[str, float]:
        """Flush + materialize the device counter plane → {slot: total}.

        This is a DRAIN POINT (one ``device_get`` per slot with pending
        adds) — call it at end of run / bench report time, never per step.
        """
        return self.devctr.counters()

    def _flightrec_state(self) -> dict:
        """Full host-side engine snapshot for postmortem bundles.

        Everything here is host bookkeeping (PageBook/allocator/scheduler
        mirrors) — building the state dict never touches the device.
        """
        alloc = self.alloc
        state: dict[str, Any] = {
            "n_slots": self.B,
            "slab_tokens": self.T,
            "admission": self.admission,
            "extent_sizes": list(self._extent_sizes),
            "len_host": self._len_host.tolist(),
            "slots": [
                None
                if r is None
                else {"rid": r.rid, "generated": r.generated,
                      "max_new_tokens": r.max_new_tokens, "done": r.done}
                for r in self._slots
            ],
            "allocator": {
                "n_slabs": alloc.n_slabs,
                "free_slabs": int(np.sum(alloc.free)),
                "free_ids": np.flatnonzero(alloc.free).tolist(),
                "refcounts": np.asarray(alloc.refcount).tolist(),
                "refcount_sum": int(np.sum(alloc.refcount)),
            },
            "page_tables": [
                [int(s) for s in self.book.pages_of[slot]]
                for slot in range(self.B)
            ],
            "reserved_total": int(self.book.reserved_total),
            "scheduler": self.sched.describe() if self.sched is not None else None,
            "prefix": (
                {"cached_slabs": [int(s) for s in self.prefix.cached_slabs()]}
                if self.prefix is not None
                else None
            ),
            "pinned": {rid: ids.tolist() for rid, ids in self._matched.items()},
        }
        return state

    def _flight_dump(self, reason: str, error: BaseException | None = None,
                     invariant: dict | None = None) -> None:
        """Dump a postmortem bundle; never raises, never dumps twice for
        the same exception (nested failure paths re-raise through step())."""
        if error is not None and getattr(error, "_flightrec_dumped", False):
            return
        try:
            state = self._flightrec_state()
            if invariant:
                state["invariant"] = dict(invariant)
            try:
                metrics = self.obs.snapshot()  # lazy-counter drain point
            except Exception:
                metrics = None
            try:
                device_counters = self.devctr.counters()
            except Exception:
                device_counters = None
            self.obs.flight.dump(
                reason=reason, error=error, state=state,
                metrics=metrics, device_counters=device_counters,
            )
        except Exception:
            return  # the recorder must not mask the original failure
        if error is not None:
            try:
                error._flightrec_dumped = True
            except Exception:
                pass

    def _note_admitted(self, req: Request, slot: int) -> None:
        req.queue_wait = time.time() - req.submit_t
        self.obs.registry.counter("serve.admitted").inc()
        self.obs.registry.histogram(
            "serve.queue_wait_ms", "submit → admission wall-clock"
        ).observe(req.queue_wait * 1e3, rid=req.rid)
        self.obs.event("admit", rid=req.rid, slot=slot)

    def _note_first_token(self, req: Request) -> None:
        """Record TTFT exactly once; the histogram sample and the timeline
        event carry the same float, so the acceptance test reconciles them
        by equality, not tolerance."""
        req.ttft = time.time() - req.submit_t
        self.obs.registry.histogram(
            "serve.ttft_ms", "submit → first sampled token (dispatch)"
        ).observe(req.ttft * 1e3, rid=req.rid)
        self.obs.event("first_token", rid=req.rid, ttft_ms=req.ttft * 1e3)

    # ---- cache construction ---------------------------------------------
    def _init_caches(self) -> list:
        cfg = self.cfg
        P = cfg.n_periods
        dt = jnp.dtype(cfg.dtype)
        kh, dh = cfg.n_kv_heads, cfg.head_dim
        caches = []
        for kind in cfg.layout:
            if kind == "mamba":
                from repro.models import ssm as ssm_mod

                st = ssm_mod.init_mamba_state(cfg, self.B, dt)
                caches.append(
                    {
                        "conv": jnp.zeros((P, *st.conv.shape), dt),
                        "ssd": jnp.zeros((P, *st.ssd.shape), jnp.float32),
                    }
                )
                continue
            kv_dt = jnp.int8 if cfg.cache_quant else dt  # int8 codes + scales
            c = {
                "k_pool": jnp.zeros((P, 0, self.T, kh, dh), kv_dt),
                "v_pool": jnp.zeros((P, 0, self.T, kh, dh), kv_dt),
                "pages": jnp.full((P, self.B, self.book.max_pages), -1, jnp.int32),
            }
            if cfg.cache_quant:
                c["ks_pool"] = jnp.zeros((P, 0, self.T, kh), jnp.bfloat16)
                c["vs_pool"] = jnp.zeros((P, 0, self.T, kh), jnp.bfloat16)
            if self._extent_mode:  # tuple-of-extents layout (one empty seed)
                for key in ("k_pool", "v_pool", "ks_pool", "vs_pool"):
                    if key in c:
                        c[key] = (c[key],)
            caches.append(c)
        return caches

    def _attn_slots(self):
        return [i for i, kind in enumerate(self.cfg.layout) if kind == "attn"]

    # ---- pool / page-table management -----------------------------------
    def _grow_pool(self, extra: int, *, count: bool = True) -> None:
        """Add ≥ ``extra`` slabs of pool capacity.

        Flat layout: realloc — widen every pool array by ``extra`` slabs and
        **copy** the live bytes (counted in ``stats.pool_copied_bytes``).
        Extent layout: append fresh extent(s) per the schedule's plan —
        existing extents keep their device buffers, zero bytes copied.
        ``count=False`` (init pre-carve) skips the growth-event counters:
        growth stats measure demand-driven reallocs, not paid-up-front
        capacity.
        """
        if self._extent_mode:
            from repro.pool import plan_extents

            self._append_extents(
                plan_extents(tuple(self._extent_sizes), extra, self.grow_chunk),
                count=count,
            )
            return

        def widen(pool):
            self.obs.registry.counter("pool.copied_bytes").inc(
                pool.size * pool.dtype.itemsize
            )
            pad = jnp.zeros((pool.shape[0], extra, *pool.shape[2:]), pool.dtype)
            return jnp.concatenate([pool, pad], axis=1)

        for i in self._attn_slots():
            c = self.caches[i]
            for key in ("k_pool", "v_pool", "ks_pool", "vs_pool"):
                if key in c:
                    c[key] = widen(c[key])
        self._finish_grow(extra, count=count)

    def _append_extents(self, sizes: list[int], *, count: bool = True) -> None:
        """Zero-copy growth: append fresh extents to every pool tuple."""
        sizes = [s for s in sizes if s > 0]
        if not sizes:
            return
        # a zero-size seed extent holds no slab ids — drop it once real
        # extents exist so kernels never carry dead operands
        keep = [j for j, s in enumerate(self._extent_sizes) if s > 0]
        for i in self._attn_slots():
            c = self.caches[i]
            for key in ("k_pool", "v_pool", "ks_pool", "vs_pool"):
                if key not in c:
                    continue
                exts = list(c[key])
                proto = exts[0]
                exts = [exts[j] for j in keep] if keep else []
                for s in sizes:
                    exts.append(
                        jnp.zeros(
                            (proto.shape[0], s, *proto.shape[2:]), proto.dtype
                        )
                    )
                c[key] = tuple(exts)
        self._extent_sizes = [self._extent_sizes[j] for j in keep] + sizes
        self._finish_grow(sum(sizes), count=count)

    def _finish_grow(self, extra: int, *, count: bool = True) -> None:
        self.book.grow(extra)
        self.free_dev = jnp.concatenate([self.free_dev, jnp.ones((extra,), bool)])
        if count:
            self.obs.registry.counter("pool.grow_events").inc()
            self.obs.registry.counter("pool.grown_slabs").inc(extra)
            self.obs.event("pool_grow", slabs=extra, n_slabs=self.alloc.n_slabs)
        self._sample_live()

    def _grow_for(self, short: int) -> None:
        """Cover a free-list shortfall, sized by the growth schedule.

        Reserved-but-unclaimed slabs from in-flight chunked prefills count
        as committed demand (``reserved=``): a grow sized off the free list
        alone could be exhausted again by the claims that convert those
        reservations within the same scheduler step.

        Pool pressure is the prefix cache's eviction signal: before paying
        for new capacity, LRU cached slabs nobody aliases are released back
        to the free list, and only the remaining shortfall is grown.
        """
        from repro.pool import growth_amount, plan_extents

        if self.prefix is not None:
            freed = self.prefix.evict(short)
            if len(freed):
                self.free_dev = self.free_dev.at[jnp.asarray(freed)].set(True)
                self.obs.registry.counter("pool.released_slabs").inc(len(freed))
                short -= len(freed)
                if short <= 0:
                    self._sample_live()
                    return
        reserved = self.book.reserved_total
        if self._extent_mode:
            self._append_extents(
                plan_extents(
                    tuple(self._extent_sizes), short, self.grow_chunk,
                    reserved=reserved,
                )
            )
            return
        self._grow_pool(
            growth_amount(
                self.alloc.n_slabs, short, self.grow_chunk, reserved=reserved
            )
        )

    def _ensure_table_width(self, need: int) -> None:
        widened = self.book.widen(need)
        if widened is None:
            return
        old, new = widened
        for i in self._attn_slots():
            c = self.caches[i]
            pad = jnp.full((c["pages"].shape[0], self.B, new - old), -1, jnp.int32)
            c["pages"] = jnp.concatenate([c["pages"], pad], axis=-1)

    def _claim(self, slot: int, k: int) -> np.ndarray:
        """Claim ``k`` slabs for decode slot ``slot`` (reuse-first)."""
        if k == 0:
            return np.zeros((0,), np.int32)
        self._ensure_table_width(int(self.book.npages[slot]) + k)
        short = self.book.shortfall(k)
        if short:
            self._grow_for(short)
        before_reuse = self.alloc.reuse_claims
        ids, page0 = self.book.claim(slot, k)
        self.obs.registry.counter("pool.reused_slabs").inc(
            self.alloc.reuse_claims - before_reuse
        )
        cols = jnp.arange(page0, page0 + k)
        dev_ids = jnp.asarray(ids)
        for i in self._attn_slots():
            c = self.caches[i]
            c["pages"] = c["pages"].at[:, slot, cols].set(dev_ids)
        self.free_dev = self.free_dev.at[dev_ids].set(False)
        return ids

    def _release(self, slot: int) -> None:
        ids = self.book.release(slot)
        if len(ids):
            self.free_dev = self.free_dev.at[jnp.asarray(ids)].set(True)
        for i in self._attn_slots():
            c = self.caches[i]
            c["pages"] = c["pages"].at[:, slot, :].set(-1)
        self._len_host[slot] = 0
        self.lengths = self.lengths.at[slot].set(0)
        self.obs.registry.counter("pool.released_slabs").inc(len(ids))
        self._sample_live()

    @property
    def pool_tokens(self) -> int:
        return self.alloc.n_slabs * self.T

    @property
    def live_tokens(self) -> int:
        return int(self._len_host.sum())

    def utilization(self) -> float:
        return self.live_tokens / self.pool_tokens if self.pool_tokens else 0.0

    # ---- request lifecycle ----------------------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
            submit_t=time.time(),
        )
        self._requests[rid] = req
        self.obs.registry.counter("serve.submitted").inc()
        self.obs.event("submit", rid=rid, prompt_len=len(req.prompt))
        if self.sched is not None:
            self.sched.submit(rid, len(req.prompt))
        else:
            self._pending.append(req)
        return rid

    def _admit(self, req: Request, slot: int) -> None:
        cfg = self.cfg
        Lp = len(req.prompt)
        self._note_admitted(req, slot)
        self._claim(slot, max(-(-Lp // self.T), 1))
        with self.obs.span("prefill", rid=req.rid, tokens=Lp):
            toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None, :])
            logits, pcaches = steps.prefill(
                self.params, toks, cfg, capacity_hint=Lp, policy="static"
            )
        self.obs.registry.counter("serve.prefills").inc()
        for i, kind in enumerate(cfg.layout):
            if kind == "mamba":
                for key in ("conv", "ssd"):
                    val = pcaches[i][key][:, 0]
                    want = self.caches[i][key].shape[2]
                    if key == "conv" and val.shape[1] < want:
                        # prompt shorter than the conv window: the missing
                        # history is zeros, oldest-first (left pad)
                        val = jnp.pad(
                            val, ((0, 0), (want - val.shape[1], 0), (0, 0))
                        )
                    self.caches[i][key] = (
                        self.caches[i][key].at[:, slot].set(val)
                    )
                continue
            self._fill_slot_pages(i, slot, pcaches[i], Lp)
        self.lengths = self.lengths.at[slot].set(Lp)
        self._len_host[slot] = Lp
        self._sample_live()
        self.key, k = jax.random.split(self.key)
        first = sample(k, logits, 0.0)[0]
        req.first_tok = first
        self._note_first_token(req)
        self.cur_tok = self.cur_tok.at[slot].set(first)
        req.slot = slot
        req.admit_step = len(self._stream)
        req.generated = 1
        self._slots[slot] = req
        if req.generated >= req.max_new_tokens:
            self._complete(req)

    def _set_slabs(self, pool, ids: np.ndarray, vals: jax.Array):
        """``pool.at[:, ids].set(vals)`` across the flat or extent layout.

        ``ids`` are *host* slab ids, so extent routing is pure host
        arithmetic — one sliced scatter per extent that owns any of them.
        """
        if not self._extent_mode:
            return pool.at[:, jnp.asarray(ids, jnp.int32)].set(vals)
        exts = list(pool)
        base = 0
        for e, size in enumerate(self._extent_sizes):
            sel = np.flatnonzero((ids >= base) & (ids < base + size))
            if len(sel):
                local = jnp.asarray(ids[sel] - base, jnp.int32)
                exts[e] = exts[e].at[:, local].set(vals[:, sel])
            base += size
        return tuple(exts)

    def _fill_slot_pages(self, i: int, slot: int, pcache: dict, Lp: int) -> None:
        """Scatter a (P, 1, Lp, …) static prefill cache into claimed slabs."""
        c = self.caches[i]
        npages = int(self.book.npages[slot])
        ids = self.book.pages_in_order(slot)

        def paged(x):  # (P, Lp, …) → (P, npages, T, …)
            pad = npages * self.T - x.shape[1]
            widths = [(0, 0)] * x.ndim
            widths[1] = (0, pad)
            x = jnp.pad(x, widths)
            return x.reshape(x.shape[0], npages, self.T, *x.shape[2:])

        c["k_pool"] = self._set_slabs(c["k_pool"], ids, paged(pcache["k"][:, 0]))
        c["v_pool"] = self._set_slabs(c["v_pool"], ids, paged(pcache["v"][:, 0]))
        if "ks_pool" in c:
            c["ks_pool"] = self._set_slabs(
                c["ks_pool"], ids, paged(pcache["ks"][:, 0])
            )
            c["vs_pool"] = self._set_slabs(
                c["vs_pool"], ids, paged(pcache["vs"][:, 0])
            )

    def _complete(self, req: Request) -> None:
        req.done = True
        if self.prefix is not None:
            # publish the full prompt slabs into the trie *before* release:
            # the trie's addref keeps them alive when the tenant's page
            # references drop, so a reclaim becomes a cache fill
            self.prefix.publish(req.prompt, self.book.pages_of[req.slot])
        self._release(req.slot)
        if self.sched is not None:
            self.sched.complete(req.slot)
        self._slots[req.slot] = None
        self.obs.registry.counter("serve.completed").inc()
        if req.generated > 1:
            req.tpot_ms = req.decode_s / (req.generated - 1) * 1e3
            self.obs.registry.histogram(
                "serve.tpot_ms", "mean decode wall-clock per output token"
            ).observe(req.tpot_ms, rid=req.rid)
        self.obs.event("complete", rid=req.rid, generated=req.generated)

    # ---- chunked admission ----------------------------------------------
    def _ensure_free_slabs(self, short: int) -> bool:
        """Scheduler grow hook: the engine always covers a reservation."""
        self._grow_for(short)
        return True

    def _run_chunk(self, task) -> None:
        """Execute one scheduler ChunkTask: claim → prefill_chunk → advance."""
        req = self._requests[task.rid]
        slot = task.slot
        if task.new_slabs:
            before = self.alloc.reuse_claims
            ids, _ = self.book.claim(slot, task.new_slabs, from_reservation=True)
            self.obs.registry.counter("pool.reused_slabs").inc(
                self.alloc.reuse_claims - before
            )
            self.free_dev = self.free_dev.at[jnp.asarray(ids)].set(False)
        row = np.full((self.book.max_pages,), -1, np.int32)
        order = self.book.pages_in_order(slot)
        row[: len(order)] = order
        toks = np.zeros((1, task.width), np.int32)
        toks[0, : task.live] = req.prompt[task.t0 : task.t0 + task.live]
        first = task.t0 == 0
        key = (task.width, first, self.alloc.n_slabs, self.book.max_pages)
        if key not in self._trace_keys:
            self._trace_keys.add(key)
            self.obs.registry.gauge(
                "serve.prefill_traces", "distinct prefill-chunk trace keys"
            ).set(len(self._trace_keys))
        with self.obs.span(
            "prefill_chunk", rid=task.rid, t0=task.t0, width=task.width
        ):
            outs = _prefill_chunk_fn(self.cfg)(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(slot, jnp.int32), jnp.asarray(task.t0, jnp.int32),
                jnp.asarray(task.live, jnp.int32), jnp.asarray(row), first=first,
            )
            if self.cfg.instrument:
                logits, self.caches, ctr = outs
                self.devctr.add(ctr)
            else:
                logits, self.caches = outs
        self.obs.registry.counter("serve.prefill_chunks").inc()
        self.sched.chunk_done(task)
        self._sample_live()
        if task.final:
            self._finish_prefill(req, slot, logits)

    def _finish_prefill(self, req: Request, slot: int, logits) -> None:
        """Final chunk done: publish pages to the device table, arm decode."""
        npages = int(self.book.npages[slot])
        ids = jnp.asarray(self.book.pages_in_order(slot), jnp.int32)
        cols = jnp.arange(npages)
        for i in self._attn_slots():
            c = self.caches[i]
            c["pages"] = c["pages"].at[:, slot, cols].set(ids)
        Lp = len(req.prompt)
        self.lengths = self.lengths.at[slot].set(Lp)
        self._len_host[slot] = Lp
        self.obs.registry.counter("serve.prefills").inc()
        self._sample_live()
        self.key, k = jax.random.split(self.key)
        first = sample(k, logits, 0.0)[0]
        req.first_tok = first
        self._note_first_token(req)
        self.cur_tok = self.cur_tok.at[slot].set(first)
        req.admit_step = len(self._stream)
        req.generated = 1
        if req.generated >= req.max_new_tokens:
            self._complete(req)

    # ---- prefix caching (DESIGN.md §10) ----------------------------------
    def _match_prefix(self, rid: int, length: int) -> int:
        """Scheduler ``match`` hook: longest cached prefix → tokens cached.

        The matched slabs are **pinned** (one ``addref`` each) before the
        scheduler's ``ensure`` hook can run — growth may evict LRU cached
        slabs, and a pinned slab (refcount ≥ 2) is never evictable.  Pins
        transfer into the page table at admission (``book.adopt``) or are
        dropped when the request doesn't admit this round.
        """
        if self.prefix is None:
            return 0
        blocks, ids = self.prefix.match(self._requests[rid].prompt)
        if not blocks:
            return 0
        self.alloc.addref(ids)
        self._matched[rid] = ids
        return blocks * self.T

    def _drop_pins(self) -> None:
        for ids in self._matched.values():
            self.alloc.release(ids)
        self._matched.clear()

    def _adopt_prefix(self, req: Request, slot: int, need: int) -> None:
        """Transfer the pinned match into the slot's page table."""
        ids = self._matched.pop(req.rid)
        cached = len(ids) * self.T
        self._ensure_table_width(len(ids) + need)
        self.book.adopt(slot, ids)
        self.obs.registry.counter(
            "serve.prefix_hits", "admissions that reused cached prefix slabs"
        ).inc()
        self.obs.registry.counter(
            "serve.prefix_tokens_reused", "prompt tokens served from cache"
        ).inc(cached)
        self.obs.event(
            "prefix_hit", rid=req.rid, tokens=cached, blocks=len(ids),
            full=cached >= len(req.prompt),
        )

    def _arm_full_hit(self, req: Request, slot: int) -> None:
        """Fully cached prompt: zero prefill chunks.  Publish the aliased
        pages to the device table and arm decode on the *last* prompt token
        (its K/V rewrite COWs the tail slab) — the request's first token
        comes from the first decode step, where TTFT is recorded.
        """
        Lp = len(req.prompt)
        npages = int(self.book.npages[slot])
        ids = jnp.asarray(self.book.pages_in_order(slot), jnp.int32)
        cols = jnp.arange(npages)
        for i in self._attn_slots():
            c = self.caches[i]
            c["pages"] = c["pages"].at[:, slot, cols].set(ids)
        self.lengths = self.lengths.at[slot].set(Lp - 1)
        self._len_host[slot] = Lp - 1
        self.cur_tok = self.cur_tok.at[slot].set(req.prompt[-1])
        req.generated = 0  # first sample arrives from the first decode step
        self._sample_live()

    def _cow_if_shared(self, slot: int, page: int) -> None:
        """Copy-on-write guard: make ``slot``'s slab at ``page`` private.

        A shared slab (refcount > 1) about to be appended into is first
        copied — one slab's bytes — into a fresh claim; the page table
        repoints and one reference on the original drops.  The cached
        original is never mutated in place, so every other alias (and the
        trie) keeps bit-identical data.
        """
        old = int(self.book.pages_of[slot][page])
        if int(self.alloc.refcount[old]) <= 1:
            return
        short = self.book.shortfall(1)
        if short:
            self._grow_for(short)
        before = self.alloc.reuse_claims
        new = int(self.alloc.claim(slot, 1)[0])
        self.obs.registry.counter("pool.reused_slabs").inc(
            self.alloc.reuse_claims - before
        )
        self.book.replace(slot, page, new)
        self.alloc.release(np.asarray([old], np.int32), tenant=slot)
        publish = self.sched is None or self.sched.phase[slot] == "decode"
        for i in self._attn_slots():
            c = self.caches[i]
            for key in ("k_pool", "v_pool", "ks_pool", "vs_pool"):
                if key in c:
                    c[key] = kvcache.copy_slab(c[key], old, new, axis=1)
            if publish:  # prefill rows stay −1 until the final chunk
                c["pages"] = c["pages"].at[:, slot, page].set(new)
        self.free_dev = self.free_dev.at[new].set(False)
        self.obs.registry.counter(
            "serve.cow_copies", "shared slabs privately copied before append"
        ).inc()
        self.obs.event("cow_copy", slot=slot, page=page, src=old, dst=new)

    # ---- the decode loop -------------------------------------------------
    def _admit_pending(self) -> None:
        if self.sched is not None:
            try:
                admits = self.sched.admit(
                    self._ensure_free_slabs,
                    match=self._match_prefix if self.prefix is not None else None,
                )
            except BaseException as e:
                self._drop_pins()
                from repro.pool import QuotaExceeded

                if isinstance(e, QuotaExceeded):
                    self._flight_dump("quota_exceeded", e)
                raise
            for rid, slot, need in admits:
                req = self._requests[rid]
                req.slot = slot
                self._slots[slot] = req
                if rid in self._matched:
                    self._adopt_prefix(req, slot, need)
                else:
                    self._ensure_table_width(need)
                self._note_admitted(req, slot)
                if self.sched.phase[slot] == "decode":  # fully cached prompt
                    self._arm_full_hit(req, slot)
            self._drop_pins()  # matched but not admitted this round
            return
        for slot in range(self.B):
            if not self._pending:
                return
            if self._slots[slot] is None:
                self._admit(self._pending.popleft(), slot)

    def step(self) -> bool:
        """Admit, run prefill chunks, one batched decode step (interleaved).

        → False when nothing is active.  Chunked admission runs up to
        ``max_chunks_per_step`` prefill chunks *and then* decodes the slots
        already in the decode phase — admitted sequences keep generating
        while new prompts stream in.

        Any failure inside the step dumps a flight-recorder postmortem
        bundle (event ring + engine state + drained counters) before the
        exception propagates — DESIGN.md §9.y.
        """
        try:
            return self._step_inner()
        except BaseException as e:
            self._flight_dump("step_failure", e)
            raise

    def _step_inner(self) -> bool:
        self._admit_pending()
        tasks = self.sched.next_chunks() if self.sched is not None else []
        for task in tasks:
            self._run_chunk(task)
        if self.sched is not None:
            active = [
                r for r in self._slots
                if r is not None and self.sched.phase[r.slot] == "decode"
            ]
        else:
            active = [r for r in self._slots if r is not None]
        if not active:
            return bool(tasks)
        # capacity: claim the next slab before overflow.  The shortfall is
        # sized over the whole batch first so one growth event covers every
        # needy slot this step (per-slot grows would fire once per sequence
        # under synchronized overflow — the double-grow the tests assert
        # against).
        needy = [
            r.slot
            for r in active
            if self._len_host[r.slot] + 1 > self.book.npages[r.slot] * self.T
        ]
        if needy:
            short = self.book.shortfall(len(needy))
            if short:
                self._grow_for(short)
            for slot in needy:
                self._claim(slot, 1)
        # copy-on-write guard: the slab each slot is about to append into
        # must be private (a full-hit admission decodes its last prompt
        # token into the shared tail slab — copy it first, never mutate)
        for req in active:
            pos = int(self._len_host[req.slot])
            if pos // self.T < int(self.book.npages[req.slot]):
                self._cow_if_shared(req.slot, pos // self.T)
        if self.sched is not None and self.sched.prefilling:
            act = np.zeros((self.B,), bool)
            act[[r.slot for r in active]] = True
            active_mask = jnp.asarray(act)
        else:
            active_mask = None
        step_t0 = time.perf_counter()
        with self.obs.span(
            "decode_step", step=len(self._stream), active=len(active)
        ):
            if self.cfg.instrument:
                logits, self.caches, ctr = self._decode(
                    self.params, self.cur_tok, self.caches, self.lengths,
                    active=active_mask,
                )
                self.devctr.add(ctr)  # a list append — no transfer
            else:
                logits, self.caches = self._decode(
                    self.params, self.cur_tok, self.caches, self.lengths,
                    active=active_mask,
                )
            self.key, k = jax.random.split(self.key)
            sampled = sample(k, logits, 0.0)
        step_dt = time.perf_counter() - step_t0
        self._stream.append(sampled)
        self.cur_tok = sampled
        mask = np.zeros((self.B,), np.int32)
        for req in active:
            mask[req.slot] = 1
        self.lengths = self.lengths + jnp.asarray(mask)
        self._len_host += mask
        self.obs.registry.counter("serve.decode_steps").inc()
        self._sample_live()
        stops = None
        if self.stop_token is not None:
            # one (B,) read per step — the price of stop-token scheduling
            stops = np.asarray(self._host_read(sampled, "stop_drain"))
        for req in active:
            first_decode = req.generated == 0  # full-hit: first token is here
            req.generated += 1
            req.decode_s += step_dt
            if first_decode:
                req.first_tok = sampled[req.slot]
                req.admit_step = len(self._stream)
                self._note_first_token(req)
            hit_stop = stops is not None and stops[req.slot] == self.stop_token
            if req.generated >= req.max_new_tokens or hit_stop:
                self._complete(req)
        return True

    def _has_work(self) -> bool:
        if any(r is not None for r in self._slots):
            return True
        if self.sched is not None:
            return self.sched.busy
        return bool(self._pending)

    def run(self) -> dict[int, list[int]]:
        """Drain every submitted request → {rid: prompt + generated tokens}.

        One device→host transfer materializes the whole token stream after
        the loop (plus one for the per-request prefill samples).
        """
        while self._has_work():
            self.step()
        rids = sorted(self._requests)
        firsts = {}
        if rids:
            stack = jnp.stack([self._requests[r].first_tok for r in rids])
            vals = np.asarray(self._host_read(stack, "first_token_drain"))
            firsts = {r: int(v) for r, v in zip(rids, vals)}
        stream = (
            np.asarray(self._host_read(jnp.stack(self._stream), "stream_drain"))
            if self._stream
            else np.zeros((0, self.B), np.int32)
        )
        out = {}
        for rid in rids:
            req = self._requests[rid]
            toks = [firsts[rid]]
            lo = req.admit_step
            toks.extend(
                int(t) for t in stream[lo : lo + req.generated - 1, req.slot]
            )
            out[rid] = list(req.prompt) + toks
        return out

    def run_all(self, prompts: list[list[int]], max_new_tokens: int) -> list[list[int]]:
        """Submit + drain in one call → outputs in prompt order."""
        rids = [self.submit(p, max_new_tokens) for p in prompts]
        out = self.run()
        return [out[r] for r in rids]

    # ---- verification (test/debug only: reads the device) ----------------
    def check_free_list(self) -> None:
        """Device bitmap ⇔ host allocator ⇔ page-table ⇔ refcount audit.

        Refcount conservation (DESIGN.md §10): every reference on a claimed
        slab is exactly one page-table entry, one prefix-cache node, or one
        in-flight admission pin — Σ references == ``alloc.refcount`` per
        slab, and a slab is live iff someone references it.

        A violation dumps a flight-recorder postmortem bundle naming the
        offending slab ids before the assertion propagates.
        """
        try:
            self._check_free_list_inner()
        except AssertionError as e:
            self._flight_dump("engine_invariant", e)  # no-op if already dumped
            raise

    def _check_free_list_inner(self) -> None:
        free = np.asarray(self._host_read(self.free_dev, "free_list_debug"))
        if not (free == self.alloc.free).all():
            bad = np.flatnonzero(free != self.alloc.free)
            err = AssertionError(f"device free bitmap drifted: slabs {bad}")
            self._flight_dump(
                "free_bitmap_drift", err,
                invariant={"check": "free_bitmap", "offending_slabs": bad.tolist()},
            )
            raise err
        self.alloc.check()
        refs = np.zeros((self.alloc.n_slabs,), np.int64)
        for slot in range(self.B):
            for s in self.book.pages_of[slot]:
                refs[s] += 1
        if self.prefix is not None:
            for s in self.prefix.cached_slabs():
                refs[s] += 1
        for ids in self._matched.values():
            for s in ids:
                refs[s] += 1
        bad = np.flatnonzero(refs != self.alloc.refcount)
        if len(bad):
            err = AssertionError(
                f"refcounts drift from page tables + prefix cache: {bad}"
            )
            self._flight_dump(
                "refcount_mismatch", err,
                invariant={
                    "check": "refcount_conservation",
                    "offending_slabs": bad.tolist(),
                    "expected_refcount": refs[bad].tolist(),
                    "actual_refcount": np.asarray(
                        self.alloc.refcount
                    )[bad].tolist(),
                },
            )
            raise err
        bad = np.flatnonzero((refs > 0) == self.alloc.free)
        if len(bad):
            err = AssertionError(
                "slab freed while referenced (or live without references): "
                f"{bad}"
            )
            self._flight_dump(
                "liveness_drift", err,
                invariant={"check": "liveness", "offending_slabs": bad.tolist()},
            )
            raise err
        for i in self._attn_slots():
            pages = np.asarray(
                self._host_read(self.caches[i]["pages"], "free_list_debug")
            )[0]
            claimed = pages[pages >= 0]
            assert not free[claimed].any() if len(claimed) else True
            for slot in range(self.B):
                npg = int(self.book.npages[slot])
                row = pages[slot]
                if self.sched is not None and self.sched.phase[slot] == "prefill":
                    # chunked prefills hold claimed slabs the device table
                    # doesn't list yet (published on the final chunk)
                    assert (row == -1).all(), f"slot {slot}: published early"
                else:
                    want = np.asarray(self.book.pages_of[slot], np.int64)
                    assert (row[:npg] == want).all(), f"slot {slot}: row drift"
                    assert (row[npg:] == -1).all(), f"slot {slot}: stray pages"
