"""Host-side prefix cache: a trie of slab-aligned token blocks (DESIGN.md §10).

Serving fleets share long prompt prefixes (system prompts, few-shot
templates, multi-turn history).  The slab arena already gives every sequence
an *indirect* page table of slab ids, so two sequences with a common prefix
can point at the same physical slabs at zero kernel cost — sharing is pure
page-table aliasing.  This module is the admission-time index that finds
those slabs:

* **Keying** — the trie descends one node per full ``slab_tokens``-sized
  block of the prompt; only *full* blocks are cached (a partially-filled
  slab is still being written by its owner, so it can never be safely
  shared).  Each edge is keyed by a **truncated hash** of the block's
  tokens (``hash_bits`` of a blake2b digest) for O(1) child lookup, with
  the block's exact tokens stored on the node.
* **Collision safety** — a hash hit is never trusted: every candidate
  node's stored tokens are compared to the query block before descending,
  so two blocks that collide in the truncated hash can coexist (they hang
  off the same edge key) and a lookup can never alias the wrong slab.
* **Reference counting** — the trie holds exactly one
  :meth:`~repro.pool.planner.SlabAllocator.addref` reference per cached
  node.  A match additionally pins the returned slabs (the caller takes
  page-table references), so a cached slab's refcount is always
  ``1 (trie) + #page tables aliasing it``.
* **Eviction** — under pool pressure (:meth:`evict`), least-recently-used
  *leaf* nodes whose slab refcount is 1 (held only by the trie) are
  released back to the free list.  Evicting leaves first preserves the
  prefix property: a cached block is only reachable through cached
  ancestors, so the trie never serves a suffix without its prefix.
"""
from __future__ import annotations

import collections
import hashlib
from typing import Iterable, Sequence

import numpy as np

from repro.obs import ServingTimeline

__all__ = ["PrefixCache", "block_hash"]


def block_hash(tokens: Sequence[int], bits: int) -> int:
    """Deterministic truncated hash of a token block (``bits`` low bits of
    a blake2b digest).  Process-stable, unlike Python's salted ``hash``."""
    digest = hashlib.blake2b(
        np.asarray(tokens, np.int64).tobytes(), digest_size=8
    ).digest()
    return int.from_bytes(digest, "little") & ((1 << bits) - 1)


class _Node:
    __slots__ = ("tokens", "slab", "key", "parent", "children")

    def __init__(self, tokens: tuple, slab: int, key: int, parent):
        self.tokens = tokens  # the block's exact tokens (collision guard)
        self.slab = slab  # pool slab id holding this block's K/V
        self.key = key  # truncated hash — the edge key under parent
        self.parent = parent
        self.children: dict[int, list[_Node]] = {}


class PrefixCache:
    """Trie of full-slab prompt prefixes → slab ids, over one allocator."""

    def __init__(
        self,
        alloc,
        *,
        slab_tokens: int,
        hash_bits: int = 24,
        obs: ServingTimeline | None = None,
    ):
        if slab_tokens < 1 or hash_bits < 1:
            raise ValueError(f"need positive slab_tokens/hash_bits, got "
                             f"{slab_tokens}/{hash_bits}")
        self.alloc = alloc
        self.T = slab_tokens
        self.hash_bits = hash_bits
        self.obs = obs
        self.root = _Node((), -1, -1, None)
        # LRU order over cached nodes: oldest first, touch = move_to_end.
        self._lru: collections.OrderedDict[_Node, None] = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._lru)

    # ---- internals -------------------------------------------------------
    def _blocks(self, tokens: Sequence[int]) -> Iterable[tuple]:
        for j in range(len(tokens) // self.T):
            yield tuple(tokens[j * self.T : (j + 1) * self.T])

    def _find(self, node: _Node, block: tuple) -> _Node | None:
        for cand in node.children.get(block_hash(block, self.hash_bits), ()):
            if cand.tokens == block:  # verify: never trust the hash alone
                return cand
        return None

    def _touch(self, node: _Node) -> None:
        self._lru[node] = None
        self._lru.move_to_end(node)

    def _remove(self, node: _Node) -> None:
        siblings = node.parent.children[node.key]
        siblings.remove(node)
        if not siblings:
            del node.parent.children[node.key]
        del self._lru[node]

    # ---- queries ---------------------------------------------------------
    def cached_slabs(self) -> list[int]:
        """Every slab id the trie currently holds a reference on."""
        return [n.slab for n in self._lru]

    # ---- the admission path ----------------------------------------------
    def match(self, tokens: Sequence[int]) -> tuple[int, np.ndarray]:
        """Longest cached full-slab prefix of ``tokens`` → (blocks, ids).

        Pure lookup: the caller pins the returned slabs (``alloc.addref``)
        before anything that could evict runs.  Matched nodes are touched
        to the MRU end, so concurrent pressure evicts cold entries first.
        """
        node, ids = self.root, []
        for block in self._blocks(tokens):
            child = self._find(node, block)
            if child is None:
                break
            ids.append(child.slab)
            self._touch(child)
            node = child
        return len(ids), np.asarray(ids, np.int32)

    def publish(self, tokens: Sequence[int], page_ids: Sequence[int]) -> int:
        """Cache every full-slab block of a completed prompt → new nodes.

        ``page_ids`` is the sequence's page table (slab id per page); block
        ``j`` lives in slab ``page_ids[j]``.  New nodes take one trie
        reference on their slab; blocks already cached keep the existing
        slab (the duplicate stays with its owner and is released normally).
        """
        node, new = self.root, 0
        for j, block in enumerate(self._blocks(tokens)):
            child = self._find(node, block)
            if child is None:
                slab = int(page_ids[j])
                self.alloc.addref(np.asarray([slab], np.int32))
                child = _Node(block, slab, block_hash(block, self.hash_bits), node)
                node.children.setdefault(child.key, []).append(child)
                new += 1
            self._touch(child)
            node = child
        if new and self.obs is not None:
            self.obs.event("prefix_publish", blocks=new, cached=len(self._lru))
        return new

    def evict(self, want: int) -> np.ndarray:
        """Free up to ``want`` LRU unreferenced cached slabs → freed ids.

        Only leaves whose slab refcount is 1 (the trie's own reference) are
        evictable: interior nodes anchor cached suffixes, and a slab some
        page table still aliases must survive.  Cascades — a parent whose
        last child was evicted becomes a leaf and is considered on the next
        pass.
        """
        freed: list[int] = []
        while len(freed) < want:
            victim = None
            for node in self._lru:  # oldest first
                if not node.children and int(self.alloc.refcount[node.slab]) == 1:
                    victim = node
                    break
            if victim is None:
                break
            self._remove(victim)
            freed.extend(
                int(s)
                for s in self.alloc.release(np.asarray([victim.slab], np.int32))
            )
        if freed and self.obs is not None:
            self.obs.registry.counter(
                "serve.prefix_evicted", "cached slabs evicted under pool pressure"
            ).inc(len(freed))
            self.obs.event("prefix_evict", slabs=len(freed), cached=len(self._lru))
        return np.asarray(freed, np.int32)
