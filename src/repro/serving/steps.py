"""Serving steps: prefill (context encode → cache) and decode (one token).

Both walk the same period-scanned layer stack as training; the cache pytree
rides the scan as xs/ys so its leaves carry the (n_periods, ...) stacking.
``decode_step`` is the ``serve_step`` the decode_32k / long_500k dry-run
cells lower: one new token against a cache filled to seq_len.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import constrain
from repro.models import ssm as ssm_mod
from repro.models.attention import inner_attention, project_out, project_qkv
from repro.models.mlp import mlp_block
from repro.models.moe import moe_block
from repro.models.modules import embed, rms_norm, unembed

from repro.obs import device
from repro.serving import kvcache

__all__ = [
    "prefill",
    "prefill_chunk",
    "decode_step",
    "init_decode_caches",
    "logits_from_hidden",
]


def logits_from_hidden(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = constrain(unembed(x, table), ("batch", "vocab"))
    if cfg.padded_vocab != cfg.vocab_size:
        live = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(live, logits, -1e30)
    return logits


def _mlp_or_moe(sp, x, slot, cfg):
    h = rms_norm(x, sp["norm2"], cfg.norm_eps)
    if cfg.is_moe_layer(slot):
        out, _ = moe_block(sp["moe"], h, cfg)
        return x + out
    return x + mlp_block(sp["mlp"], h, cfg.activation)


# Cache slots ride the period scan with a leading (n_periods, …) stacking;
# _cache_get/_cache_put index one period in/out.  Values are tree-mapped, not
# indexed directly: a paged pool entry may be a *tuple of extents*
# (pool/extents segmented layout) rather than one array.

def _cache_get(full: dict, i):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), full
    )


def _cache_put(full: dict, part: dict, i):
    out = dict(full)
    for k, p in part.items():  # only updated keys (cross K/V stay as-is)
        out[k] = jax.tree.map(
            lambda a, b: jax.lax.dynamic_update_index_in_dim(
                a, b.astype(a.dtype), i, 0
            ),
            full[k],
            p,
        )
    return out


# --------------------------------------------------------------------------
# Prefill: full context forward, emitting filled caches per layer.
# --------------------------------------------------------------------------

def prefill(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    capacity_hint: int | None = None,
    policy: str | None = None,
    prefix_embeds: jax.Array | None = None,
    memory: jax.Array | None = None,
    lengths: jax.Array | None = None,  # (B,) per-seq prompt lengths (right-pad)
) -> tuple[jax.Array, list]:
    """→ (last-position logits (B, V), caches list[slot])."""
    policy = cfg.cache_policy if policy is None else policy
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    cap = capacity_hint if capacity_hint is not None else S
    positions = jnp.arange(S)[None, :]

    def period_body(carry, period_params):
        (x,) = carry
        x = constrain(x, ("batch", "seq", None))
        caches_out = []
        for slot, kind in enumerate(cfg.layout):
            sp = period_params[slot]
            h = rms_norm(x, sp["norm1"], cfg.norm_eps)
            if kind == "mamba":
                y, state = ssm_mod.mamba_block(sp["mamba"], h, cfg, return_state=True)
                x = x + y
                caches_out.append({"conv": state.conv, "ssd": state.ssd})
                continue
            q, k, v = project_qkv(sp["attn"], h, cfg, positions)
            att = inner_attention(q, k, v, cfg, causal=True)
            x = x + project_out(sp["attn"], att)
            cache = kvcache.init_cache(cfg, B, cap, policy)
            cache = kvcache.fill_from_prefill(cache, k, v)
            if memory is not None:
                ck = jnp.einsum("bsd,dhk->bshk", memory, sp["cross"]["wk"])
                cv = jnp.einsum("bsd,dhk->bshk", memory, sp["cross"]["wv"])
                hc = rms_norm(x, sp["cross_norm"], cfg.norm_eps)
                qc = jnp.einsum("bsd,dhk->bshk", hc, sp["cross"]["wq"])
                attc = inner_attention(qc, ck, cv, cfg, causal=False)
                x = x + project_out(sp["cross"], attc)
                cache = dict(cache, cross_k=ck, cross_v=cv)
            x = _mlp_or_moe(sp, x, slot, cfg)
            caches_out.append(cache)
        return (x,), caches_out

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    (x,), caches = jax.lax.scan(body, (x,), params["layers"])
    if lengths is None:
        last = x[:, -1]
    else:
        last = x[jnp.arange(B), jnp.asarray(lengths, jnp.int32) - 1]
    logits = logits_from_hidden(params, last, cfg)
    return logits, caches


# --------------------------------------------------------------------------
# Chunked prefill: one chunk of one slot's prompt into the shared paged
# caches (BatchEngine admission, DESIGN.md §7).  Compiles per chunk *bucket*
# width, never per prompt length — the O(log C) trace bound.
# --------------------------------------------------------------------------

def prefill_chunk(
    params: dict,
    tokens: jax.Array,  # (1, Cb) bucket-padded chunk of one prompt
    caches: list,  # the BatchEngine's shared (donated) caches
    slot: jax.Array,  # () decode-slot index owning this prompt
    t0: jax.Array,  # () tokens of this prompt already prefilled
    live: jax.Array,  # () live tokens in this chunk (Cb − live are padding)
    pages_row: jax.Array,  # (maxp,) the slot's claimed slab ids, −1-padded
    cfg: ModelConfig,
    first: bool = True,  # STATIC: t0 == 0 (fresh state, no prefix to attend)
) -> tuple[jax.Array, list]:
    """→ (last-live-position logits (1, V), updated caches) — plus the
    summed device counter vector when ``cfg.instrument`` is set.

    The engine's device page table stays −1 for the slot until the final
    chunk (prefilling slots are inert under concurrent decode steps), so the
    claimed pages arrive as the separate ``pages_row`` operand.  K/V scatter
    targets the claimed slabs; Mamba layers run the resumable SSD block
    against the slot's state row.  Logits only matter on the final chunk.

    ``first`` must be static (it is known at chunk-planning time): the first
    chunk runs from a ZERO recurrence — a reused slot's state rows still
    hold the previous occupant's final state — on the monolithic SSD chunk
    grid (``state=None`` → ``Q = min(chunk_size, L)``), and skips the prefix
    walk outright (every prefix lane is dead at t0 = 0).
    """
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    Cb = tokens.shape[1]
    positions = (t0 + jnp.arange(Cb))[None, :]  # (1, Cb) global positions

    def period_body(carry, xs):
        if cfg.instrument:
            x, caches, ctr = carry
        else:
            x, caches = carry
        x = constrain(x, ("batch", None, None))
        period_params, idx = xs
        # the tape is opened per scan-body iteration so recorded vectors
        # never escape their trace level (device.tape docstring)
        scope = device.tape() if cfg.instrument else contextlib.nullcontext()
        with scope as t:
            for lslot, kind in enumerate(cfg.layout):
                sp = period_params[lslot]
                c = _cache_get(caches[lslot], idx)
                h = rms_norm(x, sp["norm1"], cfg.norm_eps)
                if kind == "mamba":
                    st = (
                        None
                        if first
                        else ssm_mod.MambaState(
                            conv=c["conv"][slot][None], ssd=c["ssd"][slot][None]
                        )
                    )
                    y, st = ssm_mod.mamba_block(
                        sp["mamba"], h, cfg, state=st, return_state=True
                    )
                    x = x + y
                    caches[lslot] = _cache_put(
                        caches[lslot],
                        {
                            "conv": c["conv"].at[slot].set(
                                st.conv[0].astype(c["conv"].dtype)
                            ),
                            "ssd": c["ssd"].at[slot].set(st.ssd[0]),
                        },
                        idx,
                    )
                    continue
                q, k, v = project_qkv(sp["attn"], h, cfg, positions)
                att = kvcache.chunk_attend(
                    c, pages_row, q, k, v, t0, live, cfg, first=first
                )
                x = x + project_out(sp["attn"], att)
                c2 = kvcache.scatter_chunk(c, pages_row, k, v, t0, live, cfg)
                x = _mlp_or_moe(sp, x, lslot, cfg)
                caches[lslot] = _cache_put(caches[lslot], c2, idx)
        if cfg.instrument:
            return (x, caches, ctr + t.total()), None
        return (x, caches), None

    if cfg.instrument:
        (x, new_caches, ctr), _ = jax.lax.scan(
            period_body,
            (x, list(caches), device.zeros()),
            (params["layers"], jnp.arange(cfg.n_periods)),
        )
    else:
        (x, new_caches), _ = jax.lax.scan(
            period_body,
            (x, list(caches)),
            (params["layers"], jnp.arange(cfg.n_periods)),
        )
    last = jax.lax.dynamic_index_in_dim(x[0], live - 1, 0, keepdims=False)
    logits = logits_from_hidden(params, last[None], cfg)
    if cfg.instrument:
        return logits, new_caches, ctr
    return logits, new_caches


# --------------------------------------------------------------------------
# Decode: one token, cache push_back + bucket-walk attention.
# --------------------------------------------------------------------------

def init_decode_caches(
    cfg: ModelConfig,
    batch: int,
    length_hint: int,
    *,
    policy: str | None = None,
    enc_len: int | None = None,
) -> list:
    """Empty caches sized for a context of ``length_hint`` (dry-run entry)."""
    policy = cfg.cache_policy if policy is None else policy
    caches = []
    P = cfg.n_periods
    dt = jnp.dtype(cfg.dtype)
    for slot, kind in enumerate(cfg.layout):
        if kind == "mamba":
            st = ssm_mod.init_mamba_state(cfg, batch, dt)
            caches.append(
                {
                    "conv": jnp.zeros((P, *st.conv.shape), dt),
                    "ssd": jnp.zeros((P, *st.ssd.shape), jnp.float32),
                }
            )
            continue
        c = kvcache.init_cache(cfg, batch, length_hint, policy, stack=P)
        if cfg.n_enc_layers and enc_len:
            c = dict(
                c,
                cross_k=jnp.zeros((P, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt),
                cross_v=jnp.zeros((P, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt),
            )
        caches.append(c)
    return caches


def decode_step(
    params: dict,
    token: jax.Array,  # (B,) or (B, 1)
    caches: list,
    length: jax.Array,  # () or (B,) live context length
    cfg: ModelConfig,
    active: jax.Array | None = None,  # (B,) bool — rows whose state may move
) -> tuple[jax.Array, list]:
    """One serve step → (logits (B, V), updated caches).

    ``active`` masks *state writes* for rows mid-chunked-prefill: their KV
    appends already drop (page table −1) but Mamba conv/SSD rows would be
    clobbered by the batch-wide recurrence without the gate.

    With ``cfg.instrument`` the return gains a third element: the summed
    device counter vector (obs/device) recorded by the cache ops across all
    periods — device data, no transfer.
    """
    token = token.reshape(token.shape[0], 1)
    x = embed(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    positions = pos[:, None]  # (B, 1)

    def period_body(carry, xs):
        # caches ride the CARRY and are updated in place (dynamic-update-
        # slice) — the xs→ys formulation double-buffers the whole KV cache
        # (2× HBM on a 32k×128 cache; caught by the dry-run memory analysis).
        if cfg.instrument:
            x, caches, ctr = carry
        else:
            x, caches = carry
        x = constrain(x, ("batch", None, None))
        period_params, idx = xs
        # per-iteration tape: kvcache records land here and fold into the
        # scan carry, so the counter vector rides the step as device data
        scope = device.tape() if cfg.instrument else contextlib.nullcontext()
        with scope as t:
            for slot, kind in enumerate(cfg.layout):
                sp = period_params[slot]
                c = _cache_get(caches[slot], idx)
                h = rms_norm(x, sp["norm1"], cfg.norm_eps)
                if kind == "mamba":
                    y, st = ssm_mod.mamba_decode_step(
                        sp["mamba"], h, ssm_mod.MambaState(c["conv"], c["ssd"]), cfg
                    )
                    x = x + y
                    new_conv, new_ssd = st.conv, st.ssd
                    if active is not None:
                        keep = active[:, None, None]
                        new_conv = jnp.where(keep, new_conv, c["conv"])
                        new_ssd = jnp.where(keep[..., None], new_ssd, c["ssd"])
                    caches[slot] = _cache_put(
                        caches[slot], {"conv": new_conv, "ssd": new_ssd}, idx
                    )
                    continue
                q, k, v = project_qkv(sp["attn"], h, cfg, positions)
                kv_only = {key: val for key, val in c.items() if not key.startswith("cross")}
                c2 = kvcache.append(kv_only, k, v, pos, cfg)
                att = kvcache.attend(c2, q, pos + 1, cfg)
                x = x + project_out(sp["attn"], att)
                if "cross_k" in c:
                    hc = rms_norm(x, sp["cross_norm"], cfg.norm_eps)
                    qc = jnp.einsum("bsd,dhk->bshk", hc, sp["cross"]["wq"])
                    enc_len = c["cross_k"].shape[-3]
                    attc = kvcache.attend(
                        {"k": c["cross_k"], "v": c["cross_v"]}, qc,
                        jnp.full((B,), enc_len, jnp.int32), cfg,
                    )
                    x = x + project_out(sp["cross"], attc)
                x = _mlp_or_moe(sp, x, slot, cfg)
                caches[slot] = _cache_put(caches[slot], c2, idx)
        if cfg.instrument:
            return (x, caches, ctr + t.total()), None
        return (x, caches), None

    if cfg.instrument:
        (x, new_caches, ctr), _ = jax.lax.scan(
            period_body,
            (x, list(caches), device.zeros()),
            (params["layers"], jnp.arange(cfg.n_periods)),
        )
    else:
        (x, new_caches), _ = jax.lax.scan(
            period_body,
            (x, list(caches)),
            (params["layers"], jnp.arange(cfg.n_periods)),
        )
    logits = logits_from_hidden(params, x[:, 0], cfg)
    if cfg.instrument:
        return logits, new_caches, ctr
    return logits, new_caches
