"""Shared model building blocks: norms, rotary embeddings, embeddings, init."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "rope",
    "apply_rope",
    "embed",
    "unembed",
    "dense_init",
    "Param",
]

Param = dict[str, Any]


def dense_init(key: jax.Array, shape: tuple[int, ...], dtype, fan_in: int | None = None):
    """Scaled normal init (1/sqrt(fan_in))."""
    fan_in = shape[0] if fan_in is None else fan_in
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with a hand-written VJP that keeps *boundary* dtypes at the
    input dtype (bf16): the autodiff VJP of the internal f32 upcast emits
    f32 x-sized cotangents, which ride every sequence-parallel collective at
    2× payload (EXPERIMENTS.md §Perf B4/B6). Math inside stays f32."""
    return _rms_fwd(x, weight, eps)[0]


def _rms_fwd(x, weight, eps):
    x32 = x.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    y = (x32 * rstd * weight.astype(jnp.float32)).astype(x.dtype)
    return y, (x, weight)


def _rms_bwd(eps, res, dy):
    x, weight = res
    x32 = x.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    w32 = weight.astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    xhat = x32 * rstd
    # dL/dw — reduce over all leading dims
    dw = jnp.sum(dy32 * xhat, axis=tuple(range(dy.ndim - 1)))
    # dL/dx = rstd * (g - xhat * mean(g * xhat)) with g = dy * w
    g = dy32 * w32
    dx = rstd * (g - xhat * jnp.mean(g * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dw.astype(weight.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def rope(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding at ``positions`` (..., seq)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, dim/2)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate head vectors. x: (..., seq, heads, head_dim); cos/sin (..., seq, hd/2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    return jnp.concatenate((x1 * cos - x2 * sin, x2 * cos + x1 * sin), axis=-1).astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Project to vocab logits (fp32 for a stable softmax/CE)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32))
