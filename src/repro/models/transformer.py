"""Decoder stack: period-scanned heterogeneous layers (DESIGN.md §5).

``cfg.layout`` lists the layer kinds of one period (dense: ``("attn",)``;
Jamba: 7×mamba + 1×attn); parameters are stacked over ``n_periods`` and the
stack runs as one ``lax.scan`` — HLO stays O(one period) deep for a 64-layer
model, which keeps 80 dry-run compiles tractable and gives a uniform remat
boundary (one checkpoint per period when ``cfg.remat``).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import constrain
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.modules import Param, dense_init, embed, rms_norm, unembed

__all__ = ["init_params", "forward", "init_period_layers"]


def _init_slot(key: jax.Array, slot: int, kind: str, cfg: ModelConfig, dtype) -> Param:
    d = cfg.d_model
    p: Param = {"norm1": jnp.ones((d,), dtype)}
    if kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba(key, cfg, dtype)
        return p
    k1, k2, k3 = jax.random.split(key, 3)
    p["attn"] = attn_mod.init_attention(k1, cfg, dtype)
    p["norm2"] = jnp.ones((d,), dtype)
    if cfg.is_moe_layer(slot):
        p["moe"] = moe_mod.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp(k2, d, cfg.d_ff, cfg.activation, dtype)
    if cfg.n_enc_layers:  # enc-dec decoder: cross-attention sub-block
        p["cross_norm"] = jnp.ones((d,), dtype)
        p["cross"] = attn_mod.init_attention(k3, cfg, dtype)
    return p


def init_period_layers(key: jax.Array, cfg: ModelConfig, dtype) -> list[Param]:
    """One param pytree per layout slot, leaves stacked over periods."""
    slots = []
    for slot, kind in enumerate(cfg.layout):
        kslot = jax.random.fold_in(key, slot)
        keys = jax.random.split(kslot, cfg.n_periods)
        slots.append(
            jax.vmap(lambda k, s=slot, kd=kind: _init_slot(k, s, kd, cfg, dtype))(keys)
        )
    return slots


def init_params(key: jax.Array, cfg: ModelConfig) -> Param:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    params: Param = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": init_period_layers(keys[1], cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(keys[2], (cfg.padded_vocab, cfg.d_model)) * 0.02
        ).astype(dtype)
    if cfg.n_enc_layers:
        from repro.models import encdec

        params["encoder"] = encdec.init_encoder(keys[3], cfg, dtype)
    return params


def _apply_slot(
    sp: Param,
    x: jax.Array,
    kind: str,
    slot: int,
    cfg: ModelConfig,
    positions: jax.Array,
    memory_kv: tuple[jax.Array, jax.Array] | None,
) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    # constrain each norm output: forces the SP seq-gather (and its transpose
    # reduce-scatter) to move the bf16 tensor, not the norm's f32 internal
    # upcast — halves every activation collective's payload (§Perf).
    h = constrain(rms_norm(x, sp["norm1"], cfg.norm_eps), ("batch", "seq", None))
    if kind == "mamba":
        x = x + ssm_mod.mamba_block(sp["mamba"], h, cfg)
        return x, aux
    x = x + attn_mod.attention_block(sp["attn"], h, cfg, positions)
    if memory_kv is not None:
        h = constrain(rms_norm(x, sp["cross_norm"], cfg.norm_eps), ("batch", "seq", None))
        x = x + attn_mod.attention_block(sp["cross"], h, cfg, positions, kv=memory_kv)
    h = constrain(rms_norm(x, sp["norm2"], cfg.norm_eps), ("batch", "seq", None))
    if cfg.is_moe_layer(slot):
        out, aux = moe_mod.moe_block(sp["moe"], h, cfg)
        x = x + out
    else:
        x = x + mlp_mod.mlp_block(sp["mlp"], h, cfg.activation)
    return x, aux


def forward(
    params: Param,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    prefix_embeds: jax.Array | None = None,
    memory: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward → (logits, aux_loss).

    ``prefix_embeds``: (B, P, D) multimodal stub embeddings prepended to the
    token embeddings (VLM patches / audio frames).  ``memory``: (B, Senc, D)
    encoder output for enc-dec cross-attention.
    """
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, ("batch", "seq", None))
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    memory_kv = None
    if memory is not None:
        # cross-attention K/V are shared by all decoder layers per-slot; they
        # are computed inside each slot from its own projections, so pass the
        # raw memory and let the slot project (stacked weights under scan).
        memory_kv = memory

    def period_body(carry, period_params):
        from repro.distributed.sharding import constrain_param_tree

        x, aux = carry
        # DP batch + sequence-parallel residual stream at every period
        # boundary — this is what the scan carry (and remat save) inherits.
        x = constrain(x, ("batch", "seq", None))
        # pin sliced layer params (and, via transpose, their cotangents)
        period_params = constrain_param_tree(period_params, cfg)
        for slot, kind in enumerate(cfg.layout):
            sp = period_params[slot]
            mkv = None
            if memory_kv is not None and kind == "attn":
                k = jnp.einsum("bsd,dhk->bshk", memory_kv, sp["cross"]["wk"])
                v = jnp.einsum("bsd,dhk->bshk", memory_kv, sp["cross"]["wv"])
                mkv = (k, v)
            x, a = _apply_slot(sp, x, kind, slot, cfg, positions, mkv)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = constrain(unembed(x, table), ("batch", None, "vocab"))
    if cfg.padded_vocab != cfg.vocab_size:  # mask vocab-padding columns
        live = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(live, logits, -1e30)
    return logits, aux
