"""Encoder stack for encoder-decoder archs (seamless-m4t backbone).

The encoder consumes precomputed frame embeddings (the audio frontend is a
stub per the assignment — ``input_specs()`` supplies the embeddings) and runs
bidirectional attention layers; the decoder in models/transformer.py
cross-attends to the encoder output via per-layer cross blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.modules import Param, rms_norm

__all__ = ["init_encoder", "encode"]


def _init_enc_layer(key: jax.Array, cfg: ModelConfig, dtype) -> Param:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_mod.init_attention(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_mod.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def init_encoder(key: jax.Array, cfg: ModelConfig, dtype) -> Param:
    keys = jax.random.split(key, cfg.n_enc_layers)
    layers = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(keys)
    return {"layers": layers, "final_norm": jnp.ones((cfg.d_model,), dtype)}


def encode(enc_params: Param, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings → encoder memory (B, S_enc, D)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + attn_mod.attention_block(lp["attn"], h, cfg, positions, causal=False)
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp_mod.mlp_block(lp["mlp"], h, cfg.activation)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, enc_params["layers"])
    return rms_norm(x, enc_params["final_norm"], cfg.norm_eps)
