from repro.models import attention, encdec, frontends, mlp, modules, moe, ssm, transformer

__all__ = ["attention", "encdec", "frontends", "mlp", "modules", "moe", "ssm", "transformer"]
