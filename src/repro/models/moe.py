"""Mixture-of-Experts with parallel-insertion dispatch (DESIGN.md §3).

Assigning each routed token a unique slot in its expert's buffer **is** the
paper's insertion problem: experts ↔ LFVector blocks, token assignments ↔ the
insertion mask, and the per-expert rank is an exclusive prefix sum over the
assignment matrix — computed here by the same ``insertion_offsets`` machinery
(``cfg.insertion_method`` selects atomic/scan/mxu, the paper's three
algorithms; the MXU scan is the Pallas kernel).

Two execution paths:

``_moe_local``   — single-device / small-token path: one global buffer.
``_moe_sharded`` — the production path under a mesh (shard_map): each shard
    routes its own tokens and runs the insertion scan **shard-locally** (the
    paper's block-local independence, one LFVector set per shard), builds a
    local (E, C_local, D) buffer, and exchanges expert rows with one
    ``all_to_all`` over the EP ('model') axis — the Megatron/Tutel pattern.
    A global scatter-dispatch under auto-SPMD forces GSPMD to materialize
    replicated (E·C, D) intermediates (dbrx: >600 GB/device, caught by the
    dry-run); the shard-local formulation keeps every buffer
    O(local_tokens).

Expert capacity follows the GGArray geometry when ``ggarray_capacity`` is on:
instead of a fixed capacity factor (drop on overflow — the static-array
failure mode of §V), the buffer capacity snaps to the next geometric bucket
level, trading ≤2× memory for no drops; growth across steps is a copy-free
program-boundary event exactly like GGArray growth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import indexing
from repro.core.insertion import insertion_offsets
from repro.distributed.context import active_mesh, constrain
from repro.models.modules import Param, dense_init

__all__ = ["init_moe", "moe_block", "expert_capacity"]


def expert_capacity(moe: MoEConfig, n_tokens: int) -> int:
    """Per-expert buffer slots for a batch of ``n_tokens`` routed tokens."""
    mean = n_tokens * moe.top_k / moe.n_experts
    if moe.ggarray_capacity:
        # GGArray geometry: capacity = next bucket-chain level ≥ the mean load
        # (≤2× the needed memory, no token drops at ≤2× skew).
        need = int(mean) + 1
        nb = indexing.min_buckets_for(moe.capacity_b0, need)
        return indexing.capacity(moe.capacity_b0, max(nb, 1))
    return max(int(mean * moe.capacity_factor), 1)


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> Param:
    moe = cfg.moe
    d, dff = cfg.d_model, moe.d_ff_expert
    keys = jax.random.split(key, 4)
    return {
        "router": dense_init(keys[0], (d, moe.n_experts), jnp.float32),
        "w_gate": dense_init(keys[1], (moe.n_experts, d, dff), dtype, fan_in=d),
        "w_up": dense_init(keys[2], (moe.n_experts, d, dff), dtype, fan_in=d),
        "w_down": dense_init(keys[3], (moe.n_experts, dff, d), dtype, fan_in=dff),
    }


def _route_and_pack(p, xt, cfg, C):
    """Route tokens, run the parallel-insertion scan, pack expert buffers.

    xt: (T, D) → (buf (E, C, D), slot (Tk,), gate (T, k), stats).  Pure local
    jnp — usable standalone or inside shard_map (where T is per-shard and the
    insertion scan is the paper's block-local LFVector push_back).
    """
    moe = cfg.moe
    T, D = xt.shape
    logits = xt.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, moe.top_k)  # (T, k)
    gate = gate / jnp.clip(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # parallel insertion: experts are the LFVector blocks (paper §III.B)
    flat_expert = expert.reshape(-1)  # (Tk,)
    assign = jax.nn.one_hot(flat_expert, moe.n_experts, dtype=jnp.int32).T  # (E, Tk)
    offsets, _ = insertion_offsets(assign.astype(bool), method=cfg.insertion_method)
    rank = jnp.take_along_axis(offsets.T, flat_expert[:, None], axis=1)[:, 0]

    keep = rank < C
    slot = jnp.where(keep, flat_expert * C + rank, -1)
    xrep = jnp.repeat(xt, moe.top_k, axis=0)  # (Tk, D)
    tgt = jnp.where(slot >= 0, slot, moe.n_experts * C)
    buf = jnp.zeros((moe.n_experts * C, D), xt.dtype).at[tgt].set(xrep, mode="drop")
    density = jnp.mean(assign.astype(jnp.float32), axis=1)
    router_prob = jnp.mean(probs, axis=0)
    return buf.reshape(moe.n_experts, C, D), slot, gate, (density, router_prob)


def _expert_ffn(p, buf):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _combine(out_buf, slot, gate, T, D, dtype):
    flat = out_buf.reshape(-1, D)
    gathered = flat[jnp.where(slot >= 0, slot, 0)]
    gathered = jnp.where((slot >= 0)[:, None], gathered, 0.0)
    k = slot.shape[0] // T
    return jnp.sum(gathered.reshape(T, k, D) * gate[..., None].astype(dtype), axis=1)


def _moe_local(p: Param, x: jax.Array, cfg: ModelConfig):
    """One global buffer — single-device tests and tiny decode batches."""
    moe = cfg.moe
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    C = expert_capacity(moe, T)
    buf, slot, gate, (density, router_prob) = _route_and_pack(p, xt, cfg, C)
    out_buf = _expert_ffn(p, buf)
    out = _combine(out_buf, slot, gate, T, D, x.dtype)
    aux = moe.n_experts * jnp.sum(density * router_prob) * moe.top_k
    return out.reshape(B, S, D), aux


def _moe_sharded(p: Param, x: jax.Array, cfg: ModelConfig, mesh):
    """shard_map path: local routing + insertion, all_to_all over EP axis."""
    moe = cfg.moe
    B, S, D = x.shape
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = mesh.shape["model"]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    T_local = (B // dp_size) * (S // tp)
    C_local = expert_capacity(moe, T_local)

    def local_block(xl, router, w_gate, w_up, w_down):
        # xl: (B/dp, S/tp, D) — this shard's tokens (one LFVector set/shard)
        b, s, _ = xl.shape
        pl = {"router": router, "w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        xt = xl.reshape(b * s, D)
        buf, slot, gate, (density, router_prob) = _route_and_pack(pl, xt, cfg, C_local)
        # EP exchange: scatter expert rows to their owners, gather this
        # shard's experts from every peer → (E/tp, tp·C_local, D)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1, tiled=True)
        out = _expert_ffn(pl, buf)
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0, tiled=True)
        y = _combine(out, slot, gate, b * s, D, xl.dtype)
        aux_n = jnp.sum(density * router_prob)
        aux = moe.n_experts * moe.top_k * jax.lax.pmean(
            aux_n, tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        )
        return y.reshape(b, s, D), aux

    xspec = P(dp if dp else None, "model", None)
    out, aux = jax.shard_map(
        local_block,
        mesh=mesh,
        in_specs=(xspec, P(), P("model", None, None), P("model", None, None), P("model", None, None)),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def moe_block(p: Param, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Top-k routed expert MLP. x: (B, S, D) → (out, aux_loss)."""
    mesh = active_mesh()
    moe = cfg.moe
    B, S, D = x.shape
    if mesh is not None and "model" in mesh.shape:
        tp = mesh.shape["model"]
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                dp *= mesh.shape[a]
        if S % tp == 0 and moe.n_experts % tp == 0 and B % dp == 0:
            return _moe_sharded(p, x, cfg, mesh)
    return _moe_local(p, x, cfg)
