"""Attention: GQA projections + three interchangeable inner implementations.

``blockwise``  — pure-JAX flash (online softmax over KV chunks via ``lax.scan``,
                 optional query chunking): the dry-run/compile path.  Never
                 materializes a (Sq, Skv) score tensor, so 32k prefill and 500k
                 caches lower with bounded live memory.
``xla``        — naive einsum softmax (tiny shapes / oracle).
``pallas``     — the kernels/flash_attention TPU kernel (interpret off-TPU).

The decode path (one query token against a cache) lives in serving/kvcache.py
and reuses ``_chunk_update`` below for its per-bucket partial attention — the
GGArray rw_b access pattern (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import constrain
from repro.models.modules import Param, apply_rope, dense_init, rms_norm, rope

__all__ = [
    "init_attention",
    "attention_block",
    "project_qkv",
    "project_out",
    "inner_attention",
    "SoftmaxState",
    "softmax_state_init",
    "chunk_update",
    "softmax_state_finish",
    "MASK_VALUE",
]

MASK_VALUE = -1e30


# --------------------------------------------------------------------------
# Online-softmax machinery (shared by prefill blockwise + decode buckets).
# --------------------------------------------------------------------------

class SoftmaxState(NamedTuple):
    m: jax.Array  # (..., 1) running max
    l: jax.Array  # (..., 1) running denominator
    acc: jax.Array  # (..., d) running numerator


def softmax_state_init(shape: tuple[int, ...], d: int) -> SoftmaxState:
    return SoftmaxState(
        m=jnp.full((*shape, 1), MASK_VALUE, jnp.float32),
        l=jnp.zeros((*shape, 1), jnp.float32),
        acc=jnp.zeros((*shape, d), jnp.float32),
    )


def chunk_update(
    state: SoftmaxState,
    s: jax.Array,  # (..., kv_chunk) masked scores, f32
    v: jax.Array,  # broadcastable to (..., kv_chunk, d), f32
) -> SoftmaxState:
    m_new = jnp.maximum(state.m, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(state.m - m_new)
    l = state.l * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc = state.acc * alpha + p @ v
    return SoftmaxState(m_new, l, acc)


def softmax_state_finish(state: SoftmaxState) -> jax.Array:
    return state.acc / jnp.maximum(state.l, 1e-30)


# --------------------------------------------------------------------------
# Inner attention implementations. q: (B, Sq, H, Dh); k,v: (B, Skv, KH, Dh).
# --------------------------------------------------------------------------

def _xla_attention(q, k, v, *, group, causal, q_offset=0):
    B, Sq, H, Dh = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    qr = q.reshape(B, Sq, KH, group, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32)) * (Dh ** -0.5)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = qpos[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def _blockwise_attention(q, k, v, *, group, causal, chunk, q_offset=0):
    """Flash attention in pure JAX: scan over KV chunks, carry softmax state."""
    B, Sq, H, Dh = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    qr = q.reshape(B, Sq, KH, group, Dh).astype(jnp.float32) * (Dh ** -0.5)
    # q stays seq-sharded; each KV chunk is small and streamed per scan step.
    # Without these constraints the chunk-major reshape can lose the seq
    # sharding (n_chunks not mesh-divisible, e.g. VLM's 33024 tokens) and
    # GSPMD replicates the f32 q (10 GB global on 32k prefill).
    qr = constrain(qr, ("batch", "seq", None, None, None))
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KH, Dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KH, Dh), 1, 0)
    kc = constrain(kc, (None, "batch", None, None, None))
    vc = constrain(vc, (None, "batch", None, None, None))
    qpos = q_offset + jnp.arange(Sq)

    def body(state: SoftmaxState, xs):
        # state.m/l: (B, Sq, KH, G); state.acc: (B, Sq, KH, G, Dh)
        ci, kk, vv = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qr, kk.astype(jnp.float32))
        kpos = ci * chunk + jnp.arange(chunk)
        live = kpos < Skv
        if causal:
            live = live[None, :] & (qpos[:, None] >= kpos[None, :])
            s = jnp.where(live[None, :, None, None, :], s, MASK_VALUE)
        else:
            s = jnp.where(live[None, None, None, None, :], s, MASK_VALUE)
        m_new = jnp.maximum(state.m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(state.m - m_new)
        l = state.l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p, vv.astype(jnp.float32))
        acc = state.acc * alpha[..., None] + pv
        return SoftmaxState(m_new, l, acc), None

    state0 = SoftmaxState(
        m=jnp.full((B, Sq, KH, group), MASK_VALUE, jnp.float32),
        l=jnp.zeros((B, Sq, KH, group), jnp.float32),
        acc=jnp.zeros((B, Sq, KH, group, Dh), jnp.float32),
    )
    # Nested remat: without it the backward pass saves the (B,Sq,KH,G,chunk)
    # score/probability tensors of EVERY chunk — the flash-backward property
    # (recompute s/p per chunk) comes from checkpointing the chunk body.
    state, _ = jax.lax.scan(jax.checkpoint(body), state0, (jnp.arange(n_chunks), kc, vc))
    out = state.acc / jnp.maximum(state.l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def _merge_states(a: SoftmaxState, b: SoftmaxState) -> SoftmaxState:
    """Combine two online-softmax partials over disjoint KV sets."""
    m = jnp.maximum(a.m, b.m)
    ea, eb = jnp.exp(a.m - m), jnp.exp(b.m - m)
    return SoftmaxState(
        m=m,
        l=a.l * ea + b.l * eb,
        acc=a.acc * ea[..., None] + b.acc * eb[..., None],
    )


def _rect_state(qr, k, v, chunk, kv_offset=0):
    """Unmasked blockwise attention returning the softmax state.

    qr: (B, Sq, KH, G, Dh) pre-scaled f32; k/v: (B, Skv, KH, Dh).
    """
    B, Sq, KH, G, Dh = qr.shape
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KH, Dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KH, Dh), 1, 0)

    def body(state, xs):
        ci, kk, vv = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qr, kk.astype(jnp.float32))
        live = ci * chunk + jnp.arange(chunk) < Skv
        s = jnp.where(live[None, None, None, None, :], s, MASK_VALUE)
        m_new = jnp.maximum(state.m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(state.m - m_new)
        l = state.l * alpha + jnp.sum(p, axis=-1)
        acc = state.acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vv.astype(jnp.float32)
        )
        return SoftmaxState(m_new, l, acc), None

    state0 = SoftmaxState(
        m=jnp.full((B, Sq, KH, G), MASK_VALUE, jnp.float32),
        l=jnp.zeros((B, Sq, KH, G), jnp.float32),
        acc=jnp.zeros((B, Sq, KH, G, Dh), jnp.float32),
    )
    if n_chunks == 1:
        state, _ = body(state0, (jnp.int32(0), kc[0], vc[0]))
        return state
    state, _ = jax.lax.scan(jax.checkpoint(body), state0, (jnp.arange(n_chunks), kc, vc))
    return state


def _diag_state(qr, k, v, q_offset, kv_offset):
    """One causal leaf block: masked single-chunk attention state."""
    B, Sq, KH, G, Dh = qr.shape
    s = jnp.einsum("bqkgd,bckd->bqkgc", qr, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(Sq)
    kpos = kv_offset + jnp.arange(k.shape[1])
    mask = qpos[:, None] >= kpos[None, :]
    s = jnp.where(mask[None, :, None, None, :], s, MASK_VALUE)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return SoftmaxState(m, l, acc)


def _causal_tri_state(qr, k, v, chunk, q_offset=0):
    """Recursive triangular causal attention (flop-exact ~n(n+1)/2 chunks).

    causal([A;B]) = [causal(A); merge(causal(B), rect(B→A))] — the strictly-
    lower rectangle is *unmasked*, so no masked-out chunk work is computed.
    Halves 32k-prefill attention FLOPs vs the rectangular+mask formulation
    (§Perf cell C); recursion depth is log2(S/chunk), unrolled statically.
    """
    S = qr.shape[1]
    if S <= chunk:
        return _diag_state(qr, k, v, q_offset, q_offset)
    half = S // 2
    qa, qb = qr[:, :half], qr[:, half:]
    ka, kb = k[:, :half], k[:, half:]
    va, vb = v[:, :half], v[:, half:]
    state_a = _causal_tri_state(qa, ka, va, chunk, q_offset)
    state_b = _causal_tri_state(qb, kb, vb, chunk, q_offset + half)
    state_b = _merge_states(state_b, _rect_state(qb, ka, va, chunk))
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1), state_a, state_b)


def _blockwise_tri_attention(q, k, v, *, group, causal, chunk, q_offset=0):
    B, Sq, H, Dh = q.shape
    KH = k.shape[2]
    qr = q.reshape(B, Sq, KH, group, Dh).astype(jnp.float32) * (Dh ** -0.5)
    # no seq-gather here: with chunk == seq/shards the recursion's halving
    # splits are all shard-aligned, so diagonal leaves stay shard-local
    if not causal or Sq != k.shape[1]:
        state = _rect_state(qr, k, v, chunk)
    else:
        state = _causal_tri_state(qr, k, v, chunk, q_offset)
    out = state.acc / jnp.maximum(state.l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def _pallas_attention(q, k, v, *, group, causal):
    from repro.kernels.flash_attention import ops as fa_ops

    B, Sq, H, Dh = q.shape
    KH = k.shape[2]
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KH, k.shape[1], Dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KH, v.shape[1], Dh)
    out = fa_ops.flash_attention(qh, kh, vh, group=group, causal=causal)
    return out.reshape(B, H, Sq, Dh).transpose(0, 2, 1, 3)


def inner_attention(q, k, v, cfg: ModelConfig, *, causal=None, q_offset=0):
    causal = cfg.causal if causal is None else causal
    group = q.shape[2] // k.shape[2]
    if cfg.attention_impl == "xla":
        return _xla_attention(q, k, v, group=group, causal=causal, q_offset=q_offset)
    if cfg.attention_impl == "pallas":
        return _pallas_attention(q, k, v, group=group, causal=causal)
    if cfg.attention_impl == "blockwise_tri":
        return _blockwise_tri_attention(
            q, k, v, group=group, causal=causal, chunk=cfg.attention_chunk, q_offset=q_offset
        )
    return _blockwise_attention(
        q, k, v, group=group, causal=causal, chunk=cfg.attention_chunk, q_offset=q_offset
    )


# --------------------------------------------------------------------------
# Full attention block: projections (+bias), qk-norm, rope.
# --------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig, dtype) -> Param:
    d, dh = cfg.d_model, cfg.head_dim
    keys = jax.random.split(key, 4)
    p: Param = {
        "wq": dense_init(keys[0], (d, cfg.n_heads, dh), dtype),
        "wk": dense_init(keys[1], (d, cfg.n_kv_heads, dh), dtype),
        "wv": dense_init(keys[2], (d, cfg.n_kv_heads, dh), dtype),
        "wo": dense_init(keys[3], (cfg.n_heads, dh, d), dtype, fan_in=cfg.n_heads * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, dh), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, dh), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def project_qkv(p: Param, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """x: (B, S, D) → q (B,S,H,Dh), k,v (B,S,KH,Dh) with bias/qk-norm/rope.

    Activations are head-sharded (Megatron TP): dWq/dWk/dWv then come out
    head-sharded with no model-axis gradient reduction (§Perf).
    """
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), ("batch", None, "heads", None))
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), ("batch", None, "kv_heads", None))
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), ("batch", None, "kv_heads", None))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def project_out(p: Param, attn_out: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"])


def attention_block(
    p: Param,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    causal: bool | None = None,
    kv: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """Self-attention (or cross-attention when ``kv`` is provided)."""
    if kv is None:
        q, k, v = project_qkv(p, x, cfg, positions)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        k, v = kv
        causal = False
    out = inner_attention(q, k, v, cfg, causal=causal)
    return project_out(p, out)
