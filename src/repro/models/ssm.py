"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) layer.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
work *within* a chunk, a linear recurrence *across* chunk states — memory
stays O(L·d + chunks·state), which is what makes ``long_500k`` runnable for
SSM/hybrid archs (DESIGN.md §6).  Decode carries an O(1) recurrent state
(conv window + SSD state) per layer — no KV cache at all, hence GGArray's
cache role is inapplicable for pure-SSM archs (noted §Arch-applicability).

Jamba's Mamba blocks reuse this layer with the SSD formulation (d_state=16);
the original Jamba uses Mamba-1 — recorded as an adaptation in DESIGN.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.modules import Param, dense_init, rms_norm

__all__ = ["init_mamba", "mamba_block", "mamba_decode_step", "init_mamba_state", "MambaState"]


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, d_inner + 2*g*n) — rolling conv window
    ssd: jax.Array  # (B, nh, hd, n) — recurrent SSD state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_ssm_heads(cfg.d_model)
    return s, di, nh, s.head_dim, s.n_groups, s.d_state


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype) -> Param:
    # Projections are kept as separate weights (not the fused zxbcdt matrix of
    # the reference impl) so each can carry its own TP sharding: wz/wx shard
    # the inner (head) dim, wBC is shared across heads and stays replicated,
    # wdt is per-head.  Math is identical; XLA fuses the matmuls back.
    s, di, nh, hd, g, n = _dims(cfg)
    d = cfg.d_model
    conv_ch = di + 2 * g * n
    keys = jax.random.split(key, 6)
    return {
        "wz": dense_init(keys[0], (d, di), dtype),
        "wx": dense_init(keys[1], (d, di), dtype),
        "wBC": dense_init(keys[2], (d, 2 * g * n), dtype),
        "wdt": dense_init(keys[3], (d, nh), dtype),
        "conv_w": dense_init(keys[4], (s.d_conv, conv_ch), dtype, fan_in=s.d_conv),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(keys[5], (di, d), dtype),
    }


def _split_proj(p: Param, x: jax.Array, cfg: ModelConfig):
    z = x @ p["wz"]
    xBC = jnp.concatenate([x @ p["wx"], x @ p["wBC"]], axis=-1)
    dt = x @ p["wdt"]
    return z, xBC, dt


def _causal_conv(p: Param, xBC: jax.Array, d_conv: int) -> jax.Array:
    """Depthwise causal conv along L via shifted adds (window is tiny)."""
    out = xBC * p["conv_w"][-1]
    for i in range(1, d_conv):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, : xBC.shape[1]]
        out = out + shifted * p["conv_w"][-1 - i]
    return jax.nn.silu(out + p["conv_b"])


def _segsum(dA: jax.Array) -> jax.Array:
    """L[i, j] = sum_{j<k<=i} dA[k] for i>=j else -inf. dA: (..., Q)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def mamba_block(
    p: Param,
    x: jax.Array,
    cfg: ModelConfig,
    state: MambaState | None = None,
    *,
    return_state: bool = False,
):
    """Full-sequence SSD pass. x: (B, L, D) → (B, L, D) [, final MambaState].

    ``state`` makes this a *resumable* chunk step (serving's chunked
    prefill): ``state.ssd`` seeds the inter-chunk recurrence and
    ``state.conv`` supplies the raw pre-conv history the causal conv window
    reaches back into.  With a zero state the history rows are zeros — the
    exact values the implicit left zero-pad used to contribute — so the
    ``state=None`` path is bit-identical to before.
    """
    s, di, nh, hd, g, n = _dims(cfg)
    B, L, _ = x.shape
    # Resumable calls keep the full chunk grid: a short tail (L < chunk_size)
    # must pad up to the same Q the monolithic pass used, or the repartition
    # changes fp association (pad steps are dt-zeroed, hence state-neutral).
    Q = s.chunk_size if state is not None else min(s.chunk_size, L)
    pad = (-L) % Q
    Lp = L + pad
    nc = Lp // Q

    z, xBC, dt = _split_proj(p, x, cfg)
    hist = (
        state.conv.astype(xBC.dtype)
        if state is not None
        else jnp.zeros((B, s.d_conv - 1, xBC.shape[-1]), xBC.dtype)
    )
    xBC = jnp.concatenate([hist, xBC], axis=1)  # (B, d_conv-1 + L, ch)
    # raw (pre-conv) tail → the next step's conv window; the history concat
    # keeps it full-width even for L < d_conv-1 prompts
    conv_tail = xBC[:, xBC.shape[1] - (s.d_conv - 1) :, :]
    if pad:  # pad to a chunk multiple; dt is zeroed on pad steps below, which
        # makes them state-neutral (decay=exp(0)=1, contribution dt·B·x=0)
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        xBC = jnp.pad(xBC, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xBC = _causal_conv(p, xBC, s.d_conv)[:, s.d_conv - 1 :]
    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    xs = xs.reshape(B, Lp, nh, hd)
    Bm = Bm.reshape(B, Lp, g, n)
    Cm = Cm.reshape(B, Lp, g, n)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, Lp, nh)
    if pad:
        dt = dt * (jnp.arange(Lp) < L).astype(dt.dtype)[None, :, None]
    A = -jnp.exp(p["A_log"])  # (nh,)
    dA = dt * A  # (B, Lp, nh) log-decay

    # chunk reshape: (B, nc, Q, ...)
    xc = xs.reshape(B, nc, Q, nh, hd).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, g, n).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, g, n).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, nh)
    dAc = dA.reshape(B, nc, Q, nh)

    # heads → groups mapping (heads per group)
    hpg = nh // g
    Bh = jnp.repeat(Bc, hpg, axis=3)  # (B, nc, Q, nh, n)
    Ch = jnp.repeat(Cc, hpg, axis=3)

    # ---- within-chunk (quadratic, attention-like) ----
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dAc, -1, -2)))  # (B, nc, nh, Q, Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)  # (B, nc, nh, Q, Q)
    xdt = xc * dtc[..., None]  # (B, nc, Q, nh, hd)
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp", scores, Lmat, xdt)

    # ---- chunk states ----
    cs = jnp.cumsum(dAc, axis=2)  # (B, nc, Q, nh)
    tot = cs[:, :, -1:, :]  # (B, nc, 1, nh)
    decay_to_end = jnp.exp(tot - cs)  # (B, nc, Q, nh)
    chunk_states = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn", Bh, decay_to_end * dtc, xc
    )  # (B, nc, nh, hd, n)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(tot[:, :, 0, :])  # (B, nc, nh)
    s0 = (
        state.ssd.astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, nh, hd, n), jnp.float32)
    )

    def scan_body(carry, xs_):
        st, dec = xs_  # st: (B, nh, hd, n), dec: (B, nh)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* each chunk

    final_ssd, prev_states = jax.lax.scan(
        scan_body,
        s0,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, nc, nh, hd, n)

    # ---- state → output ----
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, jnp.exp(cs)
    )
    y = (y_diag + y_off).reshape(B, Lp, nh, hd)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, Lp, di)[:, :L].astype(x.dtype)
    z = z[:, :L]

    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, MambaState(conv=conv_tail, ssd=final_ssd)
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    s, di, nh, hd, g, n = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, s.d_conv - 1, di + 2 * g * n), dtype),
        ssd=jnp.zeros((batch, nh, hd, n), jnp.float32),
    )


def mamba_decode_step(
    p: Param, x: jax.Array, state: MambaState, cfg: ModelConfig
) -> tuple[jax.Array, MambaState]:
    """One-token recurrent step. x: (B, 1, D) → (B, 1, D), new state."""
    s, di, nh, hd, g, n = _dims(cfg)
    B = x.shape[0]
    z, xBC, dt = _split_proj(p, x, cfg)  # (B, 1, ...)
    xBC = xBC[:, 0]

    # rolling conv window
    window = jnp.concatenate([state.conv, xBC[:, None]], axis=1)  # (B, d_conv, ch)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs, Bm, Cm = jnp.split(xBC, [di, di + g * n], axis=-1)
    xs = xs.reshape(B, nh, hd).astype(jnp.float32)
    Bm = Bm.reshape(B, g, n).astype(jnp.float32)
    Cm = Cm.reshape(B, g, n).astype(jnp.float32)
    hpg = nh // g
    Bh = jnp.repeat(Bm, hpg, axis=1)  # (B, nh, n)
    Ch = jnp.repeat(Cm, hpg, axis=1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)  # (B, nh)

    new_ssd = state.ssd * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xs, Bh
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_ssd) + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], MambaState(conv=new_conv, ssd=new_ssd)
