"""Dense MLP blocks: SwiGLU (llama/qwen lineage), GELU (starcoder2), ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import constrain
from repro.models.modules import Param, dense_init

__all__ = ["init_mlp", "mlp_block"]


def init_mlp(key: jax.Array, d_model: int, d_ff: int, activation: str, dtype) -> Param:
    keys = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": dense_init(keys[0], (d_model, d_ff), dtype),
            "w_up": dense_init(keys[1], (d_model, d_ff), dtype),
            "w_down": dense_init(keys[2], (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(keys[0], (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(keys[1], (d_ff, d_model), dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def mlp_block(p: Param, x: jax.Array, activation: str) -> jax.Array:
    # Megatron TP inside the block: the hidden is ff-sharded, so d(w_up/gate)
    # = xᵀ·dh contracts only data-sharded dims and comes out ff-sharded —
    # no full-(D, ff) model-axis gradient all-reduce per layer (§Perf).
    if activation == "swiglu":
        h = jax.nn.silu(constrain(x @ p["w_gate"], ("batch", None, "ff"))) * constrain(
            x @ p["w_up"], ("batch", None, "ff")
        )
        return h @ p["w_down"]
    act = jax.nn.gelu if activation == "gelu" else jax.nn.relu
    h = act(constrain(x @ p["w_up"], ("batch", None, "ff")) + p["b_up"])
    return h @ p["w_down"] + p["b_down"]
