"""Modality frontend stubs (assignment: [audio]/[vlm] specify the BACKBONE).

``input_specs()`` supplies precomputed patch/frame embeddings; these helpers
generate synthetic ones for smoke tests and examples, and document the split
between the (stubbed) frontend and the (real) backbone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["synthetic_prefix_embeds", "synthetic_frames"]


def synthetic_prefix_embeds(
    key: jax.Array, cfg: ModelConfig, batch: int, dtype=None
) -> jax.Array:
    """ViT-patch-embedding stand-ins: (B, n_prefix, d_model)."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    return (
        jax.random.normal(key, (batch, cfg.n_prefix_embeds, cfg.d_model)) * 0.02
    ).astype(dtype)


def synthetic_frames(
    key: jax.Array, cfg: ModelConfig, batch: int, seq: int, dtype=None
) -> jax.Array:
    """Audio frame-embedding stand-ins: (B, S_enc, d_model)."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    return (jax.random.normal(key, (batch, seq, cfg.d_model)) * 0.02).astype(dtype)
