"""LR schedule: linear warmup + cosine decay (the MaxText/llama default)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    frac = (step - warmup) / jnp.maximum(total - warmup, 1)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(frac, 0, 1)))
    return jnp.where(step < warmup, warm, cos)
