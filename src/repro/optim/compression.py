"""Gradient compression with error feedback (distributed-optimization trick).

Per-tensor symmetric int8 quantization; the residual (quantization error) is
carried in an error-feedback buffer and re-added next step, which keeps SGD
convergence (1-bit-Adam lineage).  In a pod-level data-parallel reduction this
cuts cross-pod all-reduce bytes 4× for bf16 grads (2× for f32 moments); the
dry-run's collective-bytes accounting picks this up when enabled because the
reduced tensors are physically int8 (see distributed/collectives.py
``compressed_psum``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["EFState", "ef_init", "quantize", "dequantize", "compress_grads"]


class EFState(NamedTuple):
    residual: dict  # same tree as grads, fp32


def ef_init(grads_like) -> EFState:
    return EFState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp → (int8, scale). Symmetric, per-tensor."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState) -> tuple[dict, EFState]:
    """Quantize-dequantize each grad with error feedback → (grads', ef')."""

    def one(g, r):
        full = g.astype(jnp.float32) + r
        q, scale = quantize(full)
        deq = dequantize(q, scale)
        return deq.astype(g.dtype), full - deq

    pairs = jax.tree.map(one, grads, ef.residual)
    new_g = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, EFState(residual=new_r)
