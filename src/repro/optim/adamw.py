"""AdamW with fp32 moments over (possibly bf16) params, ZeRO-friendly.

Moments inherit the parameter sharding (FSDP rules shard them with the
params), which is the optimizer-state sharding half of ZeRO; the update runs
in fp32 and casts back to the parameter dtype.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "init", "update", "global_norm"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros32, params),
        v=jax.tree.map(zeros32, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(
    grads, state: AdamWState, params, cfg: AdamWConfig, lr_scale: jax.Array | float = 1.0
):
    """One AdamW step → (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat, vhat = m / bc1, v / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
