from repro.optim import adamw, compression, schedule

__all__ = ["adamw", "schedule", "compression"]
