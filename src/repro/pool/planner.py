"""Host-side slab accounting: free-list allocator + per-tenant planner.

The allocator is the host mirror of the pool's device free-list bitmap
(``SlabPool.free``): claims and releases are pure host bookkeeping (the
device bitmap is updated by the arena in the same program-boundary step), so
slab allocation never reads the device — the arena analog of the
``CapacityPlanner`` contract (DESIGN.md §2/§4).

``TenantPlanner`` extends ``core.ggarray.CapacityPlanner``'s bound tracking
to a *fleet*: one upper bound per logical array, advanced by exact per-array
lane counts when the append mask is host-known, plus an optional per-tenant
slab quota — the admission-control knob a multi-tenant serving pool needs so
one runaway sequence cannot starve the others.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

__all__ = [
    "SlabAllocator",
    "TenantPlanner",
    "PageBook",
    "QuotaExceeded",
    "growth_amount",
]


def growth_amount(n_slabs: int, short: int, grow_chunk: int | str) -> int:
    """Slabs to add when the free list is ``short`` of a claim.

    ``grow_chunk`` is the over-provisioning policy:

    * an int ``c`` — demand growth with a floor: add ``max(short, c)``
      (``1`` = exact demand, the tight-capacity default);
    * ``"geometric"`` — double the pool: add ``max(short, n_slabs, 1)``,
      so a fleet that keeps growing pays **O(log n_slabs)** realloc copies
      total instead of one per growth wave (Tarjan & Zwick amortization;
      asserted in ``tests/pool/test_arena.py``).

    Pre-carving (``SlabArena(initial_slabs=...)`` / a pool sized to the
    expected high-water mark at engine start) composes with either policy —
    growth only begins once the pre-carve is exhausted.
    """
    if grow_chunk == "geometric":
        return max(short, n_slabs, 1)
    return max(short, int(grow_chunk))


class QuotaExceeded(RuntimeError):
    """A claim would push a tenant past its per-tenant slab quota."""


class SlabAllocator:
    """Lowest-index-first free list over ``n_slabs`` pool slots.

    Lowest-first claiming makes reuse the default: released slabs always sit
    below freshly grown ones, so the pool only grows once every freed slab
    is back in use (the reclamation invariant the property tests assert).
    """

    def __init__(self, n_slabs: int = 0, *, quota_slabs: int | None = None):
        self.free = np.ones((n_slabs,), bool)
        self.owner = np.full((n_slabs,), -1, np.int32)  # tenant per slab
        self.quota_slabs = quota_slabs
        self.claims = 0
        self.reuse_claims = 0  # claims satisfied by a previously released slab
        self.releases = 0
        self.grown_slabs = 0
        self.peak_live = 0
        self._ever_released = np.zeros((n_slabs,), bool)

    @property
    def n_slabs(self) -> int:
        return len(self.free)

    @property
    def free_count(self) -> int:
        return int(self.free.sum())

    @property
    def live_count(self) -> int:
        return self.n_slabs - self.free_count

    def tenant_slabs(self, tenant: int) -> int:
        return int((self.owner == tenant).sum())

    def shortfall(self, k: int) -> int:
        """Slabs the pool must grow by before ``claim(·, k)`` can succeed."""
        return max(k - self.free_count, 0)

    def grow(self, extra: int) -> None:
        self.free = np.concatenate([self.free, np.ones((extra,), bool)])
        self.owner = np.concatenate([self.owner, np.full((extra,), -1, np.int32)])
        self._ever_released = np.concatenate(
            [self._ever_released, np.zeros((extra,), bool)]
        )
        self.grown_slabs += extra

    def claim(self, tenant: int, k: int) -> np.ndarray:
        """Claim ``k`` slabs for ``tenant`` → int32 slab ids (lowest first)."""
        if k == 0:
            return np.zeros((0,), np.int32)
        if self.quota_slabs is not None:
            if self.tenant_slabs(tenant) + k > self.quota_slabs:
                raise QuotaExceeded(
                    f"tenant {tenant}: {self.tenant_slabs(tenant)} + {k} slabs "
                    f"> quota {self.quota_slabs}"
                )
        ids = np.flatnonzero(self.free)[:k].astype(np.int32)
        if len(ids) < k:
            raise RuntimeError(
                f"free list exhausted: want {k}, have {len(ids)} "
                "(grow the pool first — see SlabArena._ensure_slabs)"
            )
        self.free[ids] = False
        self.owner[ids] = tenant
        self.claims += k
        self.reuse_claims += int(self._ever_released[ids].sum())
        self.peak_live = max(self.peak_live, self.live_count)
        return ids

    def release(self, ids: np.ndarray) -> None:
        ids = np.asarray(ids, np.int32)
        if len(ids) == 0:
            return
        if self.free[ids].any():
            raise RuntimeError(f"double free: {ids[self.free[ids]]}")
        self.free[ids] = True
        self.owner[ids] = -1
        self._ever_released[ids] = True
        self.releases += len(ids)

    def release_tenant(self, tenant: int) -> np.ndarray:
        """Release every slab of ``tenant`` → the freed ids."""
        ids = np.flatnonzero(self.owner == tenant).astype(np.int32)
        self.release(ids)
        return ids

    def check(self) -> None:
        """Internal free-xor-owned invariant."""
        bad = self.free & (self.owner >= 0)
        assert not bad.any(), f"slabs both free and owned: {np.flatnonzero(bad)}"
        bad = ~self.free & (self.owner < 0)
        assert not bad.any(), f"slabs claimed but unowned: {np.flatnonzero(bad)}"


class PageBook:
    """Host-side page-table bookkeeping shared by the arena and the engine.

    One :class:`SlabAllocator` plus the pieces every page-table owner needs
    kept consistent with it: per-tenant page counts, the slab→page mapping
    (claim order), and the geometric table-width policy.  Pure host state —
    callers apply the matching device updates (pool growth, free bitmap,
    page-table scatters) at the program boundary.  Keeping this in one
    place is what keeps ``SlabArena`` and ``BatchEngine`` free-list
    semantics identical (reuse-before-grow, page0 offsetting, O(log) table
    restructures).
    """

    def __init__(self, ntenants: int, *, quota_slabs: int | None = None):
        self.alloc = SlabAllocator(0, quota_slabs=quota_slabs)
        self.npages = np.zeros((ntenants,), np.int64)
        self.page_of_slab = np.full((0,), -1, np.int64)
        self.max_pages = 1

    def grow(self, extra: int) -> None:
        """Record ``extra`` fresh slabs (caller grew the device pool)."""
        self.alloc.grow(extra)
        self.page_of_slab = np.concatenate(
            [self.page_of_slab, np.full((extra,), -1, np.int64)]
        )

    def shortfall(self, k: int) -> int:
        return self.alloc.shortfall(k)

    def widen(self, need: int) -> tuple[int, int] | None:
        """Geometric table widening → (old, new) widths, or None if covered."""
        if need <= self.max_pages:
            return None
        old, self.max_pages = self.max_pages, max(need, 2 * self.max_pages)
        return old, self.max_pages

    def claim(self, tenant: int, k: int) -> tuple[np.ndarray, int]:
        """Claim ``k`` slabs → (ids, first page index).  Reuse-first; the
        free list must already cover ``k`` (grow the pool on shortfall)."""
        ids = self.alloc.claim(tenant, k)
        page0 = int(self.npages[tenant])
        self.page_of_slab[ids] = page0 + np.arange(k)
        self.npages[tenant] += k
        return ids, page0

    def release(self, tenant: int) -> np.ndarray:
        """Free every slab of ``tenant`` → the freed ids."""
        ids = self.alloc.release_tenant(tenant)
        self.page_of_slab[ids] = -1
        self.npages[tenant] = 0
        return ids

    def pages_in_order(self, tenant: int) -> np.ndarray:
        """``tenant``'s slab ids sorted by their page index."""
        owned = np.flatnonzero(self.alloc.owner == tenant)
        return owned[np.argsort(self.page_of_slab[owned])]


class TenantPlanner:
    """Per-tenant size upper bounds — ``CapacityPlanner`` at fleet scale.

    ``plan(m, mask)`` advances each tenant's bound (exactly, when ``mask``
    is a host array; by ``m`` otherwise) and returns the per-tenant counts;
    ``sync(sizes)`` re-seeds the bounds from a device read when pessimism
    would otherwise claim slabs the data doesn't need.
    """

    def __init__(self, ntenants: int):
        self.ub = np.zeros((ntenants,), np.int64)
        self.host_syncs = 0

    @staticmethod
    def host_counts(mask: Any, ntenants: int, m: int) -> np.ndarray | None:
        if mask is None:
            return np.full((ntenants,), m, np.int64)
        if isinstance(mask, jax.Array):
            return None  # device mask: converting it would be the sync
        arr = np.asarray(mask)
        if arr.ndim != 2 or arr.shape[0] != ntenants:
            return None
        return (arr != 0).sum(axis=1).astype(np.int64)

    def plan(self, m: int, mask: Any = None) -> tuple[np.ndarray, bool]:
        """→ (per-tenant advance, exact?) without touching the bounds."""
        counts = self.host_counts(mask, len(self.ub), m)
        if counts is None:
            return np.full((len(self.ub),), m, np.int64), False
        return counts, mask is None or not isinstance(mask, jax.Array)

    def advance(self, counts: np.ndarray) -> None:
        self.ub += counts

    def sync(self, sizes: jax.Array) -> np.ndarray:
        """Re-seed bounds from the device sizes vector (one transfer)."""
        self.ub = np.asarray(jax.device_get(sizes), np.int64)
        self.host_syncs += 1
        return self.ub

    def reset(self, tenant: int) -> None:
        self.ub[tenant] = 0
