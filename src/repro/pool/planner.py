"""Host-side slab accounting: free-list allocator + per-tenant planner.

The allocator is the host mirror of the pool's device free-list bitmap
(``SlabPool.free``): claims and releases are pure host bookkeeping (the
device bitmap is updated by the arena in the same program-boundary step), so
slab allocation never reads the device — the arena analog of the
``CapacityPlanner`` contract (DESIGN.md §2/§4).

``TenantPlanner`` extends ``core.ggarray.CapacityPlanner``'s bound tracking
to a *fleet*: one upper bound per logical array, advanced by exact per-array
lane counts when the append mask is host-known, plus an optional per-tenant
slab quota — the admission-control knob a multi-tenant serving pool needs so
one runaway sequence cannot starve the others.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

__all__ = [
    "SlabAllocator",
    "TenantPlanner",
    "PageBook",
    "QuotaExceeded",
    "growth_amount",
]


def growth_amount(
    n_slabs: int, short: int, grow_chunk: int | str, *, reserved: int = 0
) -> int:
    """Slabs to add when the free list is ``short`` of a claim.

    ``grow_chunk`` is the over-provisioning policy:

    * an int ``c`` — demand growth with a floor: add ``max(short, c)``
      (``1`` = exact demand, the tight-capacity default);
    * ``"geometric"`` — double the pool: add
      ``max(short, n_slabs + reserved, 1)``, so a fleet that keeps growing
      pays **O(log n_slabs)** realloc copies total instead of one per growth
      wave (Tarjan & Zwick amortization; asserted in
      ``tests/pool/test_arena.py``).

    ``reserved`` is the count of reserved-but-unclaimed slabs from in-flight
    chunked prefills (``SlabAllocator.reserved_total``): the doubling base
    counts them as committed demand, so a growth sized while reservations
    are outstanding leaves headroom for the claims that convert them — a
    grow sized off the free list alone could be exhausted again within the
    same scheduler step (the double-grow the engine tests assert against).

    Pre-carving (``SlabArena(initial_slabs=...)`` / a pool sized to the
    expected high-water mark at engine start) composes with either policy —
    growth only begins once the pre-carve is exhausted.
    """
    if grow_chunk == "geometric":
        return max(short, n_slabs + reserved, 1)
    return max(short, int(grow_chunk))


class QuotaExceeded(RuntimeError):
    """A claim would push a tenant past its per-tenant slab quota."""


class SlabAllocator:
    """Lowest-index-first free list over ``n_slabs`` pool slots.

    Lowest-first claiming makes reuse the default: released slabs always sit
    below freshly grown ones, so the pool only grows once every freed slab
    is back in use (the reclamation invariant the property tests assert).

    Slabs are **refcounted** (DESIGN.md §10): ``claim`` starts a slab at one
    reference, ``addref`` lets a second page table (or the prefix cache)
    alias it, and ``release`` drops one reference per id — the slab only
    returns to the free bitmap when the *last* reference goes.  ``owner``
    names the tenant charged for the slab while its first claimant still
    holds a reference; a slab that outlives its claimant (aliases remain)
    is marked ``SHARED`` so quota accounting stops billing the departed
    tenant.
    """

    SHARED = -2  # owner sentinel: claimed, but the first claimant released

    def __init__(self, n_slabs: int = 0, *, quota_slabs: int | None = None):
        self.free = np.ones((n_slabs,), bool)
        self.owner = np.full((n_slabs,), -1, np.int32)  # tenant per slab
        self.refcount = np.zeros((n_slabs,), np.int32)  # references per slab
        self.quota_slabs = quota_slabs
        self.claims = 0
        self.reuse_claims = 0  # claims satisfied by a previously released slab
        self.releases = 0
        self.alias_claims = 0  # addref calls — shared-page references taken
        self.grown_slabs = 0
        self.peak_live = 0
        # Reservation ledger: slab *counts* (not ids) promised to tenants with
        # in-flight chunked prefills.  Reserved counts are subtracted from the
        # availability other claims see, so decode growth can never starve a
        # prefill that was already admitted (DESIGN.md §7 invariant).
        self.reserved: dict[int, int] = {}
        self._ever_released = np.zeros((n_slabs,), bool)

    @property
    def n_slabs(self) -> int:
        return len(self.free)

    @property
    def free_count(self) -> int:
        return int(self.free.sum())

    @property
    def live_count(self) -> int:
        return self.n_slabs - self.free_count

    @property
    def reserved_total(self) -> int:
        return sum(self.reserved.values())

    def tenant_slabs(self, tenant: int) -> int:
        return int((self.owner == tenant).sum())

    def shortfall(self, k: int, *, tenant: int | None = None) -> int:
        """Slabs the pool must grow by before ``claim(·, k)`` can succeed.

        Outstanding reservations are unavailable to everyone except their own
        tenant: pass ``tenant`` to count that tenant's reservation as usable
        (the claim-from-reservation path).
        """
        avail = self.free_count - self.reserved_total
        if tenant is not None:
            avail += self.reserved.get(tenant, 0)
        return max(k - avail, 0)

    def reserve(self, tenant: int, k: int) -> None:
        """Promise ``k`` slabs to ``tenant`` (quota-checked, ids unassigned).

        The pool must already cover the reservation (grow on
        ``shortfall(k)`` first, like a claim).
        """
        if k == 0:
            return
        if self.quota_slabs is not None:
            held = self.tenant_slabs(tenant) + self.reserved.get(tenant, 0)
            if held + k > self.quota_slabs:
                raise QuotaExceeded(
                    f"tenant {tenant}: {held} + {k} slabs > quota "
                    f"{self.quota_slabs}"
                )
        if self.shortfall(k) > 0:
            raise RuntimeError(
                f"cannot reserve {k}: only "
                f"{self.free_count - self.reserved_total} unreserved slabs free"
            )
        self.reserved[tenant] = self.reserved.get(tenant, 0) + k

    def unreserve(self, tenant: int, k: int | None = None) -> int:
        """Cancel (part of) a tenant's reservation → slabs returned."""
        held = self.reserved.get(tenant, 0)
        k = held if k is None else min(k, held)
        if k:
            self.reserved[tenant] = held - k
            if self.reserved[tenant] == 0:
                del self.reserved[tenant]
        return k

    def grow(self, extra: int) -> None:
        self.free = np.concatenate([self.free, np.ones((extra,), bool)])
        self.owner = np.concatenate([self.owner, np.full((extra,), -1, np.int32)])
        self.refcount = np.concatenate(
            [self.refcount, np.zeros((extra,), np.int32)]
        )
        self._ever_released = np.concatenate(
            [self._ever_released, np.zeros((extra,), bool)]
        )
        self.grown_slabs += extra

    def claim(
        self, tenant: int, k: int, *, from_reservation: bool = False
    ) -> np.ndarray:
        """Claim ``k`` slabs for ``tenant`` → int32 slab ids (lowest first).

        ``from_reservation`` draws down the tenant's reservation first (that
        part was quota-checked at ``reserve`` time); any excess is treated as
        a fresh claim.
        """
        if k == 0:
            return np.zeros((0,), np.int32)
        from_res = min(k, self.reserved.get(tenant, 0)) if from_reservation else 0
        fresh = k - from_res
        if self.quota_slabs is not None and fresh > 0:
            held = self.tenant_slabs(tenant) + self.reserved.get(tenant, 0)
            if held + fresh > self.quota_slabs:
                raise QuotaExceeded(
                    f"tenant {tenant}: {held} + {fresh} slabs "
                    f"> quota {self.quota_slabs}"
                )
        ids = np.flatnonzero(self.free)[:k].astype(np.int32)
        if len(ids) < k:
            raise RuntimeError(
                f"free list exhausted: want {k}, have {len(ids)} "
                "(grow the pool first — see SlabArena._ensure_slabs)"
            )
        self.unreserve(tenant, from_res)
        self.free[ids] = False
        self.owner[ids] = tenant
        self.refcount[ids] = 1
        self.claims += k
        self.reuse_claims += int(self._ever_released[ids].sum())
        self.peak_live = max(self.peak_live, self.live_count)
        return ids

    def addref(self, ids: np.ndarray) -> None:
        """Take one extra reference per id on already-claimed slabs.

        This is the aliasing primitive: a second page table (or the prefix
        cache) pointing at a claimed slab holds a reference, and the slab
        stays out of the free list until every holder releases.  Aliasing a
        free slab is a bug — the data it indexes is gone.
        """
        ids = np.asarray(ids, np.int32)
        if len(ids) == 0:
            return
        if self.free[ids].any():
            raise RuntimeError(f"alias of free slab: {ids[self.free[ids]]}")
        np.add.at(self.refcount, ids, 1)
        self.alias_claims += len(ids)

    def release(
        self, ids: np.ndarray, *, tenant: int | None = None
    ) -> np.ndarray:
        """Drop one reference per id → the ids actually freed.

        Shared slabs (refcount > 1) survive: the free bitmap, ``releases``
        counter, and reuse tracking only move when a slab's **last**
        reference goes.  ``tenant`` marks surviving slabs charged to that
        tenant as :data:`SHARED`, so a departed claimant's quota is no
        longer billed for pages its aliases keep alive.
        """
        ids = np.asarray(ids, np.int32)
        if len(ids) == 0:
            return ids
        if self.free[ids].any():
            raise RuntimeError(f"double free: {ids[self.free[ids]]}")
        np.subtract.at(self.refcount, ids, 1)
        if (self.refcount[ids] < 0).any():
            raise RuntimeError(
                f"negative refcount: {ids[self.refcount[ids] < 0]}"
            )
        freed = np.unique(ids[self.refcount[ids] == 0]).astype(np.int32)
        self.free[freed] = True
        self.owner[freed] = -1
        self._ever_released[freed] = True
        self.releases += len(freed)
        if tenant is not None:
            kept = ids[self.refcount[ids] > 0]
            kept = kept[self.owner[kept] == tenant]
            self.owner[kept] = self.SHARED
        return freed

    def release_tenant(self, tenant: int) -> np.ndarray:
        """Release every slab still *charged to* ``tenant`` → the freed ids.

        Owner-based, so it only sees exclusively-held slabs; sharing callers
        (``PageBook.release``) release their page list instead.
        """
        ids = np.flatnonzero(self.owner == tenant).astype(np.int32)
        return self.release(ids, tenant=tenant)

    def check(self) -> None:
        """Free-xor-claimed, refcount, and reservation-coverage invariants."""
        bad = self.free & (self.owner != -1)
        assert not bad.any(), f"slabs both free and owned: {np.flatnonzero(bad)}"
        bad = ~self.free & (self.owner == -1)
        assert not bad.any(), f"slabs claimed but unowned: {np.flatnonzero(bad)}"
        bad = self.free & (self.refcount != 0)
        assert not bad.any(), f"free slabs with references: {np.flatnonzero(bad)}"
        bad = ~self.free & (self.refcount < 1)
        assert not bad.any(), (
            f"claimed slabs without references: {np.flatnonzero(bad)}"
        )
        assert all(v > 0 for v in self.reserved.values()), self.reserved
        assert self.reserved_total <= self.free_count, (
            f"reservations ({self.reserved_total}) exceed free slabs "
            f"({self.free_count}) — a claim ate reserved capacity"
        )


class PageBook:
    """Host-side page-table bookkeeping shared by the arena and the engine.

    One :class:`SlabAllocator` plus the pieces every page-table owner needs
    kept consistent with it: per-tenant page counts, the slab→page mapping
    (claim order), and the geometric table-width policy.  Pure host state —
    callers apply the matching device updates (pool growth, free bitmap,
    page-table scatters) at the program boundary.  Keeping this in one
    place is what keeps ``SlabArena`` and ``BatchEngine`` free-list
    semantics identical (reuse-before-grow, page0 offsetting, O(log) table
    restructures).
    """

    def __init__(self, ntenants: int, *, quota_slabs: int | None = None):
        self.alloc = SlabAllocator(0, quota_slabs=quota_slabs)
        self.npages = np.zeros((ntenants,), np.int64)
        self.page_of_slab = np.full((0,), -1, np.int64)
        self.max_pages = 1
        # Per-tenant page lists (slab id per page, page order).  With slab
        # sharing a slab can sit in several tables at different page indices,
        # so the flat ``page_of_slab`` inverse is only authoritative for
        # exclusively-held slabs (the arena's kernel tables); these lists
        # are the source of truth for ordering and release.
        self.pages_of: list[list[int]] = [[] for _ in range(ntenants)]

    def grow(self, extra: int) -> None:
        """Record ``extra`` fresh slabs (caller grew the device pool)."""
        self.alloc.grow(extra)
        self.page_of_slab = np.concatenate(
            [self.page_of_slab, np.full((extra,), -1, np.int64)]
        )

    def shortfall(self, k: int, *, tenant: int | None = None) -> int:
        return self.alloc.shortfall(k, tenant=tenant)

    @property
    def reserved_total(self) -> int:
        """Reserved-but-unclaimed slabs — counted when sizing a new extent
        (``growth_amount(..., reserved=...)`` / ``extents.plan_extents``)."""
        return self.alloc.reserved_total

    def reserve(self, tenant: int, k: int) -> None:
        """Promise ``k`` slabs to ``tenant`` (see ``SlabAllocator.reserve``)."""
        self.alloc.reserve(tenant, k)

    def unreserve(self, tenant: int, k: int | None = None) -> int:
        return self.alloc.unreserve(tenant, k)

    def widen(self, need: int) -> tuple[int, int] | None:
        """Geometric table widening → (old, new) widths, or None if covered."""
        if need <= self.max_pages:
            return None
        old, self.max_pages = self.max_pages, max(need, 2 * self.max_pages)
        return old, self.max_pages

    def claim(
        self, tenant: int, k: int, *, from_reservation: bool = False
    ) -> tuple[np.ndarray, int]:
        """Claim ``k`` slabs → (ids, first page index).  Reuse-first; the
        free list must already cover ``k`` (grow the pool on shortfall)."""
        ids = self.alloc.claim(tenant, k, from_reservation=from_reservation)
        page0 = int(self.npages[tenant])
        self.page_of_slab[ids] = page0 + np.arange(k)
        self.pages_of[tenant].extend(int(i) for i in ids)
        self.npages[tenant] += k
        return ids, page0

    def adopt(self, tenant: int, ids: np.ndarray) -> int:
        """Append pre-referenced slabs to ``tenant``'s table → first page.

        The references must already be held (a prefix-cache match pins its
        slabs with ``alloc.addref`` before admission); ``adopt`` just
        transfers them into the page table.  Use :meth:`alias` when the
        reference still needs taking.
        """
        ids = np.asarray(ids, np.int32)
        page0 = int(self.npages[tenant])
        self.pages_of[tenant].extend(int(i) for i in ids)
        self.npages[tenant] += len(ids)
        return page0

    def alias(self, tenant: int, ids: np.ndarray) -> int:
        """Point ``tenant``'s next pages at already-claimed slabs
        (refcount++ per slab) → first page index."""
        ids = np.asarray(ids, np.int32)
        self.alloc.addref(ids)
        return self.adopt(tenant, ids)

    def replace(self, tenant: int, page: int, new_id: int) -> int:
        """Swap the slab at ``page`` of ``tenant``'s table → the old id.

        The copy-on-write primitive: ``new_id`` must already be claimed for
        ``tenant`` via ``alloc.claim`` (so its reference exists); the old
        slab's reference is **not** dropped here — the caller releases it
        after copying the data across.
        """
        old = self.pages_of[tenant][page]
        self.pages_of[tenant][page] = int(new_id)
        self.page_of_slab[new_id] = page
        return int(old)

    def release(self, tenant: int) -> np.ndarray:
        """Drop every page reference of ``tenant`` (and any leftover
        reservation) → the slabs actually freed (last reference gone)."""
        self.alloc.unreserve(tenant)
        ids = np.asarray(self.pages_of[tenant], np.int32)
        freed = self.alloc.release(ids, tenant=tenant)
        self.page_of_slab[freed] = -1
        self.pages_of[tenant] = []
        self.npages[tenant] = 0
        return freed

    def pages_in_order(self, tenant: int) -> np.ndarray:
        """``tenant``'s slab ids in page order."""
        return np.asarray(self.pages_of[tenant], np.int64)


class TenantPlanner:
    """Per-tenant size upper bounds — ``CapacityPlanner`` at fleet scale.

    ``plan(m, mask)`` advances each tenant's bound (exactly, when ``mask``
    is a host array; by ``m`` otherwise) and returns the per-tenant counts;
    ``sync(sizes)`` re-seeds the bounds from a device read when pessimism
    would otherwise claim slabs the data doesn't need.
    """

    def __init__(self, ntenants: int):
        self.ub = np.zeros((ntenants,), np.int64)
        self.host_syncs = 0

    @staticmethod
    def host_counts(mask: Any, ntenants: int, m: int) -> np.ndarray | None:
        if mask is None:
            return np.full((ntenants,), m, np.int64)
        if isinstance(mask, jax.Array):
            return None  # device mask: converting it would be the sync
        arr = np.asarray(mask)
        if arr.ndim != 2 or arr.shape[0] != ntenants:
            return None
        return (arr != 0).sum(axis=1).astype(np.int64)

    def plan(self, m: int, mask: Any = None) -> tuple[np.ndarray, bool]:
        """→ (per-tenant advance, exact?) without touching the bounds."""
        counts = self.host_counts(mask, len(self.ub), m)
        if counts is None:
            return np.full((len(self.ub),), m, np.int64), False
        return counts, mask is None or not isinstance(mask, jax.Array)

    def advance(self, counts: np.ndarray) -> None:
        self.ub += counts

    def sync(self, sizes: jax.Array) -> np.ndarray:
        """Re-seed bounds from the device sizes vector (one transfer)."""
        self.ub = np.asarray(jax.device_get(sizes), np.int64)
        self.host_syncs += 1
        return self.ub

    def reset(self, tenant: int) -> None:
        self.ub[tenant] = 0
