"""Slab arena: one device pool, many logical growable arrays (DESIGN.md §4).

``SlabPool`` is a pre-carved pool of fixed-size slabs (SOA pages) plus a
device-side free-list bitmap.  ``ArenaGGArray`` is the fleet of logical
arrays living in it: each array's storage is a *page table* of slab indices
rather than owned buffers, with the GGArray bucket structure preserved as a
geometric *grouping* of the table — level ``b`` of an array is the
indirection sub-table ``pages[i, 2^b − 1 : 2^(b+1) − 1]`` (``2^b`` slabs, so
level capacities are the familiar ``T·2^b``).  Growth is therefore "claim a
slab": no copy, no per-array worst case, and fleet capacity stays bounded by
live data + one partially-filled slab per array (+ any pessimism slack).

``SlabArena`` is the host manager gluing the pieces together under the
amortized-contact protocol (DESIGN.md §2): claims/releases are planned
against host mirrors (``pool.planner``), device state (pool, bitmap, page
tables) is updated at the program boundary, and the write itself is the
fused ``kernels/paged`` slab-append.  Steady-state appends issue **zero**
device→host transfers; a transfer happens only when pessimistic bounds would
otherwise claim a slab (and the mask is not host-known).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import indexing
from repro.kernels import common
from repro.obs import DeviceCounterPlane, FlightRecorder, MetricsRegistry
from repro.kernels.flatten import kernel as flatten_kernel
from repro.kernels.paged import ops as paged_ops
from repro.pool import extents as extents_mod
from repro.pool.extents import ExtentPool
from repro.pool.planner import PageBook, TenantPlanner, growth_amount

__all__ = [
    "SlabPool",
    "ExtentPool",
    "ArenaGGArray",
    "SlabArena",
    "init_pool",
    "grow_pool",
    "geometric_page_groups",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlabPool:
    """The shared device pool: slab data + free-list bitmap."""

    data: jax.Array  # (n_slabs, slab_size, *item_shape)
    free: jax.Array  # (n_slabs,) bool — True = claimable

    @property
    def n_slabs(self) -> int:
        return self.data.shape[0]

    @property
    def slab_size(self) -> int:
        return self.data.shape[1]

    @property
    def item_shape(self) -> tuple[int, ...]:
        return self.data.shape[2:]

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def capacity_tokens(self) -> int:
        return self.n_slabs * self.slab_size


def init_pool(
    n_slabs: int,
    slab_size: int,
    item_shape: Sequence[int] = (),
    dtype: Any = jnp.float32,
) -> SlabPool:
    return SlabPool(
        data=jnp.zeros((n_slabs, slab_size, *item_shape), dtype=dtype),
        free=jnp.ones((n_slabs,), bool),
    )


def grow_pool(pool: SlabPool, extra: int) -> SlabPool:
    """Append ``extra`` fresh slabs by realloc+copy (flat layout).

    This is the copy the segmented :class:`~repro.pool.extents.ExtentPool`
    layout eliminates — kept as the flat fallback and oracle (the arena uses
    it for int/``"geometric"`` ``grow_chunk`` via ``extents.grow_flat``).
    Existing slab contents never move logically: page tables are indices, so
    no table changes.
    """
    return SlabPool(
        data=jnp.concatenate(
            [pool.data, jnp.zeros((extra, *pool.data.shape[1:]), pool.dtype)]
        ),
        free=jnp.concatenate([pool.free, jnp.ones((extra,), bool)]),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ArenaGGArray:
    """The fleet's logical arrays: per-array page tables + sizes.

    ``pages[i, p]`` is the slab holding array ``i``'s positions
    ``[p·T, (p+1)·T)``; −1 = unclaimed.  Bucket level ``b`` of array ``i``
    is the sub-table ``pages[i, 2^b − 1 : 2^(b+1) − 1]``.
    """

    pages: jax.Array  # (narrays, max_pages) int32
    sizes: jax.Array  # (narrays,) int32

    @property
    def narrays(self) -> int:
        return self.pages.shape[0]

    @property
    def max_pages(self) -> int:
        return self.pages.shape[1]


def geometric_page_groups(max_pages: int) -> list[tuple[int, int]]:
    """GGArray bucket levels as page-table slices: [(2^b−1, 2^(b+1)−1), …).

    The grouping under which a paged walk reproduces the ggarray bucket walk
    segment-for-segment (the bit-exactness contract of the paged serving
    policy when ``slab_size == cache_b0``).
    """
    groups = []
    lo = 0
    width = 1
    while lo < max_pages:
        groups.append((lo, min(lo + width, max_pages)))
        lo += width
        width *= 2
    return groups


class SlabArena:
    """Host manager for one pool + ``narrays`` logical growable arrays."""

    def __init__(
        self,
        narrays: int,
        slab_size: int,
        *,
        item_shape: Sequence[int] = (),
        dtype: Any = jnp.float32,
        initial_slabs: int = 0,
        max_pages: int = 1,
        quota_slabs: int | None = None,
        append_method: str = "fused",
        memory_space: str | None = None,
        dispatch: str = "auto",
        grow_chunk: int | str = 1,
        instrument: bool = False,
        registry: MetricsRegistry | None = None,
    ):
        """``initial_slabs`` pre-carves the pool at start (the high-water
        knob); ``grow_chunk`` is the growth policy on exhaustion:

        * int floor or ``"geometric"`` — flat single-extent layout, growth
          reallocs+copies the pool (``pool.planner.growth_amount``;
          geometric caps it at O(log slabs) copies) — the fallback/oracle;
        * ``"doubling"`` / ``"tz"`` — segmented extents (``pool.extents``):
          growth appends a fresh extent and a two-level table row, **zero
          pool bytes copied** (``pool_copied_bytes`` stays 0).

        ``memory_space`` / ``dispatch`` select the paged-kernel tiling and
        insert-permutation backend (``kernels/common``; None/"auto" =
        backend defaults)."""
        if slab_size < 1:
            raise ValueError("slab_size must be >= 1")
        self.pool = extents_mod.init_extent_pool(
            initial_slabs, slab_size, item_shape, dtype
        )
        self.arr = ArenaGGArray(
            pages=jnp.full((narrays, max(max_pages, 1)), -1, jnp.int32),
            sizes=jnp.zeros((narrays,), jnp.int32),
        )
        # one shared host book: allocator + page counts + slab→page mapping
        self.book = PageBook(narrays, quota_slabs=quota_slabs)
        self.book.grow(initial_slabs)
        self.book.max_pages = max(max_pages, 1)
        self.planner = TenantPlanner(narrays)
        self.append_method = append_method
        self.memory_space = memory_space
        self.dispatch = dispatch
        self.grow_chunk = grow_chunk
        self.instrument = instrument
        # device mirrors of owners/bases, refreshed only when claims change
        self._tables_dev: tuple[jax.Array, jax.Array] | None = None
        # metrics (DESIGN.md §9): counters/gauges in a registry, the legacy
        # int attributes survive as read properties below.  Pool occupancy is
        # registered as callback gauges so snapshots always see live values.
        self.registry = registry if registry is not None else MetricsRegistry()
        reg = self.registry
        reg.counter("arena.appends", "wave appends executed")
        reg.counter("pool.grow_events", "pool capacity growth events")
        reg.counter("pool.table_grow_events", "page-table widenings")
        # bytes of live pool data copied by growth: stays 0 under the extent
        # schedules (the zero-copy contract CI gates on), O(log n)·pool under
        # "geometric", O(grows)·pool under int chunking.
        reg.counter("pool.copied_bytes", "pool bytes memcpy'd by realloc growth")
        reg.gauge("pool.live_tokens_ub", "host upper bound on live elements")
        reg.gauge_fn("pool.host_syncs", lambda: self.planner.host_syncs,
                     "planner device contacts")
        reg.gauge_fn("pool.capacity_tokens", lambda: self.capacity_tokens)
        reg.gauge_fn("pool.live_slabs", lambda: self.alloc.live_count)
        reg.gauge_fn("pool.free_slabs", lambda: self.alloc.free_count)
        reg.gauge_fn("pool.reserved_slabs", lambda: self.alloc.reserved_total)
        reg.gauge_fn("pool.utilization", self.utilization)
        # device counter plane + flight recorder (DESIGN.md §9.x/§9.y):
        # instrumented appends hand their counter vector to the plane;
        # invariant violations dump a postmortem bundle before raising
        self.devctr = DeviceCounterPlane(reg)
        self.flight = FlightRecorder()

    @property
    def alloc(self):
        return self.book.alloc

    # ---- legacy stat attributes (reads of the registry) ------------------
    @property
    def appends(self) -> int:
        return int(self.registry.counter("arena.appends").total())

    @property
    def pool_grow_events(self) -> int:
        return int(self.registry.counter("pool.grow_events").total())

    @property
    def table_grow_events(self) -> int:
        return int(self.registry.counter("pool.table_grow_events").total())

    @property
    def peak_live_ub(self) -> int:
        return int(self.registry.gauge("pool.live_tokens_ub").hwm())

    @property
    def pool_copied_bytes(self) -> int:
        return int(self.registry.counter("pool.copied_bytes").total())

    # ---- geometry --------------------------------------------------------
    @property
    def narrays(self) -> int:
        return self.arr.narrays

    @property
    def slab_size(self) -> int:
        return self.pool.slab_size

    @property
    def item_shape(self) -> tuple[int, ...]:
        return self.pool.item_shape

    @property
    def capacity_tokens(self) -> int:
        return self.pool.capacity_tokens

    @property
    def live_tokens_ub(self) -> int:
        """Host upper bound on live elements (exact under host-known masks)."""
        return int(self.planner.ub.sum())

    @property
    def host_syncs(self) -> int:
        return self.planner.host_syncs

    def utilization(self) -> float:
        cap = self.capacity_tokens
        return self.live_tokens_ub / cap if cap else 0.0

    # nblocks/sizes aliases — the wave-interface surface TwoPhasePipeline uses
    @property
    def nblocks(self) -> int:
        return self.narrays

    @property
    def sizes(self) -> jax.Array:
        return self.arr.sizes

    def memory_elems(self) -> int:
        return self.capacity_tokens

    # ---- slab claiming ---------------------------------------------------
    def _ensure_table_width(self, need: int) -> None:
        widened = self.book.widen(need)  # geometric: O(log) restructures
        if widened is None:
            return
        old, new = widened
        pad = jnp.full((self.narrays, new - old), -1, jnp.int32)
        self.arr = dataclasses.replace(
            self.arr, pages=jnp.concatenate([self.arr.pages, pad], axis=1)
        )
        self.registry.counter("pool.table_grow_events").inc()

    def _ensure_slabs(self, k: int) -> None:
        short = self.book.shortfall(k)
        if short == 0:
            return
        reserved = self.alloc.reserved_total
        if extents_mod.is_extent_schedule(self.grow_chunk):
            new_sizes = extents_mod.plan_extents(
                self.pool.extent_sizes, short, self.grow_chunk,
                reserved=reserved,
            )
            self.pool = extents_mod.grow_extents(self.pool, new_sizes)
            extra = sum(new_sizes)
        else:
            extra = growth_amount(
                self.pool.n_slabs, short, self.grow_chunk, reserved=reserved
            )
            self.registry.counter("pool.copied_bytes").inc(
                self.pool.capacity_tokens
                * int(np.prod(self.item_shape, dtype=np.int64))
                * jnp.dtype(self.pool.dtype).itemsize
            )
            self.pool = extents_mod.grow_flat(self.pool, extra)
        self.book.grow(extra)
        self.registry.counter("pool.grow_events").inc()

    def _claim(self, per_tenant: np.ndarray) -> None:
        """Claim ``per_tenant[i]`` fresh slabs for each array (one scatter)."""
        total = int(per_tenant.sum())
        if total == 0:
            return
        self._ensure_table_width(int((self.book.npages + per_tenant).max()))
        self._ensure_slabs(total)
        rows, cols, ids = [], [], []
        for tenant in np.flatnonzero(per_tenant):
            k = int(per_tenant[tenant])
            got, page0 = self.book.claim(int(tenant), k)
            rows.extend([int(tenant)] * k)
            cols.extend(range(page0, page0 + k))
            ids.extend(int(s) for s in got)
        self.arr = dataclasses.replace(
            self.arr,
            pages=self.arr.pages.at[jnp.asarray(rows), jnp.asarray(cols)].set(
                jnp.asarray(ids, jnp.int32)
            ),
        )
        self.pool = dataclasses.replace(
            self.pool, free=self.pool.free.at[jnp.asarray(ids)].set(False)
        )
        self._tables_dev = None  # ownership changed: refresh kernel tables

    def _owner_tables(self) -> tuple[jax.Array, jax.Array]:
        if self._tables_dev is None:
            self._tables_dev = (
                jnp.asarray(self.book.alloc.owner),
                jnp.asarray(self.book.page_of_slab * self.slab_size, jnp.int32),
            )
        return self._tables_dev

    def _pool_arg(self):
        """The pool as the paged ops expect it: a flat array for the
        single-extent layout (the original trace), a tuple of extents for
        the segmented layouts (resolved through the two-level table)."""
        if self.pool.n_extents == 1:
            return self.pool.extents[0]
        return self.pool.extents

    # ---- the hot path ----------------------------------------------------
    def append(self, elems: jax.Array, mask: Any = None) -> jax.Array:
        """Wave append: up to ``m`` elements per array → positions (−1 masked).

        ``elems: (narrays, m, *item_shape)``.  Capacity planning follows the
        PLAN state machine: host bounds advance by exact lane counts when
        ``mask`` is host-known, pessimistically by ``m`` otherwise; a device
        read happens only when pessimism alone would claim a new slab.
        """
        n, m = elems.shape[:2]
        if n != self.narrays:
            raise ValueError(f"elems rows {n} != narrays {self.narrays}")
        if m == 0:
            return jnp.zeros((n, 0), jnp.int32)
        T = self.slab_size
        counts, exact = self.planner.plan(m, mask)
        need = -(-(self.planner.ub + counts) // T)  # pages needed per array
        delta = np.maximum(need - self.book.npages, 0)
        if delta.any() and not exact:
            # PLAN: one vector read re-seeds the bounds before claiming
            self.planner.sync(self.arr.sizes)
            need = -(-(self.planner.ub + counts) // T)
            delta = np.maximum(need - self.book.npages, 0)
        self._claim(delta)
        owners, bases = self._owner_tables()
        if mask is None:
            mask_dev = jnp.ones((n, m), bool)
        else:
            mask_dev = jnp.asarray(mask)
            if mask_dev.dtype != jnp.bool_:
                mask_dev = mask_dev != 0
        outs = paged_ops.slab_append_donated(
            self._pool_arg(),
            owners,
            bases,
            self.arr.sizes,
            elems,
            mask_dev,
            use_ref=self.append_method in ("ref", "jnp"),
            memory_space=self.memory_space,
            dispatch=self.dispatch,
            instrument=self.instrument,
        )
        data, sizes, pos = outs[:3]
        if self.instrument:
            self.devctr.add(outs[3])  # a list append — no transfer
        new_exts = tuple(data) if isinstance(data, (tuple, list)) else (data,)
        self.pool = dataclasses.replace(self.pool, extents=new_exts)
        self.arr = dataclasses.replace(self.arr, sizes=sizes)
        self.planner.advance(counts)
        self.registry.counter("arena.appends").inc()
        self.registry.gauge("pool.live_tokens_ub").set(self.live_tokens_ub)
        return pos

    # ---- reclamation -----------------------------------------------------
    def release(self, tenant: int) -> int:
        """Free every slab of array ``tenant`` (sequence completed) → count.

        The slabs go back on the free list (host + device bitmap) and are
        reused by later claims *before* the pool grows — the reclamation
        invariant the property tests assert.
        """
        ids = self.book.release(tenant)
        if len(ids):
            self.pool = dataclasses.replace(
                self.pool, free=self.pool.free.at[jnp.asarray(ids)].set(True)
            )
            self._tables_dev = None
        self.arr = ArenaGGArray(
            pages=self.arr.pages.at[tenant].set(-1),
            sizes=self.arr.sizes.at[tenant].set(0),
        )
        self.planner.reset(tenant)
        return len(ids)

    # ---- reads -----------------------------------------------------------
    def logical_view(self) -> jax.Array:
        """(narrays, max_pages·T, *item) contiguous views (paged gather)."""
        return paged_ops.paged_gather(
            self._pool_arg(), self.arr.pages, memory_space=self.memory_space
        )

    def flatten(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """→ (flat, total, block_starts) in block-major global order.

        The arena's freeze path: a paged gather materializes each array's
        compact row, then the flatten kernels' segmented gather (scalar
        items) or a jnp scatter (non-scalar) applies the global ordering —
        the same two-step structure as ``kernels/flatten``.
        """
        starts = indexing.block_starts(self.arr.sizes).astype(jnp.int32)
        total = jnp.sum(self.arr.sizes)
        cap_pb = self.arr.max_pages * self.slab_size
        if self.pool.n_slabs == 0:
            flat = jnp.zeros(
                (self.narrays * cap_pb, *self.item_shape), self.pool.dtype
            )
            return flat, total, starts
        compact = self.logical_view()
        if not self.item_shape:
            flat = flatten_kernel.segmented_gather_pallas(
                compact,
                starts,
                starts + self.arr.sizes.astype(jnp.int32),
                memory_space=common.resolve_memory_space(self.memory_space),
                interpret=common.should_interpret(None),
            )
            return flat, total, starts
        cap = self.narrays * cap_pb
        posn = jnp.arange(cap_pb, dtype=jnp.int32)[None, :]
        live = posn < self.arr.sizes[:, None]
        tgt = jnp.where(live, starts[:, None] + posn, cap)
        flat = jnp.zeros((cap, *self.item_shape), self.pool.dtype)
        flat = flat.at[tgt].set(compact, mode="drop")
        return flat, total, starts

    # ---- verification (test/debug only: reads the device) ----------------
    def _flight_dump(self, reason: str, error: BaseException | None = None,
                     invariant: dict | None = None) -> None:
        """Postmortem bundle on invariant failure; never raises or re-dumps."""
        if error is not None and getattr(error, "_flightrec_dumped", False):
            return
        try:
            state = {
                "narrays": self.narrays,
                "slab_size": self.slab_size,
                "extent_sizes": list(self.pool.extent_sizes),
                "n_slabs": self.pool.n_slabs,
                "free_ids": np.flatnonzero(self.alloc.free).tolist(),
                "refcounts": np.asarray(self.alloc.refcount).tolist(),
                "npages": np.asarray(self.book.npages).tolist(),
                "live_ub": np.asarray(self.planner.ub).tolist(),
                "page_tables": [
                    [int(s) for s in self.book.pages_of[i]]
                    for i in range(self.narrays)
                ],
            }
            if invariant:
                state["invariant"] = dict(invariant)
            self.flight.dump(
                reason=reason, error=error, state=state,
                metrics=self.registry.snapshot(),
                device_counters=self.devctr.counters(),
            )
        except Exception:
            return
        if error is not None:
            try:
                error._flightrec_dumped = True
            except Exception:
                pass

    def check_invariants(self) -> dict:
        """Cross-check device state against host mirrors; raises on drift.

        A failure dumps a flight-recorder bundle (offending slab ids, page
        tables, refcounts) before the assertion propagates — DESIGN.md §9.y.
        """
        try:
            return self._check_invariants_inner()
        except AssertionError as e:
            self._flight_dump("arena_invariant", e)
            raise

    def _check_invariants_inner(self) -> dict:
        free_dev = np.asarray(jax.device_get(self.pool.free))
        pages_dev = np.asarray(jax.device_get(self.arr.pages))
        sizes_dev = np.asarray(jax.device_get(self.arr.sizes))
        assert (free_dev == self.alloc.free).all(), "device bitmap drifted"
        # two-level table round-trip: base[ext_of[s]] + off_of[s] == s
        ext_of, off_of = extents_mod.slab_tables(self.pool.extent_sizes)
        assert len(ext_of) == self.pool.n_slabs == len(free_dev), (
            "extent sizes disagree with the free bitmap"
        )
        if len(ext_of):
            bases = np.asarray(self.pool.bases)
            assert (
                bases[ext_of] + off_of == np.arange(self.pool.n_slabs)
            ).all(), "two-level table does not round-trip"
        self.alloc.check()
        claimed = pages_dev[pages_dev >= 0]
        assert not free_dev[claimed].any() if len(claimed) else True, (
            "free slab present in a page table"
        )
        # refcount audit (DESIGN.md §10): every reference on a claimed slab
        # is exactly one live page-table entry — the arena never aliases, so
        # this also implies the old uniqueness + coverage invariants (a
        # double-assigned slab would need refcount 2; an orphaned claim
        # would have refcount 0 and fail alloc.check above).
        refs = np.zeros((self.alloc.n_slabs,), np.int64)
        if len(claimed):
            vals, counts = np.unique(claimed, return_counts=True)
            refs[vals] = counts
        bad = np.flatnonzero(refs != self.alloc.refcount)
        if len(bad):
            err = AssertionError(f"refcounts drift from page tables: {bad}")
            self._flight_dump(
                "refcount_mismatch", err,
                invariant={
                    "check": "refcount_conservation",
                    "offending_slabs": bad.tolist(),
                    "expected_refcount": refs[bad].tolist(),
                    "actual_refcount": np.asarray(self.alloc.refcount)[bad].tolist(),
                },
            )
            raise err
        for i in range(self.narrays):
            npg = int(self.book.npages[i])
            assert (pages_dev[i, :npg] >= 0).all(), f"array {i}: hole in table"
            assert (pages_dev[i, npg:] == -1).all(), f"array {i}: stray pages"
            assert sizes_dev[i] <= npg * self.slab_size, f"array {i}: overflow"
            assert sizes_dev[i] <= self.planner.ub[i], f"array {i}: bound lies"
        return {
            "live_slabs": self.alloc.live_count,
            "free_slabs": self.alloc.free_count,
            "live_tokens": int(sizes_dev.sum()),
            "capacity_tokens": self.capacity_tokens,
            "reuse_claims": self.alloc.reuse_claims,
            "grown_slabs": self.alloc.grown_slabs,
        }
