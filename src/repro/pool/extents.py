"""Segmented pool extents: zero-copy growth via a two-level page table.

The realloc in ``grow_pool`` was the one copy left on the growth path: even
geometric chunking pays O(log slabs) *full-pool* memcpys, each one a latency
spike mid-serve.  This module replaces the monolithic pool array with a list
of fixed-size **extents** plus a two-level mapping

    slab id  s  →  (extent id ``ext_of[s]``, offset-in-extent ``off_of[s]``)

so growth is "allocate one new extent and append a table row" — existing
extents keep their device buffers, and **zero pool bytes are ever copied**
(Tarjan & Zwick, "Optimal resizable arrays"; DynaSOAr's hierarchical blocks
are the massively-parallel precedent — see PAPERS.md and DESIGN.md §8).

Global slab ids stay the allocator's currency: ids are assigned in extent
order, so the concatenation of all extents *is* the flat pool and every jnp
oracle keeps working on ``flat_data(pool)`` unchanged.  Kernels resolve ids
through the (``ext_of``, ``off_of``) tables — host-derived from the static
extent sizes, so the resolution adds no device reads.

Two growth schedules are selectable via ``grow_chunk`` (plus the flat
single-extent fallback, which preserves the realloc behaviour as oracle):

``"doubling"``
    One new extent sized ``max(short, committed, 1)`` where ``committed``
    counts live + reserved slabs — the pool doubles, so a fleet that keeps
    growing holds **O(log n)** extents and wastes at most half the pool.

``"tz"``
    The Tarjan–Zwick optimal-block sequence: superblock ``k`` holds
    ``2^⌊k/2⌋`` extents of ``2^⌈k/2⌉`` slabs each (sizes 1, 2, 2, 2,
    4, 4, 4, 4, 4, 4, 8, …), giving **O(√n)** extents *and* O(√n)
    waste — asymptotically optimal for a resizable array.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ExtentPool",
    "EXTENT_SCHEDULES",
    "is_extent_schedule",
    "init_extent_pool",
    "grow_extents",
    "grow_flat",
    "plan_extents",
    "slab_tables",
    "resolve_pages",
    "flat_data",
]

EXTENT_SCHEDULES = ("doubling", "tz")


def is_extent_schedule(grow_chunk: Any) -> bool:
    """True when ``grow_chunk`` selects a zero-copy extent layout."""
    return isinstance(grow_chunk, str) and grow_chunk in EXTENT_SCHEDULES


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExtentPool:
    """The shared device pool as a tuple of extents + one free bitmap.

    ``extents[e]`` is ``(size_e, slab_size, *item_shape)``; slab ids are
    global (extent-order), so ``free`` stays a single ``(n_slabs,)`` bitmap
    — metadata small enough that concatenating it on growth is noise next
    to the pool bytes the extents never copy.
    """

    extents: tuple[jax.Array, ...]
    free: jax.Array  # (n_slabs,) bool — True = claimable

    @property
    def extent_sizes(self) -> tuple[int, ...]:
        return tuple(e.shape[0] for e in self.extents)

    @property
    def bases(self) -> tuple[int, ...]:
        """Global slab id of each extent's slab 0."""
        out, acc = [], 0
        for s in self.extent_sizes:
            out.append(acc)
            acc += s
        return tuple(out)

    @property
    def n_extents(self) -> int:
        return len(self.extents)

    @property
    def n_slabs(self) -> int:
        return sum(self.extent_sizes)

    @property
    def slab_size(self) -> int:
        return self.extents[0].shape[1]

    @property
    def item_shape(self) -> tuple[int, ...]:
        return self.extents[0].shape[2:]

    @property
    def dtype(self):
        return self.extents[0].dtype

    @property
    def capacity_tokens(self) -> int:
        return self.n_slabs * self.slab_size

    @property
    def data(self) -> jax.Array:
        """Flat (n_slabs, slab_size, *item) view — **copies** when multi-
        extent; oracle/debug only, never the hot path."""
        return flat_data(self.extents)


def init_extent_pool(
    n_slabs: int,
    slab_size: int,
    item_shape: Sequence[int] = (),
    dtype: Any = jnp.float32,
) -> ExtentPool:
    """Pre-carve the pool as one initial extent (possibly empty)."""
    return ExtentPool(
        extents=(jnp.zeros((n_slabs, slab_size, *item_shape), dtype=dtype),),
        free=jnp.ones((n_slabs,), bool),
    )


def _tz_size(j: int) -> int:
    """Size of the ``j``-th data block in the Tarjan–Zwick sequence.

    Superblock ``k`` holds ``2^⌊k/2⌋`` blocks of ``2^⌈k/2⌉`` slabs each,
    so block sizes run 1, 2, 2, 2, 4, 4, 4, 4, 4, 4, 8, … — after ``n``
    appends both the last block and the block count are Θ(√n), which is
    what makes the waste bound O(√n) rather than doubling's n/2.
    """
    k = 0
    while j >= 1 << (k // 2):
        j -= 1 << (k // 2)
        k += 1
    return 1 << ((k + 1) // 2)


def plan_extents(
    existing_sizes: Sequence[int],
    short: int,
    schedule: str,
    *,
    reserved: int = 0,
) -> list[int]:
    """Sizes of the new extent(s) covering ``short`` fresh slabs.

    ``reserved`` counts reserved-but-unclaimed slabs from in-flight prefills:
    the doubling schedule sizes off *committed* demand (``n_slabs +
    reserved``), not the free list alone, so converting those reservations to
    claims cannot trigger an immediate second grow (the accounting fix the
    scheduler tests assert).  The tz sequence has fixed block sizes and
    ``shortfall()`` already folds reservations into ``short``, so ``reserved``
    is ignored there.
    """
    if short <= 0:
        return []
    total = sum(existing_sizes)
    if schedule == "doubling":
        return [max(short, total + reserved, 1)]
    if schedule != "tz":
        raise ValueError(f"unknown extent schedule {schedule!r}")
    sizes: list[int] = []
    k = len([s for s in existing_sizes if s > 0])
    got = 0
    while got < short:
        step = _tz_size(k)
        sizes.append(step)
        got += step
        k += 1
    return sizes


def grow_extents(pool: ExtentPool, new_sizes: Sequence[int]) -> ExtentPool:
    """Append fresh zero extents — existing extents pass through **by
    identity** (the zero-copy contract the buffer-identity test spies on).

    Zero-size extents (an empty pre-carve) are dropped once a real extent
    exists; they hold no slab ids, so the global numbering is unchanged.
    """
    if not new_sizes:
        return pool
    T, item, dt = pool.slab_size, pool.item_shape, pool.dtype
    keep = tuple(e for e in pool.extents if e.shape[0] > 0)
    fresh = tuple(jnp.zeros((s, T, *item), dt) for s in new_sizes if s > 0)
    extra = sum(new_sizes)
    return ExtentPool(
        extents=(keep + fresh) or pool.extents,
        free=jnp.concatenate([pool.free, jnp.ones((extra,), bool)]),
    )


def grow_flat(pool: ExtentPool, extra: int) -> ExtentPool:
    """The realloc fallback: widen a single-extent pool by copy (oracle and
    baseline for the extent schedules; O(log) copies under "geometric")."""
    if pool.n_extents != 1:
        raise ValueError("grow_flat requires a single-extent (flat) pool")
    data = pool.extents[0]
    return ExtentPool(
        extents=(
            jnp.concatenate(
                [data, jnp.zeros((extra, *data.shape[1:]), data.dtype)]
            ),
        ),
        free=jnp.concatenate([pool.free, jnp.ones((extra,), bool)]),
    )


@lru_cache(maxsize=None)
def slab_tables(extent_sizes: tuple[int, ...]) -> tuple[np.ndarray, np.ndarray]:
    """Host two-level table: global slab id → (extent id, offset-in-extent).

    Pure shape arithmetic — derived from the static extent sizes, cached per
    geometry, never a device read.
    """
    ext = np.concatenate(
        [np.full((s,), e, np.int32) for e, s in enumerate(extent_sizes)]
        or [np.zeros((0,), np.int32)]
    )
    off = np.concatenate(
        [np.arange(s, dtype=np.int32) for s in extent_sizes]
        or [np.zeros((0,), np.int32)]
    )
    return ext, off


def resolve_pages(
    pages: jax.Array, extent_sizes: tuple[int, ...]
) -> tuple[jax.Array, jax.Array]:
    """Resolve a page table of global slab ids through the two-level table.

    → ``(ext_tbl, off_tbl)`` int32 with the page table's shape; invalid ids
    (< 0, the unclaimed-page sentinel) map to (−1, −1).
    """
    ext_np, off_np = slab_tables(tuple(extent_sizes))
    n = len(ext_np)
    pages = pages.astype(jnp.int32)
    valid = (pages >= 0) & (pages < n)
    idx = jnp.clip(pages, 0, max(n - 1, 0))
    ext = jnp.where(valid, jnp.asarray(ext_np)[idx], -1)
    off = jnp.where(valid, jnp.asarray(off_np)[idx], -1)
    return ext, off


def flat_data(extents: Sequence[jax.Array]) -> jax.Array:
    """Concatenate extents into the flat pool (global-id order) — the jnp
    oracle for every multi-extent kernel; copies, so debug/oracle only."""
    extents = tuple(extents)
    if len(extents) == 1:
        return extents[0]
    return jnp.concatenate(extents, axis=0)
