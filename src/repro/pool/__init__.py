"""Multi-tenant slab arena — many GGArrays, one device pool (DESIGN.md §4).

One pre-carved pool of fixed-size slabs (SOA pages) backs a whole fleet of
logical growable arrays: growth is "claim a slab" through a free-list bitmap
instead of allocating a per-array bucket chain, so fleet capacity is bounded
by live data + one slab per array — the DynaSOAr-style answer to the
worst-case-VRAM problem GGArray solves for a single array.
"""
from repro.pool.arena import (
    ArenaGGArray,
    SlabArena,
    SlabPool,
    grow_pool,
    init_pool,
)
from repro.pool.extents import (
    EXTENT_SCHEDULES,
    ExtentPool,
    grow_extents,
    init_extent_pool,
    is_extent_schedule,
    plan_extents,
)
from repro.pool.planner import (
    PageBook,
    QuotaExceeded,
    SlabAllocator,
    TenantPlanner,
    growth_amount,
)

__all__ = [
    "ArenaGGArray",
    "SlabArena",
    "SlabPool",
    "ExtentPool",
    "EXTENT_SCHEDULES",
    "SlabAllocator",
    "TenantPlanner",
    "PageBook",
    "QuotaExceeded",
    "init_pool",
    "init_extent_pool",
    "grow_pool",
    "grow_extents",
    "plan_extents",
    "is_extent_schedule",
    "growth_amount",
]
